//! The runtime server thread: owns the (non-Send) `XlaRuntime` and serves
//! channel RPCs from any number of cloneable `XlaHandle`s.
//!
//! One thread per GPU is the honest topology for the paper's system:
//! SEED-RL funnels all inference and training through a single
//! accelerator-side service. Requests are processed FIFO; the inference
//! batcher upstream is what creates GPU-efficient batch sizes.

use super::engine::XlaRuntime;
use super::{InferReply, InferRequest, ModelDims, TrainBatch, TrainReply};
use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;

enum Msg {
    Infer(InferRequest, mpsc::Sender<anyhow::Result<InferReply>>),
    Train(TrainBatch, mpsc::Sender<anyhow::Result<TrainReply>>),
    SyncTarget(mpsc::Sender<anyhow::Result<()>>),
    Stop,
}

/// Cloneable, Send handle to the runtime thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: mpsc::Sender<Msg>,
    dims: ModelDims,
}

/// Owns the runtime thread; join on drop (after issuing Stop).
pub struct XlaServer {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl XlaServer {
    /// Spawn the runtime thread, loading artifacts from `dir`.
    /// Returns (server, handle); clone the handle freely.
    pub fn spawn(
        dir: &Path,
        infer_batches: Option<Vec<usize>>,
        with_train: bool,
    ) -> anyhow::Result<(XlaServer, XlaHandle)> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<ModelDims>>();
        let dir = dir.to_path_buf();
        let join = std::thread::Builder::new()
            .name("rlarch-runtime".into())
            .spawn(move || {
                let mut rt = match XlaRuntime::load(
                    &dir,
                    infer_batches.as_deref(),
                    with_train,
                ) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(rt.dims()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Infer(req, reply) => {
                            let _ = reply.send(rt.infer(&req));
                        }
                        Msg::Train(batch, reply) => {
                            let _ = reply.send(rt.train(&batch));
                        }
                        Msg::SyncTarget(reply) => {
                            let _ = reply.send(rt.sync_target());
                        }
                        Msg::Stop => break,
                    }
                }
            })
            .expect("spawn runtime thread");
        let dims = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("runtime thread died during load"))??;
        let handle = XlaHandle {
            tx: tx.clone(),
            dims,
        };
        Ok((
            XlaServer {
                tx,
                join: Some(join),
            },
            handle,
        ))
    }
}

impl Drop for XlaServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl XlaHandle {
    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    fn rpc<R>(&self, build: impl FnOnce(mpsc::Sender<anyhow::Result<R>>) -> Msg) -> anyhow::Result<R> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(build(rtx))
            .map_err(|_| anyhow::anyhow!("runtime thread gone"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("runtime thread dropped reply"))?
    }

    pub fn infer(&self, req: InferRequest) -> anyhow::Result<InferReply> {
        self.rpc(|r| Msg::Infer(req, r))
    }

    pub fn train(&self, batch: TrainBatch) -> anyhow::Result<TrainReply> {
        self.rpc(|r| Msg::Train(batch, r))
    }

    pub fn sync_target(&self) -> anyhow::Result<()> {
        self.rpc(Msg::SyncTarget)
    }
}
