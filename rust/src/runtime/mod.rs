//! PJRT runtime: loads the AOT HLO artifacts and serves inference/train
//! requests to the coordinator. Python never runs here — the artifacts
//! are self-contained HLO text compiled once at startup.
//!
//! Threading model: the `xla` crate's handles are not `Send`, so a single
//! dedicated runtime thread owns the PJRT client, the compiled
//! executables, and the parameter literals (`server::XlaServer`). This is
//! also the faithful model of the paper's system: SEED RL's *central
//! inference* design funnels every observation through one GPU-side
//! service instead of running per-actor CPU inference (IMPALA). The
//! coordinator talks to it through the cloneable [`Backend`] handle.

pub mod bundle;
pub mod checkpoint;
pub mod engine;
pub mod manifest;
pub mod mock;
pub mod server;
pub mod tensor;

pub use bundle::Bundle;
pub use engine::XlaRuntime;
pub use manifest::Manifest;
pub use mock::MockModel;
pub use server::{XlaHandle, XlaServer};
pub use tensor::{DType, Tensor, TensorData};

use std::sync::Arc;

/// Model dimensions the coordinator needs for buffer sizing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub obs_len: usize,
    pub hidden: usize,
    pub num_actions: usize,
    pub seq_len: usize,
    pub train_batch: usize,
}

/// A batched inference request: `n` rows of recurrent state + obs.
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub n: usize,
    pub h: Vec<f32>,   // [n * hidden]
    pub c: Vec<f32>,   // [n * hidden]
    pub obs: Vec<f32>, // [n * obs_len]
}

/// Borrowed view of a batched inference request: `n` rows sliced out of
/// caller-owned slabs. Lets chunked local inference hand the backend a
/// row range without copying it into an owned [`InferRequest`] first —
/// the mock consumes the slices directly; the XLA path converts to an
/// owned request only at the channel boundary, where ownership is
/// genuinely required.
#[derive(Clone, Copy, Debug)]
pub struct InferSlices<'a> {
    pub n: usize,
    pub h: &'a [f32],   // [n * hidden]
    pub c: &'a [f32],   // [n * hidden]
    pub obs: &'a [f32], // [n * obs_len]
}

/// Inference output: q-values and next recurrent state, `n` rows.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub q: Vec<f32>, // [n * num_actions]
    pub h: Vec<f32>, // [n * hidden]
    pub c: Vec<f32>, // [n * hidden]
}

/// A learner batch in the train artifact's ABI layout (batch-major).
#[derive(Clone, Debug)]
pub struct TrainBatch {
    pub batch: usize,
    pub obs: Vec<f32>,       // [B * T * obs_len]
    pub actions: Vec<i32>,   // [B * T]
    pub rewards: Vec<f32>,   // [B * T]
    pub discounts: Vec<f32>, // [B * T]
    pub h0: Vec<f32>,        // [B * hidden]
    pub c0: Vec<f32>,        // [B * hidden]
}

/// Learner step output.
#[derive(Clone, Debug)]
pub struct TrainReply {
    pub loss: f32,
    pub priorities: Vec<f32>, // [B]
    pub grad_norm: f32,
    /// Learner step count after this update (= parameter version).
    pub step: u64,
}

/// The coordinator's model backend: the real XLA runtime (channel RPC to
/// the runtime thread) or the pure-Rust mock (tests / simulator-only
/// runs). Cloneable + Send.
#[derive(Clone)]
pub enum Backend {
    Xla(XlaHandle),
    Mock(Arc<MockModel>),
}

impl Backend {
    pub fn dims(&self) -> ModelDims {
        match self {
            Backend::Xla(h) => h.dims(),
            Backend::Mock(m) => m.dims(),
        }
    }

    /// Blocking batched inference.
    pub fn infer(&self, req: InferRequest) -> anyhow::Result<InferReply> {
        match self {
            Backend::Xla(h) => h.infer(req),
            Backend::Mock(m) => m.try_infer(&req),
        }
    }

    /// Blocking batched inference over borrowed row slices (the local
    /// chunked-inference path): zero-copy into the mock, one owned copy
    /// at the XLA channel boundary.
    pub fn infer_slices(&self, req: InferSlices<'_>) -> anyhow::Result<InferReply> {
        match self {
            Backend::Xla(h) => h.infer(InferRequest::from_slices(req)),
            Backend::Mock(m) => m.try_infer_slices(req),
        }
    }

    /// Blocking batched inference writing into a caller-owned reply (the
    /// central batcher's pooled path): the mock fills `out` in place,
    /// reusing whatever capacity it holds — steady state never enters
    /// the allocator. The XLA path genuinely needs owned buffers at the
    /// runtime-thread channel boundary, so there the reply replaces
    /// `out` wholesale (pooling degrades to plain allocation, exactly
    /// today's cost).
    pub fn infer_into(
        &self,
        req: InferSlices<'_>,
        out: &mut InferReply,
    ) -> anyhow::Result<()> {
        match self {
            Backend::Xla(h) => {
                *out = h.infer(InferRequest::from_slices(req))?;
                Ok(())
            }
            Backend::Mock(m) => m.try_infer_slices_into(req, out),
        }
    }

    /// Blocking learner step (updates parameters in place).
    pub fn train(&self, batch: TrainBatch) -> anyhow::Result<TrainReply> {
        match self {
            Backend::Xla(h) => h.train(batch),
            Backend::Mock(m) => m.try_train(&batch),
        }
    }

    /// Blocking learner step over a caller-owned batch the caller keeps.
    /// The mock trains by reference, so the buffers come back intact —
    /// the learner's batch pool reuses them for the next assembly. The
    /// XLA path genuinely needs an owned batch at the runtime-thread
    /// channel boundary, so there the buffers are taken and the caller's
    /// shell comes back empty (pooling degrades to plain allocation,
    /// exactly today's cost).
    pub fn train_step(&self, batch: &mut TrainBatch) -> anyhow::Result<TrainReply> {
        match self {
            Backend::Xla(h) => h.train(batch.take()),
            Backend::Mock(m) => m.try_train(batch),
        }
    }

    /// Copy online params -> target params.
    pub fn sync_target(&self) -> anyhow::Result<()> {
        match self {
            Backend::Xla(h) => h.sync_target(),
            Backend::Mock(m) => {
                m.sync_target();
                Ok(())
            }
        }
    }
}

impl InferRequest {
    /// Slice-based constructor: one `to_vec` per slab (the whole row
    /// range at once), not one per row.
    pub fn from_slices(s: InferSlices<'_>) -> Self {
        Self {
            n: s.n,
            h: s.h.to_vec(),
            c: s.c.to_vec(),
            obs: s.obs.to_vec(),
        }
    }

    pub fn validate(&self, dims: &ModelDims) -> anyhow::Result<()> {
        InferSlices {
            n: self.n,
            h: &self.h,
            c: &self.c,
            obs: &self.obs,
        }
        .validate(dims)
    }
}

impl InferSlices<'_> {
    pub fn validate(&self, dims: &ModelDims) -> anyhow::Result<()> {
        anyhow::ensure!(self.n > 0, "empty inference request");
        anyhow::ensure!(self.h.len() == self.n * dims.hidden, "h length");
        anyhow::ensure!(self.c.len() == self.n * dims.hidden, "c length");
        anyhow::ensure!(self.obs.len() == self.n * dims.obs_len, "obs length");
        Ok(())
    }
}

impl TrainBatch {
    /// An empty zero-batch shell, the unit of the learner's buffer pool
    /// (`assemble_into` fills it, reusing whatever capacity it holds).
    pub fn empty() -> TrainBatch {
        TrainBatch {
            batch: 0,
            obs: Vec::new(),
            actions: Vec::new(),
            rewards: Vec::new(),
            discounts: Vec::new(),
            h0: Vec::new(),
            c0: Vec::new(),
        }
    }

    /// Move the contents out, leaving an empty shell behind (the XLA
    /// train path needs an owned batch at the channel boundary).
    pub fn take(&mut self) -> TrainBatch {
        let taken = TrainBatch {
            batch: self.batch,
            obs: std::mem::take(&mut self.obs),
            actions: std::mem::take(&mut self.actions),
            rewards: std::mem::take(&mut self.rewards),
            discounts: std::mem::take(&mut self.discounts),
            h0: std::mem::take(&mut self.h0),
            c0: std::mem::take(&mut self.c0),
        };
        self.batch = 0;
        taken
    }

    pub fn validate(&self, dims: &ModelDims) -> anyhow::Result<()> {
        let bt = self.batch * dims.seq_len;
        anyhow::ensure!(self.batch == dims.train_batch, "batch size mismatch");
        anyhow::ensure!(self.obs.len() == bt * dims.obs_len, "obs length");
        anyhow::ensure!(self.actions.len() == bt, "actions length");
        anyhow::ensure!(self.rewards.len() == bt, "rewards length");
        anyhow::ensure!(self.discounts.len() == bt, "discounts length");
        anyhow::ensure!(self.h0.len() == self.batch * dims.hidden, "h0 length");
        anyhow::ensure!(self.c0.len() == self.batch * dims.hidden, "c0 length");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 5,
            train_batch: 2,
        }
    }

    #[test]
    fn infer_request_validation() {
        let d = dims();
        let ok = InferRequest {
            n: 2,
            h: vec![0.0; 8],
            c: vec![0.0; 8],
            obs: vec![0.0; 16],
        };
        ok.validate(&d).unwrap();
        let bad = InferRequest { n: 2, ..ok };
        let bad = InferRequest {
            obs: vec![0.0; 15],
            ..bad
        };
        assert!(bad.validate(&d).is_err());
    }

    #[test]
    fn train_batch_validation() {
        let d = dims();
        let ok = TrainBatch {
            batch: 2,
            obs: vec![0.0; 2 * 5 * 8],
            actions: vec![0; 10],
            rewards: vec![0.0; 10],
            discounts: vec![0.0; 10],
            h0: vec![0.0; 8],
            c0: vec![0.0; 8],
        };
        ok.validate(&d).unwrap();
        let bad = TrainBatch { batch: 1, ..ok };
        assert!(bad.validate(&d).is_err());
    }
}
