//! Host-side tensors: the typed boundary between Rust data and XLA
//! literals. Only the dtypes our artifacts use (f32, i32) are supported.

use xla::{ElementType, Literal, PrimitiveType};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn element_type(self) -> ElementType {
        match self {
            DType::F32 => ElementType::F32,
            DType::I32 => ElementType::S32,
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" | "s32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype `{other}`"),
        }
    }

    pub fn size(self) -> usize {
        4
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor (shape + typed data). The ABI unit fed to / read from
/// the PJRT executables.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn from_f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape,
            data: TensorData::F32(data),
        }
    }

    pub fn from_i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape,
            data: TensorData::I32(data),
        }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self::from_f32(shape, vec![0.0; n])
    }

    pub fn scalar_f32(x: f32) -> Self {
        Self::from_f32(vec![], vec![x])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            TensorData::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            TensorData::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    /// Convert to an XLA literal (host copy).
    pub fn to_literal(&self) -> anyhow::Result<Literal> {
        Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            &self.shape,
            self.raw_bytes(),
        )
        .map_err(|e| anyhow::anyhow!("literal create: {e}"))
    }

    /// Read an XLA literal back into a host tensor.
    pub fn from_literal(lit: &Literal) -> anyhow::Result<Self> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.primitive_type() {
            PrimitiveType::F32 => {
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("literal read f32: {e}"))?;
                Ok(Self::from_f32(dims, v))
            }
            PrimitiveType::S32 => {
                let v = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("literal read i32: {e}"))?;
                Ok(Self::from_i32(dims, v))
            }
            other => anyhow::bail!("unsupported literal type {other:?}"),
        }
    }

    /// Build from raw little-endian bytes (tensor-bundle payloads).
    pub fn from_le_bytes(dtype: DType, shape: Vec<usize>, bytes: &[u8]) -> anyhow::Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(
            bytes.len() == n * dtype.size(),
            "byte length {} != {} elements * 4",
            bytes.len(),
            n
        );
        match dtype {
            DType::F32 => {
                let v = bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(Self::from_f32(shape, v))
            }
            DType::I32 => {
                let v = bytes
                    .chunks_exact(4)
                    .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(Self::from_i32(shape, v))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_enforced() {
        let t = Tensor::from_f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        Tensor::from_f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn le_bytes_roundtrip() {
        let vals = [1.5f32, -2.25, 3.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let t = Tensor::from_le_bytes(DType::F32, vec![3], &bytes).unwrap();
        assert_eq!(t.as_f32(), &vals);
        let i = Tensor::from_le_bytes(DType::I32, vec![2], &[1, 0, 0, 0, 255, 255, 255, 255])
            .unwrap();
        assert_eq!(i.as_i32(), &[1, -1]);
    }

    #[test]
    fn dtype_names() {
        assert_eq!(DType::from_name("float32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("int32").unwrap(), DType::I32);
        assert!(DType::from_name("float64").is_err());
    }

    #[test]
    fn literal_roundtrip() {
        // Exercises the real XLA literal path (no artifacts needed).
        let t = Tensor::from_f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, t2);

        let ti = Tensor::from_i32(vec![3], vec![7, -8, 9]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(Tensor::from_literal(&lit).unwrap(), ti);
    }
}
