//! Pure-Rust mock model: lets the coordinator, examples, and the
//! discrete-event simulator run without compiled artifacts (and lets
//! tests exercise the full actor/batcher/learner dataflow quickly).
//!
//! The mock is a real (if tiny) function, not a stub: q-values are a
//! fixed random linear map of the observation plus a decaying recurrent
//! trace, so batching/padding bugs change its outputs and get caught by
//! the integration tests. `train` tracks a fake loss that decays with
//! step count and returns priorities derived from batch rewards.

use super::{InferReply, InferRequest, InferSlices, ModelDims, TrainBatch, TrainReply};
use crate::util::prng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Seeded inference-stall schedule (DESIGN.md §15): with probability
/// `rate` per call, sleep `stall` before computing — the mock-backend
/// seam for the fault plan's `stall_rate`. Deterministic draw per call
/// in call order; every fired stall is reported to the plan's ledger.
struct StallState {
    rng: Pcg32,
    rate: f64,
    stall: std::time::Duration,
    plan: Arc<crate::fault::FaultPlan>,
}

pub struct MockModel {
    dims: ModelDims,
    /// [obs_len * num_actions] fixed random projection.
    w_obs: Vec<f32>,
    /// [hidden] per-unit decay for the fake recurrence.
    decay: Vec<f32>,
    step: AtomicU64,
    target_syncs: AtomicU64,
    /// Optional per-call artificial latency (models GPU time in DES-free
    /// tests); protected by a mutex to keep MockModel: Sync.
    infer_latency: Mutex<std::time::Duration>,
    /// Optional per-train-step artificial latency (GPU train time for
    /// the learner-pipeline overlap tests).
    train_latency: Mutex<std::time::Duration>,
    /// Optional injected inference/train failures (failure-path tests).
    infer_error: Mutex<Option<String>>,
    train_error: Mutex<Option<String>>,
    /// Optional seeded inference stalls (armed by a fault plan; `None`
    /// is the bit-for-bit fault-free path).
    infer_stall: Mutex<Option<StallState>>,
}

impl MockModel {
    pub fn new(dims: ModelDims, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let w_obs = (0..dims.obs_len * dims.num_actions)
            .map(|_| (rng.next_f32() - 0.5) * 0.2)
            .collect();
        let decay = (0..dims.hidden).map(|_| 0.5 + 0.4 * rng.next_f32()).collect();
        Self {
            dims,
            w_obs,
            decay,
            step: AtomicU64::new(0),
            target_syncs: AtomicU64::new(0),
            infer_latency: Mutex::new(std::time::Duration::ZERO),
            train_latency: Mutex::new(std::time::Duration::ZERO),
            infer_error: Mutex::new(None),
            train_error: Mutex::new(None),
            infer_stall: Mutex::new(None),
        }
    }

    /// Default dims matching the AOT defaults (obs 10x10x4, A=4, H=128).
    pub fn default_dims() -> ModelDims {
        ModelDims {
            obs_len: 400,
            hidden: 128,
            num_actions: 4,
            seq_len: 20,
            train_batch: 16,
        }
    }

    pub fn with_infer_latency(self, d: std::time::Duration) -> Self {
        *self.infer_latency.lock().unwrap() = d;
        self
    }

    /// Add artificial GPU time to every train step (the learner-pipeline
    /// overlap tests inject latency here and measure the prefetch win).
    pub fn with_train_latency(self, d: std::time::Duration) -> Self {
        *self.train_latency.lock().unwrap() = d;
        self
    }

    /// Make every subsequent inference call fail with `msg` (exercises
    /// the batcher/actor failure-surfacing paths).
    pub fn with_infer_error(self, msg: &str) -> Self {
        *self.infer_error.lock().unwrap() = Some(msg.to_string());
        self
    }

    /// Make every subsequent train call fail with `msg` (exercises the
    /// learner failure path: the run must terminate, not hang).
    pub fn with_train_error(self, msg: &str) -> Self {
        *self.train_error.lock().unwrap() = Some(msg.to_string());
        self
    }

    /// Arm the seeded inference-stall seam from a fault plan
    /// (non-consuming: the model is usually already behind an `Arc`
    /// inside a [`super::Backend`] when the plan is wired in).
    pub fn set_infer_stall(&self, plan: &Arc<crate::fault::FaultPlan>) {
        *self.infer_stall.lock().unwrap() =
            plan.infer_stall().map(|(rate, stall, seed)| StallState {
                // A dedicated stream id keeps the stall schedule
                // independent of the transport's per-site streams.
                rng: Pcg32::new(seed, 0x57A11),
                rate,
                stall,
                plan: plan.clone(),
            });
    }

    /// Fast-forward the train-step counter (checkpoint resume: the
    /// restored learner continues the loss/priority schedule from
    /// where the snapshot left it).
    pub fn set_steps(&self, steps: u64) {
        self.step.store(steps, Ordering::Relaxed);
    }

    /// The mock's learned state as tensors (checkpointing): the fixed
    /// projection and the recurrence decay, in a stable order.
    pub fn params(&self) -> Vec<crate::runtime::Tensor> {
        vec![
            crate::runtime::Tensor::from_f32(
                vec![self.dims.obs_len, self.dims.num_actions],
                self.w_obs.clone(),
            ),
            crate::runtime::Tensor::from_f32(vec![self.dims.hidden], self.decay.clone()),
        ]
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    pub fn steps(&self) -> u64 {
        self.step.load(Ordering::Relaxed)
    }

    pub fn target_syncs(&self) -> u64 {
        self.target_syncs.load(Ordering::Relaxed)
    }

    pub fn infer(&self, req: &InferRequest) -> InferReply {
        self.infer_slices(InferSlices {
            n: req.n,
            h: &req.h,
            c: &req.c,
            obs: &req.obs,
        })
    }

    /// Fallible wrapper: fails when an error was injected via
    /// [`MockModel::with_infer_error`], otherwise runs the real mock.
    pub fn try_infer(&self, req: &InferRequest) -> anyhow::Result<InferReply> {
        self.try_infer_slices(InferSlices {
            n: req.n,
            h: &req.h,
            c: &req.c,
            obs: &req.obs,
        })
    }

    pub fn try_infer_slices(&self, req: InferSlices<'_>) -> anyhow::Result<InferReply> {
        if let Some(msg) = self.infer_error.lock().unwrap().as_ref() {
            return Err(anyhow::anyhow!("{msg}"));
        }
        Ok(self.infer_slices(req))
    }

    /// Fallible in-place wrapper over [`MockModel::infer_slices_into`]
    /// (the batcher's pooled reply path).
    pub fn try_infer_slices_into(
        &self,
        req: InferSlices<'_>,
        out: &mut InferReply,
    ) -> anyhow::Result<()> {
        if let Some(msg) = self.infer_error.lock().unwrap().as_ref() {
            return Err(anyhow::anyhow!("{msg}"));
        }
        self.infer_slices_into(req, out);
        Ok(())
    }

    /// The mock forward pass over borrowed row slices (zero-copy).
    pub fn infer_slices(&self, req: InferSlices<'_>) -> InferReply {
        let mut out = InferReply {
            q: Vec::new(),
            h: Vec::new(),
            c: Vec::new(),
        };
        self.infer_slices_into(req, &mut out);
        out
    }

    /// The mock forward pass writing into a caller-owned reply: the
    /// output vectors are cleared and refilled in place, reusing their
    /// capacity, so a recycled reply slab makes the call allocation-free
    /// in steady state (the property `micro_batcher --quick` gates on).
    pub fn infer_slices_into(&self, req: InferSlices<'_>, out: &mut InferReply) {
        let d = &self.dims;
        req.validate(d).expect("mock infer request shape");
        let lat = *self.infer_latency.lock().unwrap();
        if !lat.is_zero() {
            std::thread::sleep(lat);
        }
        if let Some(st) = self.infer_stall.lock().unwrap().as_mut() {
            if st.rng.chance(st.rate) {
                st.plan.note_stall();
                std::thread::sleep(st.stall);
            }
        }
        out.q.clear();
        out.q.resize(req.n * d.num_actions, 0.0);
        out.h.clear();
        out.h.resize(req.n * d.hidden, 0.0);
        out.c.clear();
        out.c.resize(req.n * d.hidden, 0.0);
        for i in 0..req.n {
            let obs = &req.obs[i * d.obs_len..(i + 1) * d.obs_len];
            let h_in = &req.h[i * d.hidden..(i + 1) * d.hidden];
            let c_in = &req.c[i * d.hidden..(i + 1) * d.hidden];
            for a in 0..d.num_actions {
                let mut acc = 0.0f32;
                for (j, &o) in obs.iter().enumerate() {
                    acc += o * self.w_obs[j * d.num_actions + a];
                }
                // Recurrent contribution keeps state relevant.
                acc += h_in.iter().take(4).sum::<f32>() * 0.01 * (a as f32 + 1.0);
                out.q[i * d.num_actions + a] = acc;
            }
            let obs_mean = obs.iter().sum::<f32>() / obs.len().max(1) as f32;
            for k in 0..d.hidden {
                let idx = i * d.hidden + k;
                out.c[idx] = self.decay[k] * c_in[k] + 0.1 * obs_mean;
                out.h[idx] = out.c[idx].tanh();
            }
        }
    }

    /// Fallible wrapper: fails when an error was injected via
    /// [`MockModel::with_train_error`], otherwise runs the real mock.
    pub fn try_train(&self, batch: &TrainBatch) -> anyhow::Result<TrainReply> {
        if let Some(msg) = self.train_error.lock().unwrap().as_ref() {
            return Err(anyhow::anyhow!("{msg}"));
        }
        Ok(self.train(batch))
    }

    pub fn train(&self, batch: &TrainBatch) -> TrainReply {
        self.dims();
        batch.validate(&self.dims).expect("mock train batch shape");
        let lat = *self.train_latency.lock().unwrap();
        if !lat.is_zero() {
            std::thread::sleep(lat);
        }
        let step = self.step.fetch_add(1, Ordering::Relaxed) + 1;
        let t = self.dims.seq_len;
        // Priorities: |mean reward| per sequence + small floor.
        let priorities: Vec<f32> = (0..batch.batch)
            .map(|b| {
                let r: f32 = batch.rewards[b * t..(b + 1) * t].iter().sum();
                (r.abs() / t as f32) + 0.01
            })
            .collect();
        TrainReply {
            loss: 1.0 / (1.0 + step as f32 * 0.05),
            priorities,
            grad_norm: 1.0,
            step,
        }
    }

    pub fn sync_target(&self) {
        self.target_syncs.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        }
    }

    fn req(n: usize, d: &ModelDims, fill: f32) -> InferRequest {
        InferRequest {
            n,
            h: vec![0.0; n * d.hidden],
            c: vec![0.0; n * d.hidden],
            obs: vec![fill; n * d.obs_len],
        }
    }

    #[test]
    fn infer_is_deterministic_and_batch_consistent() {
        let d = dims();
        let m = MockModel::new(d, 42);
        let single = m.infer(&req(1, &d, 0.5));
        let batch = m.infer(&req(3, &d, 0.5));
        // Same obs => same q regardless of batch position.
        for i in 0..3 {
            for a in 0..d.num_actions {
                assert_eq!(batch.q[i * d.num_actions + a], single.q[a]);
            }
        }
    }

    #[test]
    fn different_obs_different_q() {
        let d = dims();
        let m = MockModel::new(d, 42);
        let a = m.infer(&req(1, &d, 0.1));
        let b = m.infer(&req(1, &d, 0.9));
        assert_ne!(a.q, b.q);
    }

    #[test]
    fn recurrent_state_evolves() {
        let d = dims();
        let m = MockModel::new(d, 7);
        let r1 = m.infer(&req(1, &d, 0.5));
        let mut r2req = req(1, &d, 0.5);
        r2req.h = r1.h.clone();
        r2req.c = r1.c.clone();
        let r2 = m.infer(&r2req);
        assert_ne!(r1.c, r2.c);
    }

    #[test]
    fn slice_view_matches_owned_request() {
        let d = dims();
        let m = MockModel::new(d, 42);
        let owned = req(2, &d, 0.3);
        let a = m.infer(&owned);
        let b = m.infer_slices(InferSlices {
            n: 2,
            h: &owned.h,
            c: &owned.c,
            obs: &owned.obs,
        });
        assert_eq!(a.q, b.q);
        assert_eq!(a.h, b.h);
        assert_eq!(a.c, b.c);
    }

    #[test]
    fn infer_into_matches_owned_reply_and_reuses_capacity() {
        let d = dims();
        let m = MockModel::new(d, 42);
        let owned = req(3, &d, 0.4);
        let a = m.infer(&owned);
        let mut out = InferReply {
            q: Vec::new(),
            h: Vec::new(),
            c: Vec::new(),
        };
        let slices = InferSlices {
            n: 3,
            h: &owned.h,
            c: &owned.c,
            obs: &owned.obs,
        };
        m.infer_slices_into(slices, &mut out);
        assert_eq!(a.q, out.q);
        assert_eq!(a.h, out.h);
        assert_eq!(a.c, out.c);
        // Steady state: a second fill of the same shape must reuse the
        // buffers in place (no reallocation — pointer-stable).
        let (pq, ph, pc) = (out.q.as_ptr(), out.h.as_ptr(), out.c.as_ptr());
        m.infer_slices_into(slices, &mut out);
        assert_eq!(a.q, out.q);
        assert!(
            pq == out.q.as_ptr() && ph == out.h.as_ptr() && pc == out.c.as_ptr(),
            "in-place refill must not reallocate"
        );
    }

    #[test]
    fn injected_error_fails_try_infer() {
        let d = dims();
        let m = MockModel::new(d, 42).with_infer_error("boom");
        let err = m.try_infer(&req(1, &d, 0.3)).unwrap_err().to_string();
        assert!(err.contains("boom"));
    }

    #[test]
    fn train_loss_decays_and_counts_steps() {
        let d = dims();
        let m = MockModel::new(d, 1);
        let batch = TrainBatch {
            batch: 2,
            obs: vec![0.0; 2 * 4 * 8],
            actions: vec![0; 8],
            rewards: vec![1.0; 8],
            discounts: vec![0.9; 8],
            h0: vec![0.0; 8],
            c0: vec![0.0; 8],
        };
        let r1 = m.train(&batch);
        let r2 = m.train(&batch);
        assert!(r2.loss < r1.loss);
        assert_eq!(r2.step, 2);
        assert_eq!(r1.priorities.len(), 2);
        assert!(r1.priorities.iter().all(|&p| p > 0.0));
        m.sync_target();
        assert_eq!(m.target_syncs(), 1);
    }
}
