//! Reader for the tensor-bundle container `aot.py` writes
//! (`init_params.bin`): magic, u64-LE header length, JSON header
//! [{name, shape, dtype, offset, nbytes}], raw little-endian payload.

use super::tensor::{DType, Tensor};
use crate::util::json::Value;
use std::path::Path;

pub const MAGIC: &[u8; 16] = b"RLTENSORBUNDLE1\n";

pub struct Bundle {
    pub tensors: Vec<(String, Tensor)>,
}

impl Bundle {
    pub fn read(path: &Path) -> anyhow::Result<Self> {
        let raw = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e}"))?;
        Self::parse(&raw)
    }

    pub fn parse(raw: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(raw.len() > 24, "bundle too short");
        anyhow::ensure!(&raw[..16] == MAGIC, "bad bundle magic");
        let hlen = u64::from_le_bytes(raw[16..24].try_into().unwrap()) as usize;
        anyhow::ensure!(raw.len() >= 24 + hlen, "truncated bundle header");
        let header = std::str::from_utf8(&raw[24..24 + hlen])?;
        let header = Value::parse(header)
            .map_err(|e| anyhow::anyhow!("bundle header json: {e}"))?;
        let payload = &raw[24 + hlen..];

        let mut tensors = Vec::new();
        for entry in header.as_arr().unwrap_or(&[]) {
            let name = entry
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("bundle entry missing name"))?
                .to_string();
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|xs| xs.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            let dtype = DType::from_name(
                entry.get("dtype").and_then(|v| v.as_str()).unwrap_or(""),
            )?;
            let offset = entry
                .get("offset")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("missing offset"))?;
            let nbytes = entry
                .get("nbytes")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("missing nbytes"))?;
            anyhow::ensure!(
                offset + nbytes <= payload.len(),
                "tensor {name} outside payload"
            );
            tensors.push((
                name,
                Tensor::from_le_bytes(dtype, shape, &payload[offset..offset + nbytes])?,
            ));
        }
        Ok(Self { tensors })
    }

    /// Tensors whose name starts with `prefix`, in bundle order, with the
    /// prefix requirement that the remainder is numeric (so "p" does not
    /// match "vp0" but matches "p0".."p13").
    pub fn with_prefix(&self, prefix: &str) -> Vec<Tensor> {
        self.tensors
            .iter()
            .filter(|(n, _)| {
                n.strip_prefix(prefix)
                    .map(|rest| !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()))
                    .unwrap_or(false)
            })
            .map(|(_, t)| t.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{obj, Value};

    fn make_bundle(entries: &[(&str, &[f32])]) -> Vec<u8> {
        let mut payload = Vec::new();
        let mut header = Vec::new();
        for (name, data) in entries {
            let offset = payload.len();
            for v in *data {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            header.push(obj(&[
                ("name", Value::from(*name)),
                ("shape", Value::from(vec![data.len()])),
                ("dtype", Value::from("float32")),
                ("offset", Value::from(offset)),
                ("nbytes", Value::from(data.len() * 4)),
            ]));
        }
        let hjson = Value::Arr(header).to_string().into_bytes();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(hjson.len() as u64).to_le_bytes());
        out.extend_from_slice(&hjson);
        out.extend_from_slice(&payload);
        out
    }

    #[test]
    fn parse_roundtrip() {
        let raw = make_bundle(&[("p0", &[1.0, 2.0]), ("p1", &[3.0])]);
        let b = Bundle::parse(&raw).unwrap();
        assert_eq!(b.tensors.len(), 2);
        assert_eq!(b.tensors[0].0, "p0");
        assert_eq!(b.tensors[0].1.as_f32(), &[1.0, 2.0]);
        assert_eq!(b.tensors[1].1.as_f32(), &[3.0]);
    }

    #[test]
    fn prefix_filter_is_exact() {
        let raw = make_bundle(&[("p0", &[1.0]), ("p1", &[2.0]), ("vp0", &[9.0]), ("o0", &[4.0])]);
        let b = Bundle::parse(&raw).unwrap();
        let ps = b.with_prefix("p");
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[1].as_f32(), &[2.0]);
        assert_eq!(b.with_prefix("vp").len(), 1);
        assert_eq!(b.with_prefix("o").len(), 1);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(Bundle::parse(b"nope").is_err());
        let mut raw = make_bundle(&[("p0", &[1.0])]);
        raw[0] = b'X';
        assert!(Bundle::parse(&raw).is_err());
        let raw = make_bundle(&[("p0", &[1.0])]);
        assert!(Bundle::parse(&raw[..raw.len() - 2]).is_err());
    }
}
