//! Checkpointing: write/read parameter snapshots in the same
//! tensor-bundle container `aot.py` emits (`bundle.rs` reads), so
//! checkpoints interop with the Python tooling.

use super::bundle::{Bundle, MAGIC};
use super::tensor::{Tensor, TensorData};
use crate::util::json::{obj, Value};
use std::io::Write;
use std::path::Path;

/// Serialize named tensors into the tensor-bundle format.
pub fn to_bundle_bytes(named: &[(String, &Tensor)]) -> Vec<u8> {
    let mut payload: Vec<u8> = Vec::new();
    let mut header = Vec::new();
    for (name, t) in named {
        let offset = payload.len();
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        let dtype = match t.data {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
        };
        header.push(obj(&[
            ("name", Value::from(name.as_str())),
            (
                "shape",
                Value::Arr(t.shape.iter().map(|&d| Value::from(d)).collect()),
            ),
            ("dtype", Value::from(dtype)),
            ("offset", Value::from(offset)),
            ("nbytes", Value::from(payload.len() - offset)),
        ]));
    }
    let hjson = Value::Arr(header).to_string().into_bytes();
    let mut out = Vec::with_capacity(24 + hjson.len() + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(hjson.len() as u64).to_le_bytes());
    out.extend_from_slice(&hjson);
    out.extend_from_slice(&payload);
    out
}

/// Write a checkpoint of `params` (named `p0..pN` like init_params.bin).
pub fn save_params(path: &Path, params: &[Tensor]) -> anyhow::Result<()> {
    let named: Vec<(String, &Tensor)> = params
        .iter()
        .enumerate()
        .map(|(i, t)| (format!("p{i}"), t))
        .collect();
    let bytes = to_bundle_bytes(&named);
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("create {path:?}: {e}"))?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Load a checkpoint written by `save_params` (or aot.py's initializer).
pub fn load_params(path: &Path) -> anyhow::Result<Vec<Tensor>> {
    let bundle = Bundle::read(path)?;
    let params = bundle.with_prefix("p");
    anyhow::ensure!(!params.is_empty(), "no `p*` tensors in {path:?}");
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("rlarch_ckpt_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("ckpt.bin");
        let params = vec![
            Tensor::from_f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 7.25, -8.0]),
            Tensor::from_f32(vec![4], vec![0.1, 0.2, 0.3, 0.4]),
        ];
        save_params(&path, &params).unwrap();
        let loaded = load_params(&path).unwrap();
        assert_eq!(loaded, params);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bundle_bytes_parse_with_reader() {
        let t = Tensor::from_i32(vec![3], vec![7, -1, 2]);
        let bytes = to_bundle_bytes(&[("x".into(), &t)]);
        let b = Bundle::parse(&bytes).unwrap();
        assert_eq!(b.tensors.len(), 1);
        assert_eq!(b.tensors[0].0, "x");
        assert_eq!(b.tensors[0].1.as_i32(), &[7, -1, 2]);
    }

    #[test]
    fn load_rejects_bundles_without_params() {
        let t = Tensor::from_f32(vec![1], vec![0.5]);
        let bytes = to_bundle_bytes(&[("weird".into(), &t)]);
        let dir = std::env::temp_dir().join("rlarch_ckpt_test2");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("bad.bin");
        std::fs::write(&path, bytes).unwrap();
        assert!(load_params(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
