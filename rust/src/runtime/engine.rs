//! The PJRT engine: compiles HLO-text artifacts and executes them.
//!
//! Owns the parameter state (online params, target params, Adam state) as
//! XLA literals; the train step's output literals become the next step's
//! input literals directly, so parameters never round-trip through Rust
//! buffers on the hot path (they only do so on `sync_target`, every
//! `target_update_interval` steps).
//!
//! Not `Send` (the xla crate wraps raw PJRT pointers) — see
//! `server::XlaServer` for the thread that owns one of these.

use super::manifest::Manifest;
use super::tensor::Tensor;
use super::{InferReply, InferRequest, ModelDims, TrainBatch, TrainReply};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub struct XlaRuntime {
    pub manifest: Manifest,
    artifact_dir: PathBuf,
    client: xla::PjRtClient,
    infer_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    train_exe: Option<xla::PjRtLoadedExecutable>,
    /// Lazily-compiled artifacts outside the R2D2 ABI (execute_raw).
    raw_exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    params: Vec<xla::Literal>,
    target: Vec<xla::Literal>,
    opt: Vec<xla::Literal>,
    n_params: usize,
    n_opt: usize,
    step: u64,
    dims: ModelDims,
}

fn clone_literal(l: &xla::Literal) -> anyhow::Result<xla::Literal> {
    // The crate exposes no Literal::clone; round-trip through host bytes.
    Tensor::from_literal(l)?.to_literal()
}

impl XlaRuntime {
    /// Load manifest + initial parameters + compile artifacts from `dir`.
    ///
    /// `infer_batches`: which infer_b{N} artifacts to compile (None = all).
    /// `with_train`: compile the train step (examples that only serve can
    /// skip it to save startup time).
    pub fn load(
        dir: &Path,
        infer_batches: Option<&[usize]>,
        with_train: bool,
    ) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;

        let compile = |name: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let sig = manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))?;
            let path: PathBuf = dir.join(&sig.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))
        };

        let mut infer_exes = BTreeMap::new();
        let available = manifest.infer_batch_sizes();
        let wanted: Vec<usize> = match infer_batches {
            Some(bs) => bs.to_vec(),
            None => available.clone(),
        };
        for b in wanted {
            anyhow::ensure!(
                available.contains(&b),
                "no infer_b{b} artifact (available: {available:?})"
            );
            infer_exes.insert(b, compile(&format!("infer_b{b}"))?);
        }
        let train_exe = if with_train {
            Some(compile("train")?)
        } else {
            None
        };

        // Initial parameter/optimizer literals.
        let bundle = super::Bundle::read(&dir.join("init_params.bin"))?;
        let p_tensors = bundle.with_prefix("p");
        let o_tensors = bundle.with_prefix("o");
        anyhow::ensure!(
            p_tensors.len() == manifest.param_specs.len(),
            "bundle params ({}) != manifest specs ({})",
            p_tensors.len(),
            manifest.param_specs.len()
        );
        let to_lits = |ts: &[Tensor]| -> anyhow::Result<Vec<xla::Literal>> {
            ts.iter().map(|t| t.to_literal()).collect()
        };
        let params = to_lits(&p_tensors)?;
        let target = to_lits(&p_tensors)?;
        let opt = to_lits(&o_tensors)?;
        let n_params = params.len();
        let n_opt = opt.len();

        let dims = ModelDims {
            obs_len: manifest.obs_len(),
            hidden: manifest.lstm_hidden,
            num_actions: manifest.num_actions,
            seq_len: manifest.seq_len,
            train_batch: manifest.train_batch,
        };
        Ok(Self {
            manifest,
            artifact_dir: dir.to_path_buf(),
            client,
            infer_exes,
            train_exe,
            raw_exes: BTreeMap::new(),
            params,
            target,
            opt,
            n_params,
            n_opt,
            step: 0,
            dims,
        })
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest compiled batch size >= n (or the largest available).
    pub fn pick_batch(&self, n: usize) -> usize {
        for &b in self.infer_exes.keys() {
            if b >= n {
                return b;
            }
        }
        *self.infer_exes.keys().last().expect("no infer artifacts")
    }

    /// Batched inference; requests are zero-padded to a compiled size.
    pub fn infer(&self, req: &InferRequest) -> anyhow::Result<InferReply> {
        req.validate(&self.dims)?;
        let d = &self.dims;
        let b = self.pick_batch(req.n);
        anyhow::ensure!(
            req.n <= b,
            "request of {} exceeds largest compiled batch {b}",
            req.n
        );
        let exe = &self.infer_exes[&b];

        let pad = |src: &[f32], row: usize| -> Vec<f32> {
            let mut v = vec![0.0f32; b * row];
            v[..src.len()].copy_from_slice(src);
            v
        };
        let obs_dims = vec![
            b,
            self.manifest.obs_size,
            self.manifest.obs_size,
            self.manifest.obs_channels,
        ];
        let h = Tensor::from_f32(vec![b, d.hidden], pad(&req.h, d.hidden));
        let c = Tensor::from_f32(vec![b, d.hidden], pad(&req.c, d.hidden));
        let obs = Tensor::from_f32(obs_dims, pad(&req.obs, d.obs_len));

        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        let (hl, cl, ol) = (h.to_literal()?, c.to_literal()?, obs.to_literal()?);
        inputs.push(&hl);
        inputs.push(&cl);
        inputs.push(&ol);

        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow::anyhow!("infer execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("infer readback: {e}"))?;
        let mut parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("infer tuple: {e}"))?;
        anyhow::ensure!(parts.len() == 3, "infer outputs: {}", parts.len());
        let c_out = Tensor::from_literal(&parts.pop().unwrap())?;
        let h_out = Tensor::from_literal(&parts.pop().unwrap())?;
        let q_out = Tensor::from_literal(&parts.pop().unwrap())?;

        Ok(InferReply {
            q: q_out.as_f32()[..req.n * d.num_actions].to_vec(),
            h: h_out.as_f32()[..req.n * d.hidden].to_vec(),
            c: c_out.as_f32()[..req.n * d.hidden].to_vec(),
        })
    }

    /// One learner step: runs the AOT train graph, adopts the returned
    /// parameter/optimizer literals as current state.
    pub fn train(&mut self, batch: &TrainBatch) -> anyhow::Result<TrainReply> {
        batch.validate(&self.dims)?;
        let exe = self
            .train_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("runtime loaded without train artifact"))?;
        let d = &self.dims;
        let (b, t) = (batch.batch, d.seq_len);
        let s = self.manifest.obs_size;
        let ch = self.manifest.obs_channels;

        let obs = Tensor::from_f32(vec![b, t, s, s, ch], batch.obs.clone());
        let actions = Tensor::from_i32(vec![b, t], batch.actions.clone());
        let rewards = Tensor::from_f32(vec![b, t], batch.rewards.clone());
        let discounts = Tensor::from_f32(vec![b, t], batch.discounts.clone());
        let h0 = Tensor::from_f32(vec![b, d.hidden], batch.h0.clone());
        let c0 = Tensor::from_f32(vec![b, d.hidden], batch.c0.clone());

        let data_lits = [
            obs.to_literal()?,
            actions.to_literal()?,
            rewards.to_literal()?,
            discounts.to_literal()?,
            h0.to_literal()?,
            c0.to_literal()?,
        ];
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(
            2 * self.n_params + self.n_opt + data_lits.len(),
        );
        inputs.extend(self.params.iter());
        inputs.extend(self.target.iter());
        inputs.extend(self.opt.iter());
        inputs.extend(data_lits.iter());

        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow::anyhow!("train execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("train readback: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("train tuple: {e}"))?;
        let expect = self.n_params + self.n_opt + 3;
        anyhow::ensure!(
            parts.len() == expect,
            "train outputs {} != {expect}",
            parts.len()
        );

        let mut parts = parts.into_iter();
        let new_params: Vec<xla::Literal> =
            parts.by_ref().take(self.n_params).collect();
        let new_opt: Vec<xla::Literal> = parts.by_ref().take(self.n_opt).collect();
        let loss = Tensor::from_literal(&parts.next().unwrap())?.as_f32()[0];
        let priorities = Tensor::from_literal(&parts.next().unwrap())?
            .as_f32()
            .to_vec();
        let grad_norm = Tensor::from_literal(&parts.next().unwrap())?.as_f32()[0];

        self.params = new_params;
        self.opt = new_opt;
        self.step += 1;
        Ok(TrainReply {
            loss,
            priorities,
            grad_norm,
            step: self.step,
        })
    }

    /// Copy online params into the target network.
    pub fn sync_target(&mut self) -> anyhow::Result<()> {
        self.target = self
            .params
            .iter()
            .map(clone_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(())
    }

    /// Snapshot the online parameters to host tensors (checkpointing).
    pub fn params_to_host(&self) -> anyhow::Result<Vec<Tensor>> {
        self.params.iter().map(Tensor::from_literal).collect()
    }

    /// Restore online parameters from host tensors (checkpoint load).
    /// Shapes must match the manifest's param specs.
    pub fn params_from_host(&mut self, tensors: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            tensors.len() == self.n_params,
            "checkpoint has {} params, model needs {}",
            tensors.len(),
            self.n_params
        );
        for (t, spec) in tensors.iter().zip(&self.manifest.param_specs) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "param `{}`: checkpoint shape {:?} != {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
        }
        self.params = tensors
            .iter()
            .map(Tensor::to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(())
    }

    /// Compile and execute an arbitrary artifact by manifest name with an
    /// explicit flat tensor list — the extensibility path for artifacts
    /// outside the R2D2 ABI (e.g. the V-trace baseline learner).
    /// Compiles on first use; callers own the full input ABI.
    pub fn execute_raw(
        &mut self,
        name: &str,
        inputs: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        if !self.raw_exes.contains_key(name) {
            let sig = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))?;
            let path: PathBuf = self.artifact_dir.join(&sig.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
            self.raw_exes.insert(name.to_string(), exe);
        }
        let sig = &self.manifest.artifacts[name];
        anyhow::ensure!(
            inputs.len() == sig.inputs.len(),
            "artifact `{name}` wants {} inputs, got {}",
            sig.inputs.len(),
            inputs.len()
        );
        let lits = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let exe = &self.raw_exes[name];
        let result = exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| anyhow::anyhow!("{name} execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name} readback: {e}"))?;
        out.to_tuple()
            .map_err(|e| anyhow::anyhow!("{name} tuple: {e}"))?
            .iter()
            .map(Tensor::from_literal)
            .collect()
    }
}
