//! Parsed view of `artifacts/manifest.json` (the parameter ABI and
//! artifact signatures `aot.py` records at lowering time).

use crate::util::json::Value;
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub path: String,
    /// Input shapes in ABI order.
    pub inputs: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub obs_size: usize,
    pub obs_channels: usize,
    pub num_actions: usize,
    pub lstm_hidden: usize,
    pub param_count: usize,
    pub burn_in: usize,
    pub unroll_len: usize,
    pub seq_len: usize,
    pub n_step: usize,
    pub gamma: f64,
    pub train_batch: usize,
    pub param_specs: Vec<ParamSpec>,
    pub vtrace_param_specs: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("read manifest: {e} (run `make artifacts`)"))?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        Self::from_value(&v)
    }

    pub fn from_value(v: &Value) -> anyhow::Result<Self> {
        let u = |p: &str| -> anyhow::Result<usize> {
            v.path(p)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow::anyhow!("manifest missing `{p}`"))
        };
        let parse_specs = |key: &str| -> Vec<ParamSpec> {
            v.get(key)
                .and_then(|x| x.as_arr())
                .map(|xs| {
                    xs.iter()
                        .map(|s| ParamSpec {
                            name: s
                                .get("name")
                                .and_then(|n| n.as_str())
                                .unwrap_or("")
                                .to_string(),
                            shape: s
                                .get("shape")
                                .and_then(|sh| sh.as_arr())
                                .map(|d| d.iter().filter_map(|x| x.as_usize()).collect())
                                .unwrap_or_default(),
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let mut artifacts = BTreeMap::new();
        if let Some(arts) = v.get("artifacts").and_then(|x| x.as_obj()) {
            for (name, meta) in arts {
                let inputs = meta
                    .get("inputs")
                    .and_then(|x| x.as_arr())
                    .map(|xs| {
                        xs.iter()
                            .map(|i| {
                                i.get("shape")
                                    .and_then(|sh| sh.as_arr())
                                    .map(|d| {
                                        d.iter().filter_map(|x| x.as_usize()).collect()
                                    })
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                artifacts.insert(
                    name.clone(),
                    ArtifactSig {
                        path: meta
                            .get("path")
                            .and_then(|p| p.as_str())
                            .unwrap_or("")
                            .to_string(),
                        inputs,
                    },
                );
            }
        }
        Ok(Self {
            obs_size: u("agent.obs_size")?,
            obs_channels: u("agent.obs_channels")?,
            num_actions: u("agent.num_actions")?,
            lstm_hidden: u("agent.lstm_hidden")?,
            param_count: u("agent.param_count")?,
            burn_in: u("r2d2.burn_in")?,
            unroll_len: u("r2d2.unroll_len")?,
            seq_len: u("r2d2.seq_len")?,
            n_step: u("r2d2.n_step")?,
            gamma: v
                .path("r2d2.gamma")
                .and_then(|x| x.as_f64())
                .unwrap_or(0.997),
            train_batch: u("r2d2.train_batch")?,
            param_specs: parse_specs("param_specs"),
            vtrace_param_specs: parse_specs("vtrace_param_specs"),
            artifacts,
        })
    }

    /// Observation vector length the agent consumes.
    pub fn obs_len(&self) -> usize {
        self.obs_size * self.obs_size * self.obs_channels
    }

    /// Inference batch sizes available in the artifact set, ascending.
    pub fn infer_batch_sizes(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("infer_b").and_then(|b| b.parse().ok()))
            .collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "agent": {"obs_size": 10, "obs_channels": 4, "num_actions": 4,
                  "lstm_hidden": 128, "torso_dim": 128, "param_count": 247925},
        "r2d2": {"burn_in": 5, "unroll_len": 15, "seq_len": 20, "n_step": 3,
                 "gamma": 0.997, "train_batch": 16, "lr": 0.001},
        "param_specs": [{"name": "advantage.b", "shape": [4], "dtype": "float32"}],
        "vtrace_param_specs": [],
        "artifacts": {
            "infer_b1": {"path": "infer_b1.hlo.txt",
                          "inputs": [{"index": 0, "shape": [4], "dtype": "float32"}]},
            "infer_b32": {"path": "infer_b32.hlo.txt", "inputs": []},
            "train": {"path": "train.hlo.txt", "inputs": []}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let v = Value::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(&v).unwrap();
        assert_eq!(m.obs_len(), 400);
        assert_eq!(m.seq_len, 20);
        assert_eq!(m.param_specs.len(), 1);
        assert_eq!(m.param_specs[0].shape, vec![4]);
        assert_eq!(m.infer_batch_sizes(), vec![1, 32]);
        assert_eq!(m.artifacts["train"].path, "train.hlo.txt");
    }

    #[test]
    fn missing_field_errors() {
        let v = Value::parse(r#"{"agent": {}}"#).unwrap();
        assert!(Manifest::from_value(&v).is_err());
    }
}
