//! Trace-driven GPU timing model with component idealization — the
//! NVArchSim-methodology substrate behind Fig. 2 and Fig. 4.
//!
//! Each kernel's duration is modeled from first principles on the V100
//! machine model (`config::GpuModelConfig`):
//!
//! ```text
//! t_kernel = t_launch + max(t_math / occupancy, t_dram_bw, t_l2_bw) + t_latency
//!   t_math    = flops / peak_flops
//!   occupancy = threads / (waves * num_sms * threads_per_sm)   (tail effect)
//!   t_dram_bw = bytes * miss_rate / dram_bw
//!   t_l2_bw   = bytes / l2_bw
//!   t_latency = waves * chain_depth * dram_latency              (exposure)
//! ```
//!
//! The paper's experimental procedure is reproduced exactly by
//! [`GpuModel::breakdown`]: idealize components one at a time from the
//! outermost (DRAM bandwidth) to the innermost (SM occupancy), attributing
//! the time recovered at each rung to that component; what remains is
//! Math (actual compute). Absolute times are model estimates; the
//! *shares* are what Fig. 2 reports.

use super::trace::{KernelDesc, Trace};
use crate::config::GpuModelConfig;

/// Which components are idealized (the ladder knobs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Idealize {
    pub dram_bw: bool,
    pub dram_latency: bool,
    pub l2: bool,
    /// Perfect SM occupancy + free kernel launch.
    pub sm_util: bool,
}

impl Idealize {
    pub const NONE: Idealize = Idealize {
        dram_bw: false,
        dram_latency: false,
        l2: false,
        sm_util: false,
    };

    pub const ALL: Idealize = Idealize {
        dram_bw: true,
        dram_latency: true,
        l2: true,
        sm_util: true,
    };
}

/// Model tuning constants (calibrated once against the paper's Fig. 2
/// shares; see `rust/tests/simarch_calibration.rs`).
#[derive(Clone, Debug)]
pub struct GpuTuning {
    /// L2 reuse factor for compute kernels (dot/conv): weight panels stay
    /// resident across the recurrent unroll, so hit rates are high.
    pub l2_reuse_compute: f64,
    /// L2 reuse factor for data-movement / elementwise kernels
    /// (streaming traffic, little temporal locality).
    pub l2_reuse_other: f64,
    /// Dependent DRAM-access chain depth per wave (latency exposure).
    pub latency_chain: f64,
}

impl Default for GpuTuning {
    fn default() -> Self {
        Self {
            l2_reuse_compute: 0.85,
            l2_reuse_other: 0.4,
            latency_chain: 2.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GpuModel {
    pub cfg: GpuModelConfig,
    pub tuning: GpuTuning,
}

/// Per-component time shares of a trace (Fig. 2's bar segments).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub total_seconds: f64,
    /// Shares in [0,1], summing to ~1.0.
    pub math: f64,
    pub sm_util: f64,
    pub dram_bw: f64,
    pub dram_latency: f64,
    pub l2: f64,
}

impl GpuModel {
    pub fn new(cfg: GpuModelConfig) -> Self {
        Self {
            cfg,
            tuning: GpuTuning::default(),
        }
    }

    /// Same model with a different SM count (Fig. 4's knob).
    pub fn with_sms(&self, num_sms: usize) -> Self {
        let mut m = self.clone();
        m.cfg.num_sms = num_sms.max(1);
        m
    }

    /// Time for one kernel under the given idealization, in seconds.
    pub fn kernel_time(&self, k: &KernelDesc, ideal: Idealize) -> f64 {
        let cfg = &self.cfg;
        let peak = cfg.peak_flops();
        // Parallelism proxy: output elements, or reduction parallelism
        // for contraction-heavy kernels (wgrad convs / split-K dots have
        // tiny outputs but huge reducible work — real backends split the
        // contraction across SMs). ~256 FLOPs per thread of useful work.
        let threads = (k.out_elems.max(1) as f64).max(k.flops / 256.0);
        let slots = (cfg.num_sms * cfg.threads_per_sm) as f64;
        let waves = (threads / slots).ceil().max(1.0);
        let occupancy = if ideal.sm_util {
            1.0
        } else {
            (threads / (waves * slots)).clamp(1e-3, 1.0)
        };

        let t_math = k.flops / peak / occupancy;

        let bytes = k.bytes_total() as f64;
        let reuse = if matches!(k.op.as_str(), "dot" | "convolution") {
            self.tuning.l2_reuse_compute
        } else {
            self.tuning.l2_reuse_other
        };
        let hit = reuse * (cfg.l2_bytes as f64 / bytes.max(1.0)).min(1.0);
        let miss_rate = (1.0 - hit).clamp(0.0, 1.0);
        let t_dram_bw = if ideal.dram_bw {
            0.0
        } else {
            bytes * miss_rate / (cfg.dram_bw_gbps * 1e9)
        };
        let t_l2 = if ideal.l2 {
            0.0
        } else {
            bytes / (cfg.l2_bw_gbps * 1e9)
        };
        let t_mem = t_dram_bw.max(t_l2);

        // Latency exposure is per dependent-access chain, not per wave:
        // with many waves in flight the hardware pipelines misses, so
        // only low-occupancy kernels see the full load-to-use latency.
        let t_lat = if ideal.dram_latency {
            0.0
        } else {
            self.tuning.latency_chain
                * cfg.dram_latency_ns
                * 1e-9
                * miss_rate
                * (2.0 - occupancy)
        };

        let t_launch = if ideal.sm_util {
            0.0
        } else {
            cfg.launch_overhead_us * 1e-6
        };

        t_launch + t_math.max(t_mem) + t_lat
    }

    /// Time for one execution of a trace (one inference batch / train
    /// step), in seconds.
    pub fn trace_time(&self, trace: &Trace, ideal: Idealize) -> f64 {
        trace.kernels.iter().map(|k| self.kernel_time(k, ideal)).sum()
    }

    /// Pure-math floor: every non-compute component idealized.
    pub fn math_time(&self, trace: &Trace) -> f64 {
        self.trace_time(trace, Idealize::ALL)
    }

    /// The Fig. 2 ladder: idealize DRAM BW → DRAM latency → L2 → SM
    /// occupancy, attributing recovered time to each component.
    pub fn breakdown(&self, trace: &Trace) -> Breakdown {
        let t0 = self.trace_time(trace, Idealize::NONE);
        let t1 = self.trace_time(
            trace,
            Idealize {
                dram_bw: true,
                ..Idealize::NONE
            },
        );
        let t2 = self.trace_time(
            trace,
            Idealize {
                dram_bw: true,
                dram_latency: true,
                ..Idealize::NONE
            },
        );
        let t3 = self.trace_time(
            trace,
            Idealize {
                dram_bw: true,
                dram_latency: true,
                l2: true,
                ..Idealize::NONE
            },
        );
        let t4 = self.trace_time(trace, Idealize::ALL);
        Breakdown {
            total_seconds: t0,
            dram_bw: ((t0 - t1) / t0).max(0.0),
            dram_latency: ((t1 - t2) / t0).max(0.0),
            l2: ((t2 - t3) / t0).max(0.0),
            sm_util: ((t3 - t4) / t0).max(0.0),
            math: (t4 / t0).max(0.0),
        }
    }

    /// Achieved FLOP/s on a trace (efficiency metric for §Perf).
    pub fn achieved_flops(&self, trace: &Trace) -> f64 {
        trace.total_flops() / self.trace_time(trace, Idealize::NONE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simarch::trace::synthetic_train_trace;

    fn model() -> GpuModel {
        GpuModel::new(GpuModelConfig::default())
    }

    fn big_dot() -> KernelDesc {
        KernelDesc {
            name: "dot".into(),
            op: "dot".into(),
            flops: 1e9,
            bytes_read: 8 << 20,
            bytes_written: 4 << 20,
            out_elems: 1 << 20,
        }
    }

    fn tiny_elementwise() -> KernelDesc {
        KernelDesc {
            name: "fusion".into(),
            op: "fusion".into(),
            flops: 512.0,
            bytes_read: 4096,
            bytes_written: 2048,
            out_elems: 512,
        }
    }

    #[test]
    fn idealization_monotone_per_kernel() {
        let m = model();
        for k in [big_dot(), tiny_elementwise()] {
            let t0 = m.kernel_time(&k, Idealize::NONE);
            let t_bw = m.kernel_time(
                &k,
                Idealize {
                    dram_bw: true,
                    ..Idealize::NONE
                },
            );
            let t_all = m.kernel_time(&k, Idealize::ALL);
            assert!(t0 >= t_bw && t_bw >= t_all, "{}: {t0} {t_bw} {t_all}", k.name);
            assert!(t_all > 0.0);
        }
    }

    #[test]
    fn breakdown_shares_sum_to_one() {
        let m = model();
        let tr = synthetic_train_trace(3, 8, 64);
        let b = m.breakdown(&tr);
        let sum = b.math + b.sm_util + b.dram_bw + b.dram_latency + b.l2;
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
        assert!(b.math > 0.0);
        assert!(b.total_seconds > 0.0);
    }

    #[test]
    fn small_kernels_underutilize_sms() {
        let m = model();
        let k = tiny_elementwise();
        // 512 threads on 80 SMs x 2048 slots: occupancy ~0.3%.
        let t_real = m.kernel_time(&k, Idealize::NONE);
        let t_perfect = m.kernel_time(
            &k,
            Idealize {
                sm_util: true,
                dram_bw: true,
                dram_latency: true,
                l2: true,
            },
        );
        assert!(t_real > 50.0 * t_perfect);
    }

    #[test]
    fn fewer_sms_slow_compute_bound_kernels() {
        let m80 = model();
        let m2 = m80.with_sms(2);
        let k = big_dot();
        let t80 = m80.kernel_time(&k, Idealize::NONE);
        let t2 = m2.kernel_time(&k, Idealize::NONE);
        assert!(t2 > 5.0 * t80, "t2 {t2} vs t80 {t80}");
    }

    #[test]
    fn fewer_sms_barely_affect_bandwidth_bound_kernels() {
        let m80 = model();
        let m40 = m80.with_sms(40);
        // Huge bytes, tiny flops: DRAM-bandwidth-bound.
        let k = KernelDesc {
            name: "copy".into(),
            op: "copy".into(),
            flops: 1.0,
            bytes_read: 256 << 20,
            bytes_written: 256 << 20,
            out_elems: 64 << 20,
        };
        let t80 = m80.kernel_time(&k, Idealize::NONE);
        let t40 = m40.kernel_time(&k, Idealize::NONE);
        assert!(t40 < 1.3 * t80, "bw-bound kernel should not scale with SMs");
    }

    #[test]
    fn achieved_flops_below_peak() {
        let m = model();
        let tr = synthetic_train_trace(1, 8, 64);
        assert!(m.achieved_flops(&tr) < m.cfg.peak_flops());
    }

    #[test]
    fn ladder_order_attribution_non_negative() {
        let m = model();
        for seed in 0..5 {
            let tr = synthetic_train_trace(seed, 6, 32);
            let b = m.breakdown(&tr);
            assert!(b.dram_bw >= 0.0 && b.dram_latency >= 0.0);
            assert!(b.l2 >= 0.0 && b.sm_util >= 0.0 && b.math >= 0.0);
        }
    }
}
