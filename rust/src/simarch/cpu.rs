//! Host-CPU model: actors competing for hardware threads.
//!
//! The paper's Fig. 3 knee comes from the DGX-1's 20 cores / 40 hardware
//! threads saturating as the actor count grows. The model captures:
//!   * one actor at full speed on a dedicated core,
//!   * SMT pairing (two threads per core run at `smt_efficiency` each),
//!   * oversubscription beyond the thread count (timeslicing with a
//!     context-switch tax).

use crate::config::CpuModelConfig;

#[derive(Clone, Debug)]
pub struct CpuModel {
    pub cfg: CpuModelConfig,
}

impl CpuModel {
    pub fn new(cfg: CpuModelConfig) -> Self {
        Self { cfg }
    }

    pub fn with_threads(&self, hw_threads: usize) -> Self {
        let mut m = self.clone();
        m.cfg.hw_threads = hw_threads.max(1);
        m
    }

    /// Physical cores (2 SMT threads per core).
    pub fn cores(&self) -> usize {
        (self.cfg.hw_threads / 2).max(1)
    }

    /// Aggregate compute capacity in core-equivalents when `n` actors are
    /// runnable simultaneously.
    ///
    /// n <= cores: each actor gets a full core => capacity n.
    /// cores < n <= hw_threads: (n - cores) cores run SMT pairs; a pair
    ///   delivers 2*smt_efficiency core-equivalents.
    /// n > hw_threads: capacity saturates at full-SMT throughput, less a
    ///   timeslicing tax that grows with the oversubscription ratio.
    pub fn capacity(&self, n: usize) -> f64 {
        let cores = self.cores() as f64;
        let hw = self.cfg.hw_threads as f64;
        let n_f = n as f64;
        let pair_throughput = 2.0 * self.cfg.smt_efficiency;
        let cap = if n_f <= cores {
            n_f
        } else if n_f <= hw {
            let paired = n_f - cores; // cores running 2 threads
            (cores - paired) + paired * pair_throughput
        } else {
            cores * pair_throughput
        };
        if n_f > hw {
            // Context-switch tax: fraction of each quantum lost, growing
            // with the oversubscription ratio.
            let step = self.step_cost_us();
            let overhead = self.cfg.ctx_switch_us * (n_f / hw - 1.0);
            cap * (step / (step + overhead)).clamp(0.1, 1.0)
        } else {
            cap
        }
    }

    /// One actor-step's CPU work, microseconds (env + agent-side glue).
    pub fn step_cost_us(&self) -> f64 {
        self.cfg.env_step_us + self.cfg.actor_overhead_us
    }

    /// Aggregate environment steps/second with `n` CPU-busy actors.
    pub fn env_steps_per_sec(&self, n: usize) -> f64 {
        self.capacity(n) * 1e6 / self.step_cost_us()
    }

    /// Per-actor CPU time for one step when `n` actors compete
    /// (processor-sharing view), microseconds.
    pub fn actor_step_latency_us(&self, n: usize) -> f64 {
        let speed = (self.capacity(n) / n as f64).min(1.0);
        self.step_cost_us() / speed.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CpuModelConfig;

    fn model() -> CpuModel {
        CpuModel::new(CpuModelConfig::default()) // 40 threads / 20 cores
    }

    #[test]
    fn capacity_linear_up_to_cores() {
        let m = model();
        assert_eq!(m.cores(), 20);
        assert!((m.capacity(1) - 1.0).abs() < 1e-12);
        assert!((m.capacity(20) - 20.0).abs() < 1e-12);
        // 4 -> 20 actors: exactly 5x throughput.
        let r = m.env_steps_per_sec(20) / m.env_steps_per_sec(4);
        assert!((r - 5.0).abs() < 1e-9);
    }

    #[test]
    fn smt_region_sublinear_but_growing() {
        let m = model();
        let c20 = m.capacity(20);
        let c30 = m.capacity(30);
        let c40 = m.capacity(40);
        assert!(c30 > c20 && c40 > c30);
        // 40 threads on 20 cores at 0.65 SMT: 26 core-equivalents.
        assert!((c40 - 26.0).abs() < 1e-9);
        // Far less than linear.
        assert!(c40 < 40.0 * 0.7);
    }

    #[test]
    fn oversubscription_saturates_with_tax() {
        let m = model();
        let c40 = m.capacity(40);
        let c64 = m.capacity(64);
        let c256 = m.capacity(256);
        assert!(c64 <= c40);
        assert!(c256 <= c64);
        // The tax is bounded: capacity never collapses below 10%.
        assert!(c256 > 0.1 * c40);
    }

    #[test]
    fn knee_at_hw_threads() {
        // Throughput gain 4 -> 40 actors must dwarf the gain 40 -> 256
        // (the paper's core observation: 5.8x vs 2x; our analytic CPU
        // model alone gives ~6.5x vs <=1x, the system model adds the GPU
        // overlap that produces the residual 2x).
        let m = model();
        let up = m.env_steps_per_sec(40) / m.env_steps_per_sec(4);
        let beyond = m.env_steps_per_sec(256) / m.env_steps_per_sec(40);
        assert!(up > 4.0, "4->40 speedup {up}");
        assert!(beyond <= 1.05, "40->256 CPU-only speedup {beyond}");
    }

    #[test]
    fn latency_grows_under_contention() {
        let m = model();
        assert!(m.actor_step_latency_us(80) > m.actor_step_latency_us(10));
    }

    #[test]
    fn with_threads_rescales() {
        let m = model().with_threads(80);
        assert_eq!(m.cores(), 40);
        assert!((m.capacity(40) - 40.0).abs() < 1e-12);
    }
}
