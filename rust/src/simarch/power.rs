//! GPU power model (Fig. 3's right-hand story).
//!
//! Measured GPUs draw substantial power at idle (the paper reports ≈70 W
//! for the V100 at low utilization) and grow sub-linearly with
//! utilization toward TDP. Dynamic power splits between SM activity and
//! the memory system; disabling SMs (Fig. 4's knob) removes only the SM
//! share of dynamic power plus a per-SM slice of static power.

use crate::config::PowerModelConfig;

#[derive(Clone, Debug)]
pub struct PowerModel {
    pub cfg: PowerModelConfig,
}

impl PowerModel {
    pub fn new(cfg: PowerModelConfig) -> Self {
        Self { cfg }
    }

    /// Average power (W) at `util` in [0,1] with all SMs enabled.
    pub fn power(&self, util: f64) -> f64 {
        let u = util.clamp(0.0, 1.0);
        self.cfg.idle_w + (self.cfg.max_w - self.cfg.idle_w) * u.powf(self.cfg.util_exponent)
    }

    /// Average power with only `sms_enabled` of `sms_total` SMs powered.
    /// SM-gated share of dynamic power scales with the enabled fraction;
    /// idle (static + memory) power is unaffected — matching the paper's
    /// observation that low-utilization power stays high.
    pub fn power_with_sms(&self, util: f64, sms_enabled: usize, sms_total: usize) -> f64 {
        let u = util.clamp(0.0, 1.0);
        let frac = (sms_enabled.min(sms_total).max(1)) as f64 / sms_total.max(1) as f64;
        let dynamic = (self.cfg.max_w - self.cfg.idle_w) * u.powf(self.cfg.util_exponent);
        let sm_dyn = dynamic * self.cfg.sm_dynamic_frac * frac;
        let mem_dyn = dynamic * (1.0 - self.cfg.sm_dynamic_frac);
        self.cfg.idle_w + sm_dyn + mem_dyn
    }

    /// Energy (J) to run at `util` for `seconds`.
    pub fn energy(&self, util: f64, seconds: f64) -> f64 {
        self.power(util) * seconds
    }

    /// Performance per Watt: work rate / power.
    pub fn perf_per_watt(&self, work_per_sec: f64, util: f64) -> f64 {
        work_per_sec / self.power(util)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PowerModelConfig;

    fn model() -> PowerModel {
        PowerModel::new(PowerModelConfig::default())
    }

    #[test]
    fn idle_floor_and_tdp_ceiling() {
        let m = model();
        assert_eq!(m.power(0.0), 70.0);
        assert!((m.power(1.0) - 300.0).abs() < 1e-9);
        assert!(m.power(-1.0) >= 70.0);
        assert!(m.power(2.0) <= 300.0);
    }

    #[test]
    fn sublinear_growth() {
        let m = model();
        // At 50% utilization, power exceeds the linear midpoint
        // (util_exponent < 1): high power at moderate utilization.
        let linear_mid = 70.0 + 0.5 * 230.0;
        assert!(m.power(0.5) > linear_mid);
        assert!(m.power(0.5) < 300.0);
    }

    #[test]
    fn perf_per_watt_improves_with_utilization() {
        // The paper's key power observation: throughput grows faster than
        // power, so perf/W rises with actor count (utilization).
        let m = model();
        let low = m.perf_per_watt(100.0, 0.1);
        let high = m.perf_per_watt(1000.0, 1.0);
        assert!(high > low);
    }

    #[test]
    fn disabling_sms_saves_only_sm_dynamic_power() {
        let m = model();
        let full = m.power_with_sms(0.8, 80, 80);
        let half = m.power_with_sms(0.8, 40, 80);
        assert!(half < full);
        // But idle + memory share remains: saving is bounded.
        assert!(full - half < 0.5 * (full - 70.0) + 1e-9);
        assert!((m.power_with_sms(0.8, 80, 80) - m.power(0.8)).abs() < 1e-9);
    }

    #[test]
    fn energy_is_power_times_time() {
        let m = model();
        assert!((m.energy(0.5, 10.0) - m.power(0.5) * 10.0).abs() < 1e-12);
    }
}
