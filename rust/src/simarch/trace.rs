//! Kernel traces: the workload descriptions the GPU timing model consumes.
//!
//! Traces come from `artifacts/kernel_trace.json`, which `aot.py` extracts
//! from the XLA-optimized HLO of our real R2D2 graphs (per-kernel FLOPs,
//! bytes, output parallelism). A synthetic generator provides
//! deterministic traces for unit tests and for sweeps that must not
//! depend on artifact presence.

use crate::util::json::Value;
use crate::util::prng::Pcg32;
use std::path::Path;

/// One modeled GPU kernel launch.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelDesc {
    pub name: String,
    pub op: String,
    pub flops: f64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Output element count — the parallelism proxy (threads to schedule).
    pub out_elems: u64,
}

impl KernelDesc {
    pub fn bytes_total(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Arithmetic intensity (FLOPs per byte).
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes_total().max(1) as f64
    }
}

/// A kernel sequence representing one execution of a graph
/// (one inference batch or one training step).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub artifact: String,
    pub kernels: Vec<KernelDesc>,
}

impl Trace {
    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.kernels.iter().map(|k| k.bytes_total()).sum()
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }
}

/// All traces from `kernel_trace.json`.
pub struct TraceSet {
    pub traces: Vec<Trace>,
}

impl TraceSet {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("kernel_trace.json"))
            .map_err(|e| anyhow::anyhow!("read kernel_trace.json: {e} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let v = Value::parse(text).map_err(|e| anyhow::anyhow!("trace json: {e}"))?;
        let mut traces = Vec::new();
        for t in v.get("traces").and_then(|x| x.as_arr()).unwrap_or(&[]) {
            let artifact = t
                .get("artifact")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string();
            let mut kernels = Vec::new();
            for k in t.get("kernels").and_then(|x| x.as_arr()).unwrap_or(&[]) {
                kernels.push(KernelDesc {
                    name: k.get("name").and_then(|x| x.as_str()).unwrap_or("").into(),
                    op: k.get("op").and_then(|x| x.as_str()).unwrap_or("").into(),
                    flops: k.get("flops").and_then(|x| x.as_f64()).unwrap_or(0.0),
                    bytes_read: k.get("bytes_read").and_then(|x| x.as_u64()).unwrap_or(0),
                    bytes_written: k
                        .get("bytes_written")
                        .and_then(|x| x.as_u64())
                        .unwrap_or(0),
                    out_elems: k.get("out_elems").and_then(|x| x.as_u64()).unwrap_or(0),
                });
            }
            traces.push(Trace { artifact, kernels });
        }
        anyhow::ensure!(!traces.is_empty(), "no traces in kernel_trace.json");
        Ok(Self { traces })
    }

    /// Find a trace by artifact-name prefix (e.g. "infer_b", "train").
    pub fn find(&self, prefix: &str) -> Option<&Trace> {
        self.traces.iter().find(|t| t.artifact.starts_with(prefix))
    }
}

/// Deterministic synthetic trace shaped like a small NN training step:
/// interleaved large matmul-ish kernels (high FLOPs, moderate bytes, high
/// parallelism) and elementwise kernels (low FLOPs, bytes-bound).
pub fn synthetic_train_trace(seed: u64, layers: usize, batch: usize) -> Trace {
    let mut rng = Pcg32::seeded(seed);
    let mut kernels = Vec::new();
    for l in 0..layers {
        let m = 64 << (l % 3); // output rows
        let k = 128 + 64 * (l % 4); // contraction
        let n = batch;
        let flops = 2.0 * (m * n * k) as f64;
        let bytes = 4 * (m * k + k * n + m * n) as u64;
        kernels.push(KernelDesc {
            name: format!("dot.{l}"),
            op: "dot".into(),
            flops,
            bytes_read: 4 * (m * k + k * n) as u64,
            bytes_written: 4 * (m * n) as u64,
            out_elems: (m * n) as u64,
        });
        // 1-3 elementwise epilogues.
        for e in 0..(1 + rng.index(3)) {
            let elems = (m * n) as u64;
            kernels.push(KernelDesc {
                name: format!("fusion.{l}.{e}"),
                op: "fusion".into(),
                flops: elems as f64 * 3.0,
                bytes_read: elems * 8,
                bytes_written: elems * 4,
                out_elems: elems,
            });
        }
        let _ = bytes;
    }
    Trace {
        artifact: format!("synthetic_l{layers}_b{batch}"),
        kernels,
    }
}

/// Synthetic trace at the *paper's* workload scale: SEED-RL's R2D2 on
/// Atari (84x84x4 conv torso, LSTM 512, batch 64) keeps a V100 busy with
/// multi-GFLOP convolutions and [64,512]x[512,2048] recurrent matmuls.
/// Used by tests and as the fallback when artifacts are absent; the real
/// counterpart is the `*_paper_scale` trace `aot.py` extracts.
pub fn synthetic_paper_trace(seed: u64, timesteps: usize, batch: usize) -> Trace {
    let mut rng = Pcg32::seeded(seed);
    let mut kernels = Vec::new();
    let b = batch;
    for t in 0..timesteps {
        // Conv stack (84x84x4 -> 20x20x32 -> 9x9x64), NHWC, fp32.
        let conv1_out = b * 20 * 20 * 32;
        kernels.push(KernelDesc {
            name: format!("conv1.{t}"),
            op: "convolution".into(),
            flops: 2.0 * conv1_out as f64 * (8.0 * 8.0 * 4.0),
            bytes_read: (b * 84 * 84 * 4 * 4 + 8 * 8 * 4 * 32 * 4) as u64,
            bytes_written: (conv1_out * 4) as u64,
            out_elems: conv1_out as u64,
        });
        let conv2_out = b * 9 * 9 * 64;
        kernels.push(KernelDesc {
            name: format!("conv2.{t}"),
            op: "convolution".into(),
            flops: 2.0 * conv2_out as f64 * (4.0 * 4.0 * 32.0),
            bytes_read: (conv1_out * 4 + 4 * 4 * 32 * 64 * 4) as u64,
            bytes_written: (conv2_out * 4) as u64,
            out_elems: conv2_out as u64,
        });
        // LSTM gates: [B,512+?] x [., 2048] fused pair of matmuls.
        for gate in 0..2 {
            let (m, k, n) = (b, 512 + 64 * (gate % 2), 2048);
            kernels.push(KernelDesc {
                name: format!("lstm_dot{gate}.{t}"),
                op: "dot".into(),
                flops: 2.0 * (m * k * n) as f64,
                bytes_read: ((m * k + k * n) * 4) as u64,
                bytes_written: ((m * n) * 4) as u64,
                out_elems: (m * n) as u64,
            });
        }
        // Pointwise epilogues (gates, relu) — bytes-bound.
        for e in 0..(2 + rng.index(2)) {
            let elems = (b * 2048) as u64;
            kernels.push(KernelDesc {
                name: format!("ew{e}.{t}"),
                op: "fusion".into(),
                flops: elems as f64 * 6.0,
                bytes_read: elems * 12,
                bytes_written: elems * 4,
                out_elems: elems,
            });
        }
    }
    Trace {
        artifact: format!("synthetic_paper_t{timesteps}_b{batch}"),
        kernels,
    }
}

/// Paper-scale *training-step* trace: forward kernels (from
/// `synthetic_paper_trace`) + backward-pass kernels (≈2x forward FLOPs,
/// higher byte traffic for activation re-reads) + Adam optimizer kernels
/// (pure DRAM-bandwidth: read p/g/m/v, write p/m/v over ~6M params).
pub fn synthetic_paper_train_trace(seed: u64, timesteps: usize, batch: usize) -> Trace {
    let fwd = synthetic_paper_trace(seed, timesteps, batch);
    let mut kernels = fwd.kernels.clone();
    // Backward: dgrad+wgrad per forward op, ~2x FLOPs, 2x bytes.
    for k in &fwd.kernels {
        kernels.push(KernelDesc {
            name: format!("bwd_{}", k.name),
            op: k.op.clone(),
            flops: 2.0 * k.flops,
            bytes_read: 2 * k.bytes_read,
            bytes_written: 2 * k.bytes_written,
            out_elems: 2 * k.out_elems,
        });
    }
    // Input-pipeline / layout kernels: observation decode + stacking +
    // NHWC<->NCHW transposes over the [64, 80, 84, 84, 4] batch — pure
    // streaming DRAM traffic with no reuse (the TF graph the paper
    // profiles is full of these between the fused compute ops).
    for t in 0..timesteps {
        let obs_bytes = (batch * 84 * 84 * 4 * 4) as u64;
        for pass in 0..3 {
            // decode/scale, frame-stack gather, layout transpose
            kernels.push(KernelDesc {
                name: format!("preproc{pass}.{t}"),
                op: "copy".into(),
                flops: 0.0,
                bytes_read: obs_bytes,
                bytes_written: obs_bytes,
                out_elems: obs_bytes / 4,
            });
        }
    }
    // Optimizer: Adam over ~6M fp32 params, split across a few kernels.
    let params: u64 = 6_000_000;
    let chunks = 4;
    for c in 0..chunks {
        let p = params / chunks;
        kernels.push(KernelDesc {
            name: format!("adam.{c}"),
            op: "fusion".into(),
            flops: p as f64 * 12.0,
            bytes_read: p * 4 * 4,  // p, g, m, v
            bytes_written: p * 4 * 3, // p, m, v
            out_elems: p,
        });
    }
    Trace {
        artifact: format!("synthetic_paper_train_t{timesteps}_b{batch}"),
        kernels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"traces": [
      {"artifact": "infer_b64", "kernels": [
         {"name": "dot.1", "op": "dot", "flops": 1048576,
          "bytes_read": 262144, "bytes_written": 32768, "out_elems": 8192},
         {"name": "fusion.2", "op": "fusion", "flops": 8192,
          "bytes_read": 65536, "bytes_written": 32768, "out_elems": 8192}
      ], "summary": {}, "xla_cost_analysis_flops": 1100000},
      {"artifact": "train_unrolled", "kernels": [], "summary": {}}
    ]}"#;

    #[test]
    fn parses_sample() {
        let ts = TraceSet::parse(SAMPLE).unwrap();
        assert_eq!(ts.traces.len(), 2);
        let t = ts.find("infer_b").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.total_flops(), 1048576.0 + 8192.0);
        assert_eq!(t.kernels[0].bytes_total(), 294912);
        assert!(t.kernels[0].intensity() > 3.0);
    }

    #[test]
    fn find_by_prefix() {
        let ts = TraceSet::parse(SAMPLE).unwrap();
        assert!(ts.find("train").is_some());
        assert!(ts.find("nope").is_none());
    }

    #[test]
    fn rejects_empty() {
        assert!(TraceSet::parse(r#"{"traces": []}"#).is_err());
        assert!(TraceSet::parse("not json").is_err());
    }

    #[test]
    fn synthetic_trace_is_deterministic_and_mixed() {
        let a = synthetic_train_trace(7, 6, 32);
        let b = synthetic_train_trace(7, 6, 32);
        assert_eq!(a.kernels, b.kernels);
        let dots = a.kernels.iter().filter(|k| k.op == "dot").count();
        let fusions = a.kernels.iter().filter(|k| k.op == "fusion").count();
        assert_eq!(dots, 6);
        assert!(fusions >= 6);
        // Dots are compute-heavy, fusions bytes-bound.
        assert!(a.kernels[0].intensity() > 10.0 * a.kernels[1].intensity());
    }
}
