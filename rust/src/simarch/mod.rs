//! NVArchSim-style CPU-GPU architectural simulator.
//!
//! The paper's evidence (Figs. 2-4) comes from hardware profiling plus
//! NVIDIA's internal trace-driven simulator. This module reproduces the
//! *methodology* on open substrates:
//!
//! * [`trace`] — kernel descriptors extracted from our real R2D2 HLO.
//! * [`gpu`] — V100 timing model + the component-idealization ladder
//!   (Fig. 2's breakdown procedure).
//! * [`cpu`] — hardware-thread scheduling model for the actor pool.
//! * [`power`] — idle-heavy GPU power curve (Fig. 3 right axis).
//! * [`system`] — coupled steady-state model of the full SEED dataflow
//!   (Fig. 3 actor sweep, Fig. 4 SM sweep / CPU-GPU ratio).
//! * [`des`] — tick-driven discrete-event validation of the analytic
//!   steady-state solution.

pub mod cpu;
pub mod des;
pub mod gpu;
pub mod power;
pub mod system;
pub mod trace;

pub use cpu::CpuModel;
pub use gpu::{Breakdown, GpuModel, GpuTuning, Idealize};
pub use power::PowerModel;
pub use system::{default_system, InferScaling, PhaseShares, SystemModel, SystemPoint};
pub use trace::{synthetic_paper_train_trace, synthetic_paper_trace, synthetic_train_trace, KernelDesc, Trace, TraceSet};
