//! Full-system steady-state model: actors (CPU) + central inference +
//! learner sharing one GPU. Produces the paper's Fig. 3 (actor sweep)
//! and Fig. 4 (SM sweep) series.
//!
//! The model solves a fixed point over the coupled quantities:
//!   * aggregate env-step rate R,
//!   * the number of actors concurrently CPU-busy (Little's law — an
//!     actor waiting on inference yields its hardware thread, which is
//!     why oversubscribing actors beyond 40 threads keeps helping, the
//!     paper's 40→256 tail),
//!   * the inference batch size the batcher forms at rate R,
//!   * GPU queueing inflation when inference + training near capacity.
//!
//! Absolute times come from the GPU timing model over the *real* kernel
//! traces of our R2D2 graphs; the CPU side from `CpuModel`; power from
//! `PowerModel`.

use super::cpu::CpuModel;
use super::gpu::{GpuModel, Idealize};
use super::power::PowerModel;
use super::trace::Trace;

/// Scaling description for inference cost vs batch size: the reference
/// trace is for `ref_batch`; activations scale with B, parameter reads
/// do not. `weight_frac` is the fraction of trace bytes that are
/// batch-independent (weights).
#[derive(Clone, Debug)]
pub struct InferScaling {
    pub ref_batch: usize,
    pub weight_frac: f64,
}

impl Default for InferScaling {
    fn default() -> Self {
        Self {
            ref_batch: 64,
            weight_frac: 0.5,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SystemModel {
    pub cpu: CpuModel,
    pub gpu: GpuModel,
    pub power: PowerModel,
    pub infer_trace: Trace,
    pub infer_scaling: InferScaling,
    pub train_trace: Trace,
    /// Learner steps per environment step (replay ratio): R2D2 defaults
    /// give 1 / ((seq_len - overlap) * train_batch).
    pub train_per_env: f64,
    /// Batcher policy.
    pub max_batch: usize,
    pub batch_timeout_s: f64,
    /// AOT launch buckets (ascending; the execution-side
    /// `batcher.batch_sizes`): a flush of `n` rows launches as the
    /// smallest bucket `>= n`, burning GPU time on the zero-padded rows.
    /// Empty = exact-shape launches, the seed model's idealization (a
    /// launch shape per possible batch size); `[max_batch]` is the
    /// single-executable extreme that pads every partial flush to the
    /// cap. The padded rows change *GPU efficiency only* — the reply
    /// stream is shape-invariant (`tests/batcher_equivalence.rs`).
    pub batch_buckets: Vec<usize>,
    /// Environments driven in lockstep by each actor thread (vecenv).
    /// One thread's cycle becomes E env steps + one batched round-trip,
    /// so E raises environments-in-flight (and the achievable batch
    /// occupancy) without consuming more hardware threads — it shifts
    /// the *effective* CPU/GPU ratio at a fixed thread count.
    pub envs_per_actor: usize,
    /// Actor-loop software-pipeline depth (policy layer, DESIGN.md §5):
    /// the E slots split into D groups that round-robin, so env CPU work
    /// for one group overlaps the inference round-trip of the others.
    /// The serialized per-thread cycle for E steps, `W + rtt` with
    /// `W = E * t_env`, becomes `max(W, rtt + W/D)` — at depth 1 the
    /// seed's fully serialized critical path, identically.
    pub pipeline_depth: usize,
    /// CPU time the learner spends sampling a train batch from
    /// prioritized replay, seconds per train step.
    pub learner_sample_s: f64,
    /// CPU time the learner spends assembling the sampled sequences
    /// into the batch-major `TrainBatch`, seconds per train step.
    pub learner_assemble_s: f64,
    /// Learner split-phase prefetch depth (DESIGN.md §7): at 1 the
    /// train cycle serializes `t_train + t_sample + t_assemble`; at
    /// >= 2 the CPU phases overlap the accelerator step and the cycle
    /// becomes `max(t_train, t_sample + t_assemble)` — the learner-side
    /// mirror of the actor pipeline's `max(W, rtt + W/D)`.
    pub prefetch_depth: usize,
    /// Sequences each actor emits per environment step: 1 / (seq_len -
    /// overlap), the trajectory slicer's stride (paper-scale R2D2:
    /// 1 / (80 - 40)).
    pub seq_per_env: f64,
    /// Synchronization cost of committing one sequence to replay at
    /// `insert_batch = 1` — the shard-lock acquire/release plus ring
    /// bookkeeping, seconds (the payload copy lives in
    /// `actor_overhead_us`). Measured in `micro_replay`.
    pub replay_insert_s: f64,
    /// Sequences per ingest flush (the `replay.insert_batch` knob): a
    /// flush takes each shard lock at most once, so the per-sequence
    /// insert cost lands in the actor cycle amortized by this factor
    /// (DESIGN.md §8).
    pub insert_batch: usize,
    /// Replay shard count (the `replay.shards` knob). A flush of `k`
    /// sequences costs `min(k, shards)` lock round-trips, so the
    /// amortization saturates once the batch no longer exceeds the
    /// shard count — matching the counter-based `micro_replay`
    /// measurement exactly.
    pub replay_shards: usize,
    /// Fixed per-call overhead of an environment stepping call, seconds
    /// — virtual dispatch, per-slot frame-stack rotation bookkeeping,
    /// cache refills on scattered per-slot state. On the per-slot
    /// engine every env step is its own call and pays this in full; the
    /// batch-native SoA engine (`env.batch_native`, DESIGN.md §13)
    /// makes one call per slot group, amortizing it over the group's
    /// rows. Measured by the `micro_env` per-slot-vs-SoA sweep; 0 (the
    /// default) keeps both engine models identical.
    pub env_dispatch_s: f64,
    /// Mirror of the `env.batch_native` execution knob: selects which
    /// way `env_dispatch_s` enters the actor cycle.
    pub batch_native: bool,
    /// Fixed network round-trip latency an actor's inference submission
    /// pays when the fleet transport (DESIGN.md §14) separates actors
    /// from the batcher, seconds. 0 (the default) models the in-process
    /// deployment — the identity, bit-for-bit.
    pub net_rtt_s: f64,
    /// Wire bytes per environment-step row, both directions combined
    /// (obs + recurrent state out, q-values + recurrent state back,
    /// plus frame headers). Only meaningful with a finite bandwidth.
    pub net_bytes_per_row: f64,
    /// Link bandwidth in bytes/second; 0 (the default) = no bandwidth
    /// term (infinite link), keeping the identity exact.
    pub net_bandwidth_bps: f64,
    /// Fault arrivals per actor thread, faults/second — the
    /// fault-tolerance layer's availability term (DESIGN.md §15):
    /// reaped heartbeats, killed links, ticket-deadline resubmissions,
    /// supervised actor restarts. 0 (the default) models the
    /// fault-free deployment — the identity, bit-for-bit.
    pub fault_rate: f64,
    /// Wall-clock seconds one fault stalls the afflicted actor thread:
    /// detection (liveness timeout or ticket deadline), the reconnect
    /// handshake, and resubmission of the lost round. Only meaningful
    /// with a non-zero `fault_rate`.
    pub fault_recovery_s: f64,
    /// Checkpoint hot-reloads per second of wall-clock — the serving
    /// layer's availability term (DESIGN.md §16). Unlike faults, a
    /// reload pauses admission *fleet-wide* (drain + swap + resync), so
    /// the stall hits every actor thread at once. 0 (the default)
    /// models a reload-free run — the identity, bit-for-bit.
    pub reload_rate: f64,
    /// Wall-clock seconds one hot-reload stalls admission: the bounded
    /// drain, snapshot load + verify, and the worker resync behind the
    /// bumped generation fence. Only meaningful with a non-zero
    /// `reload_rate`.
    pub reload_stall_s: f64,
}

/// One steady-state operating point.
#[derive(Clone, Debug, Default)]
pub struct SystemPoint {
    pub actors: usize,
    /// Aggregate environment steps / second.
    pub env_rate: f64,
    /// Mean inference batch size formed.
    pub batch_size: f64,
    /// GPU busy fraction in [0,1].
    pub gpu_util: f64,
    /// Actors concurrently CPU-busy.
    pub cpu_busy_actors: f64,
    /// Average GPU power, W.
    pub power_w: f64,
    /// env steps per second per GPU Watt.
    pub perf_per_watt: f64,
    /// Actor-visible inference round-trip, seconds.
    pub rtt_s: f64,
}

/// Fig. 2-style phase attribution: the fraction of total busy time each
/// pipeline phase claims (shares sum to 1 when any phase is non-zero).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseShares {
    /// Env stepping (actor CPU).
    pub env: f64,
    /// Batched inference (GPU, amortized per env step).
    pub infer: f64,
    /// Train step (GPU, amortized per env step).
    pub train: f64,
    /// Replay service: actor-side insert + learner-side sample/assemble.
    pub replay: f64,
}

impl SystemModel {
    /// Inference time for a batch of `b` on the current GPU model.
    pub fn infer_time(&self, b: usize) -> f64 {
        let s = &self.infer_scaling;
        let ratio = b as f64 / s.ref_batch as f64;
        let scaled = Trace {
            artifact: self.infer_trace.artifact.clone(),
            kernels: self
                .infer_trace
                .kernels
                .iter()
                .map(|k| {
                    let mut k = k.clone();
                    k.flops *= ratio;
                    k.out_elems = ((k.out_elems as f64 * ratio).ceil() as u64).max(1);
                    let b_total = k.bytes_read + k.bytes_written;
                    let scaled_bytes = b_total as f64
                        * (s.weight_frac + (1.0 - s.weight_frac) * ratio);
                    let f = scaled_bytes / b_total.max(1) as f64;
                    k.bytes_read = (k.bytes_read as f64 * f) as u64;
                    k.bytes_written = (k.bytes_written as f64 * f) as u64;
                    k
                })
                .collect(),
        };
        self.gpu.trace_time(&scaled, Idealize::NONE)
    }

    /// Train-step time on the current GPU model.
    pub fn train_time(&self) -> f64 {
        self.gpu.trace_time(&self.train_trace, Idealize::NONE)
    }

    /// Launch shape for a flush of `rows`: the smallest configured
    /// bucket that fits, or `rows` itself with no bucket ladder (the
    /// exact-shape idealization) or when `rows` exceeds the ladder.
    pub fn launch_size(&self, rows: usize) -> usize {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= rows)
            .unwrap_or(rows)
    }

    /// Bucket-padding efficiency of a flush of `rows`: the fraction of
    /// launched rows that carry real work (1.0 with an exact-shape
    /// ladder; `n / bucket(n)` otherwise). The GPU-side cost of the
    /// fixed-shape AOT executables the batcher models.
    pub fn padding_efficiency(&self, rows: usize) -> f64 {
        let rows = rows.max(1);
        rows as f64 / self.launch_size(rows) as f64
    }

    /// Learner train-cycle time: the GPU train step plus the CPU-side
    /// sample/assemble phases — serialized at `prefetch_depth` 1,
    /// overlapped (`max`) when the split-phase learner prefetches.
    pub fn train_cycle(&self) -> f64 {
        let t_cpu = self.learner_sample_s + self.learner_assemble_s;
        let t_train = self.train_time();
        if self.prefetch_depth > 1 {
            t_train.max(t_cpu)
        } else {
            t_train + t_cpu
        }
    }

    /// Per-env-step replay-ingest overhead on the actor CPU: the
    /// per-sequence insert cost amortized by the ingest batch size,
    /// times sequences per env step. A flush of `k` sequences over `S`
    /// shards takes `min(k, S)` lock round-trips (each shard lock at
    /// most once), so the per-sequence cost is
    /// `replay_insert_s * min(k, S) / k` — at `insert_batch = 1` every
    /// sequence pays the full round-trip, and the amortization
    /// saturates once `k <= S` (batching below the shard count buys
    /// nothing, exactly what the `micro_replay` lock counters show).
    pub fn insert_overhead_s(&self) -> f64 {
        let k = self.insert_batch.max(1) as f64;
        let s = self.replay_shards.max(1) as f64;
        self.seq_per_env * self.replay_insert_s * k.min(s) / k
    }

    /// Per-env-step share of the fixed per-call stepping overhead
    /// (`env_dispatch_s`). The per-slot engine pays it on every step
    /// (one call per slot); the batch-native SoA engine makes one call
    /// per slot group of `E / D` rows, so each step carries `D / E` of
    /// it — the amortization the CuLE-style layout buys. At
    /// `env_dispatch_s = 0` (the default) both engines are identical.
    pub fn env_dispatch_term(&self) -> f64 {
        if self.batch_native {
            let e = self.envs_per_actor.max(1) as f64;
            let d = (self.pipeline_depth.max(1) as f64).min(e);
            self.env_dispatch_s * d / e
        } else {
            self.env_dispatch_s
        }
    }

    /// Network round-trip a submission of `rows` env-step rows pays on
    /// the fleet transport: the fixed latency plus the serialization
    /// time of its bytes on the link. Both terms default to 0 — the
    /// in-process identity (no transport, no cost).
    pub fn net_round_trip_s(&self, rows: f64) -> f64 {
        let transfer = if self.net_bandwidth_bps > 0.0 {
            rows * self.net_bytes_per_row / self.net_bandwidth_bps
        } else {
            0.0
        };
        self.net_rtt_s.max(0.0) + transfer
    }

    /// Availability dilation of the fault model: each actor thread
    /// loses `fault_rate * fault_recovery_s` seconds of progress per
    /// second of wall-clock (renewal-reward over the fault arrivals),
    /// so every env step effectively takes `1 + rate * recovery` times
    /// longer. Exactly 1 at the default zero rate — the identity.
    pub fn fault_slowdown(&self) -> f64 {
        1.0 + self.fault_rate.max(0.0) * self.fault_recovery_s.max(0.0)
    }

    /// Availability dilation of serving hot-reloads: each reload pauses
    /// admission fleet-wide for `reload_stall_s`, so every thread loses
    /// `reload_rate * reload_stall_s` seconds of progress per second of
    /// wall-clock (renewal-reward, same shape as
    /// [`Self::fault_slowdown`] but global rather than per-thread).
    /// Exactly 1 at the default zero rate — the identity.
    pub fn reload_slowdown(&self) -> f64 {
        1.0 + self.reload_rate.max(0.0) * self.reload_stall_s.max(0.0)
    }

    /// Solve the steady state for `n` actor threads (damped fixed
    /// point). Each thread drives `envs_per_actor` environments in
    /// lockstep: a thread's cycle is E serial env steps plus one
    /// batched inference round-trip that produces E steps' worth of
    /// actions, so E environments occupy one hardware thread.
    pub fn steady_state(&self, n: usize) -> SystemPoint {
        let e = self.envs_per_actor.max(1) as f64;
        // More pipeline stages than slots cannot help (matches the
        // actor's clamp).
        let d = (self.pipeline_depth.max(1) as f64).min(e);
        // Ideal per-step CPU time: the env step itself plus the
        // (amortized) replay-ingest and per-call dispatch shares of
        // each step. Fault-recovery stalls ride on the thread's cycle,
        // dilating every step by the availability factor (exactly 1 at
        // the default zero fault rate — the identity).
        let t_env = (self.cpu.step_cost_us() * 1e-6
            + self.insert_overhead_s()
            + self.env_dispatch_term())
            * self.fault_slowdown()
            * self.reload_slowdown();
        let t_train = self.train_time();
        // Learner-side cap: train steps complete one per train cycle
        // (GPU step + CPU sample/assemble, overlapped when prefetching),
        // which bounds the env rate through the replay ratio.
        let r_learn = if self.train_per_env > 0.0 {
            0.99 / (self.train_per_env * self.train_cycle())
        } else {
            f64::INFINITY
        };
        let mut rate = n as f64 * e / (t_env + 1e-4); // optimistic init
        let mut batch = 1.0f64;
        let mut rtt = 1e-4;
        let mut busy = n as f64;

        for _ in 0..200 {
            // Threads CPU-busy (Little): arrivals R, service t_env_eff
            // per env step; a thread stepping its E slots serially is
            // busy for E * t_env_eff of each cycle.
            let speed = (self.cpu.capacity(busy.ceil() as usize) / busy.max(1.0)).min(1.0);
            let t_env_eff = t_env / speed.max(1e-9);
            busy = (rate * t_env_eff).clamp(1.0_f64.min(n as f64), n as f64);

            // Batch formed: arrivals during min(timeout, fill time).
            // Each thread submits a slot group of E/D rows back-to-back,
            // so a flush holds at least min(E/D, max_batch) rows even at
            // low thread counts — the vecenv occupancy floor (pipelining
            // trades a slice of it for overlap).
            let fill_time = if rate > 0.0 {
                self.max_batch as f64 / rate
            } else {
                f64::INFINITY
            };
            let window = self.batch_timeout_s.min(fill_time);
            let floor = (e / d).min(self.max_batch as f64);
            batch = (rate * window).clamp(floor, self.max_batch as f64);
            // Fixed-shape AOT launches: the GPU pays for the padded
            // bucket, the actors only get `batch` rows of work out.
            let t_infer =
                self.infer_time(self.launch_size((batch.round() as usize).max(1)));

            // GPU occupancy: inference + training load.
            let gpu_load = rate * (t_infer / batch + self.train_per_env * t_train);
            let rho = gpu_load.min(0.97);
            // Queueing inflation near saturation (M/D/1-flavoured).
            let inflation = 1.0 / (1.0 - rho);
            // Actors cycle near-synchronously, so the typical wait is
            // most of the collection window (validated against the DES).
            // A fleet deployment adds the submission's network round
            // trip on top (0 in-process — the exact identity).
            let t_wait = window * 0.75;
            rtt = t_wait + t_infer * inflation + self.net_round_trip_s(e / d);

            // Concurrency-limited rate: n threads, each producing E env
            // steps per pipelined cycle max(W, rtt + W/D) with
            // W = E * t_env_eff — the round-robin over D slot groups
            // hides up to (D-1)/D of the env work under the inference
            // round-trip (at D = 1 this is the seed's fully serialized
            // W + rtt); CPU + GPU hard caps still apply.
            let w = e * t_env_eff;
            let r_conc = n as f64 * e / w.max(rtt + w / d);
            let r_cpu = self.cpu.env_steps_per_sec(n.min(busy.ceil() as usize).max(1));
            let gpu_per_step = t_infer / batch + self.train_per_env * t_train;
            let r_gpu = 0.99 / gpu_per_step;
            let target = r_conc.min(r_cpu.max(1.0)).min(r_gpu).min(r_learn);
            rate = 0.5 * rate + 0.5 * target; // damping
        }

        let t_infer = self.infer_time(self.launch_size((batch.round() as usize).max(1)));
        let gpu_util =
            (rate * (t_infer / batch + self.train_per_env * self.train_time())).min(1.0);
        let power_w = self
            .power
            .power_with_sms(gpu_util, self.gpu.cfg.num_sms, 80);
        SystemPoint {
            actors: n,
            env_rate: rate,
            batch_size: batch,
            gpu_util,
            cpu_busy_actors: busy,
            power_w,
            perf_per_watt: rate / power_w,
            rtt_s: rtt,
        }
    }

    /// Model-predicted Fig. 2-style phase attribution at `n` actors:
    /// the share of total busy time each phase claims per env step at
    /// the steady-state operating point. The live telemetry pipeline
    /// compares its measured breakdown against this and exports the
    /// gap as `telemetry.model_drift`.
    pub fn phase_shares(&self, n: usize) -> PhaseShares {
        let point = self.steady_state(n);
        let batch = point.batch_size.max(1.0);
        // Busy seconds per env step, by phase.
        let env = self.cpu.step_cost_us() * 1e-6 + self.env_dispatch_term();
        let infer =
            self.infer_time(self.launch_size((batch.round() as usize).max(1))) / batch;
        let train = self.train_per_env * self.train_time();
        let replay = self.insert_overhead_s()
            + self.train_per_env * (self.learner_sample_s + self.learner_assemble_s);
        let total = env + infer + train + replay;
        if total <= 0.0 {
            return PhaseShares {
                env: 0.0,
                infer: 0.0,
                train: 0.0,
                replay: 0.0,
            };
        }
        PhaseShares {
            env: env / total,
            infer: infer / total,
            train: train / total,
            replay: replay / total,
        }
    }

    /// Wall-clock seconds to generate `frames` env steps with `n` actors.
    pub fn runtime_for(&self, frames: u64, n: usize) -> f64 {
        frames as f64 / self.steady_state(n).env_rate
    }

    /// Clone with a different SM count (Fig. 4 sweep).
    pub fn with_sms(&self, sms: usize) -> Self {
        let mut m = self.clone();
        m.gpu = self.gpu.with_sms(sms);
        m
    }

    /// Clone with a different CPU hardware-thread count.
    pub fn with_threads(&self, threads: usize) -> Self {
        let mut m = self.clone();
        m.cpu = self.cpu.with_threads(threads);
        m
    }

    /// Clone with a different envs-per-actor count (vecenv sweep).
    pub fn with_envs_per_actor(&self, envs: usize) -> Self {
        let mut m = self.clone();
        m.envs_per_actor = envs.max(1);
        m
    }

    /// Clone with a different actor pipeline depth (policy-layer sweep).
    pub fn with_pipeline_depth(&self, depth: usize) -> Self {
        let mut m = self.clone();
        m.pipeline_depth = depth.max(1);
        m
    }

    /// Clone with a different AOT launch-bucket ladder (the
    /// `batcher.batch_sizes` sweep; empty = exact-shape launches).
    pub fn with_batch_buckets(&self, buckets: Vec<usize>) -> Self {
        let mut m = self.clone();
        m.batch_buckets = buckets;
        m
    }

    /// Clone with a different learner prefetch depth (split-phase
    /// learner sweep).
    pub fn with_prefetch_depth(&self, depth: usize) -> Self {
        let mut m = self.clone();
        m.prefetch_depth = depth.max(1);
        m
    }

    /// Clone with different learner CPU-phase costs (sample, assemble;
    /// seconds per train step).
    pub fn with_learner_overhead(&self, sample_s: f64, assemble_s: f64) -> Self {
        let mut m = self.clone();
        m.learner_sample_s = sample_s.max(0.0);
        m.learner_assemble_s = assemble_s.max(0.0);
        m
    }

    /// Clone with a different ingest batch size (the `replay.insert_batch`
    /// sweep).
    pub fn with_insert_batch(&self, k: usize) -> Self {
        let mut m = self.clone();
        m.insert_batch = k.max(1);
        m
    }

    /// Clone with a different replay shard count (caps the ingest
    /// amortization at `min(insert_batch, shards)` locks per flush).
    pub fn with_replay_shards(&self, shards: usize) -> Self {
        let mut m = self.clone();
        m.replay_shards = shards.max(1);
        m
    }

    /// Clone with different replay-ingest costs (sequences per env step,
    /// per-sequence insert seconds).
    pub fn with_ingest_cost(&self, seq_per_env: f64, insert_s: f64) -> Self {
        let mut m = self.clone();
        m.seq_per_env = seq_per_env.max(0.0);
        m.replay_insert_s = insert_s.max(0.0);
        m
    }

    /// Clone with a different fixed per-call env stepping overhead
    /// (seconds; the `micro_env` per-slot-vs-SoA gap).
    pub fn with_env_dispatch(&self, dispatch_s: f64) -> Self {
        let mut m = self.clone();
        m.env_dispatch_s = dispatch_s.max(0.0);
        m
    }

    /// Clone with the batch-native env engine toggled (mirrors the
    /// `env.batch_native` execution knob).
    pub fn with_batch_native(&self, on: bool) -> Self {
        let mut m = self.clone();
        m.batch_native = on;
        m
    }

    /// Clone with fleet-transport network terms (fixed round-trip
    /// seconds, wire bytes per env-step row, link bytes/second;
    /// all 0 = the in-process identity).
    pub fn with_network(&self, rtt_s: f64, bytes_per_row: f64, bandwidth_bps: f64) -> Self {
        let mut m = self.clone();
        m.net_rtt_s = rtt_s.max(0.0);
        m.net_bytes_per_row = bytes_per_row.max(0.0);
        m.net_bandwidth_bps = bandwidth_bps.max(0.0);
        m
    }

    /// Clone with fault-tolerance availability terms (faults per
    /// actor-thread-second, recovery seconds per fault; both 0 = the
    /// fault-free identity).
    pub fn with_faults(&self, rate: f64, recovery_s: f64) -> Self {
        let mut m = self.clone();
        m.fault_rate = rate.max(0.0);
        m.fault_recovery_s = recovery_s.max(0.0);
        m
    }

    /// Clone with serving hot-reload availability terms (reloads per
    /// second of wall-clock, admission-stall seconds per reload; both
    /// 0 = the reload-free identity).
    pub fn with_reloads(&self, rate: f64, stall_s: f64) -> Self {
        let mut m = self.clone();
        m.reload_rate = rate.max(0.0);
        m.reload_stall_s = stall_s.max(0.0);
        m
    }

    /// CPU/GPU ratio of this configuration (the paper's design metric).
    pub fn cpu_gpu_ratio(&self) -> f64 {
        self.cpu.cfg.hw_threads as f64 / self.gpu.cfg.num_sms as f64
    }
}

/// Build the default DGX-1-slice system model from traces.
///
/// The replay ratio uses the *paper's* R2D2 hyper-parameters (sequence
/// length 80, overlap 40, train batch 64): one learner step per
/// (80-40)*64 = 2560 environment steps — not our CPU-testbed training
/// config, which trains far more aggressively per env step.
pub fn default_system(infer_trace: Trace, train_trace: Trace) -> SystemModel {
    use crate::config::SystemConfig;
    let cfg = SystemConfig::default();
    SystemModel {
        cpu: CpuModel::new(cfg.cpu.clone()),
        gpu: GpuModel::new(cfg.gpu.clone()),
        power: PowerModel::new(cfg.power.clone()),
        infer_trace,
        infer_scaling: InferScaling::default(),
        train_trace,
        // One learner step per (80-40)*64 env steps, and the DGX-1
        // shards the learner across its 8 V100s, so each GPU carries
        // 1/8th of the training load alongside its inference service.
        train_per_env: 1.0 / ((80.0 - 40.0) * 64.0 * 8.0),
        max_batch: cfg.batcher.max_batch,
        batch_timeout_s: cfg.batcher.timeout_us as f64 * 1e-6,
        // Exact-shape launches by default — the seed model's
        // idealization, kept so the Fig. 3/4 baselines stay comparable
        // across PRs; `with_batch_buckets(cfg.batcher.batch_sizes)`
        // opts the model into the execution side's padded-AOT reality
        // (the bucket-padding efficiency term).
        batch_buckets: Vec::new(),
        envs_per_actor: cfg.actors.envs_per_actor,
        pipeline_depth: cfg.actors.pipeline_depth,
        // Measured on the CPU testbed (EXPERIMENTS.md §Perf): sampling
        // a batch through the sum trees is tens of microseconds; the
        // batch-major assembly copy dominates the CPU side.
        learner_sample_s: 20e-6,
        learner_assemble_s: 500e-6,
        prefetch_depth: cfg.learner.prefetch_depth,
        // Paper-scale R2D2 slices sequences at stride 80 - 40 = 40 env
        // steps; one unbatched insert costs a few microseconds of lock
        // round-trip (EXPERIMENTS.md §Perf, `replay.add`).
        seq_per_env: 1.0 / (80.0 - 40.0),
        replay_insert_s: 3e-6,
        insert_batch: cfg.replay.insert_batch,
        replay_shards: cfg.replay.shards,
        // 0 until the `micro_env` per-slot-vs-SoA sweep is measured on
        // a toolchain-equipped host (provenance rule: no invented
        // numbers) — at 0 both engine models are identical, keeping the
        // Fig. 3/4 baselines untouched.
        env_dispatch_s: 0.0,
        batch_native: cfg.env.batch_native,
        // 0 until a loopback/TCP fleet RTT is measured through the
        // `fleet.rtt_seconds` timer on a toolchain-equipped host
        // (provenance rule: no invented numbers) — at 0 the model is the
        // in-process deployment, keeping the Fig. 3/4 baselines
        // untouched.
        net_rtt_s: 0.0,
        net_bytes_per_row: 0.0,
        net_bandwidth_bps: 0.0,
        // 0 until a measured fault/recovery profile exists from a chaos
        // soak on a toolchain-equipped host (provenance rule: no
        // invented numbers) — at 0 the model is the fault-free
        // deployment, keeping the Fig. 3/4 baselines untouched. The
        // `[faults]` execution knobs are per-frame probabilities, not
        // per-second rates, so no automatic mapping is attempted.
        fault_rate: 0.0,
        fault_recovery_s: 0.0,
        // 0 until a measured reload profile exists (drain + swap +
        // resync from a serving soak on a toolchain-equipped host;
        // provenance rule: no invented numbers) — at 0 the model is
        // the reload-free run, keeping every baseline untouched.
        reload_rate: 0.0,
        reload_stall_s: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simarch::trace::{synthetic_paper_trace, synthetic_paper_train_trace};

    fn model() -> SystemModel {
        // Paper-scale traces (Atari-sized R2D2); the calibration
        // integration test checks the same shapes on the real artifact
        // traces from aot.py.
        let infer = synthetic_paper_trace(1, 1, 64);
        let train = synthetic_paper_train_trace(2, 80, 16);
        default_system(infer, train)
    }

    #[test]
    fn rate_monotone_in_actors_until_saturation() {
        let m = model();
        let rates: Vec<f64> = [1, 4, 8, 16, 32, 40, 64, 128, 256]
            .iter()
            .map(|&n| m.steady_state(n).env_rate)
            .collect();
        for w in rates.windows(2) {
            assert!(w[1] >= w[0] * 0.98, "rate dropped: {rates:?}");
        }
    }

    #[test]
    fn knee_behaviour_matches_paper_shape() {
        let m = model();
        let r4 = m.steady_state(4).env_rate;
        let r40 = m.steady_state(40).env_rate;
        let r256 = m.steady_state(256).env_rate;
        let up = r40 / r4;
        let beyond = r256 / r40;
        // Paper: 5.8x then 2.0x. Shape requirement: strong scaling to the
        // thread count, diminishing returns beyond.
        assert!(up > 3.0 && up < 12.0, "4->40 speedup {up}");
        assert!(beyond > 1.2 && beyond < 4.0, "40->256 speedup {beyond}");
        assert!(up > beyond, "knee must exist");
    }

    #[test]
    fn gpu_power_rises_with_actors_and_perf_per_watt_improves() {
        let m = model();
        let lo = m.steady_state(4);
        let hi = m.steady_state(256);
        assert!(hi.power_w > lo.power_w);
        assert!(hi.perf_per_watt > lo.perf_per_watt);
        assert!(lo.power_w >= 70.0);
    }

    #[test]
    fn batch_size_grows_with_actors() {
        let m = model();
        assert!(m.steady_state(64).batch_size > m.steady_state(2).batch_size);
    }

    #[test]
    fn phase_shares_are_a_distribution_and_env_dominates_at_scale() {
        let m = model();
        for n in [4, 40, 256] {
            let p = m.phase_shares(n);
            let total = p.env + p.infer + p.train + p.replay;
            assert!((total - 1.0).abs() < 1e-9, "shares must sum to 1, n={n}");
            for s in [p.env, p.infer, p.train, p.replay] {
                assert!((0.0..=1.0).contains(&s));
            }
        }
        // The paper's Fig. 2 finding: env stepping is the dominant CPU
        // phase for Atari-class workloads.
        let p = m.phase_shares(40);
        assert!(
            p.env > p.infer && p.env > p.train,
            "env share {p:?} should dominate"
        );
    }

    #[test]
    fn sm_sweep_mild_then_cliff() {
        let m = model();
        let base = m.steady_state(40).env_rate;
        let half = m.with_sms(40).steady_state(40).env_rate;
        let tiny = m.with_sms(2).steady_state(40).env_rate;
        let slowdown_half = base / half;
        let slowdown_tiny = base / tiny;
        assert!(
            slowdown_half < 1.25,
            "halving SMs should be mild: {slowdown_half}"
        );
        assert!(
            slowdown_tiny > slowdown_half + 0.2,
            "2 SMs must hurt: {slowdown_tiny} vs {slowdown_half}"
        );
    }

    #[test]
    fn runtime_inverse_of_rate() {
        let m = model();
        let p = m.steady_state(16);
        let t = m.runtime_for(1_000_000, 16);
        assert!((t - 1_000_000.0 / p.env_rate).abs() < 1e-6);
    }

    #[test]
    fn cpu_gpu_ratio_metric() {
        let m = model();
        assert!((m.cpu_gpu_ratio() - 0.5).abs() < 1e-12);
        assert!((m.with_sms(40).cpu_gpu_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn envs_per_actor_raises_rate_and_occupancy_at_fixed_threads() {
        let m = model();
        let single = m.steady_state(4);
        let vec8 = m.with_envs_per_actor(8).steady_state(4);
        assert!(
            vec8.env_rate > 1.5 * single.env_rate,
            "8 envs/thread at 4 threads: {} vs {}",
            vec8.env_rate,
            single.env_rate
        );
        assert!(
            vec8.batch_size > single.batch_size,
            "occupancy {} vs {}",
            vec8.batch_size,
            single.batch_size
        );
    }

    #[test]
    fn vecenv_reaches_the_fig3_tail_with_far_fewer_threads() {
        // The paper pushes past the 40-thread knee by oversubscribing to
        // 256 single-env actor threads; a vecenv pool should land in the
        // same rate regime with an order of magnitude fewer threads.
        let m = model();
        let threads_256 = m.steady_state(256).env_rate;
        let vec_32x8 = m.with_envs_per_actor(8).steady_state(32).env_rate;
        assert!(
            vec_32x8 > 0.7 * threads_256,
            "32 threads x 8 envs = {vec_32x8} vs 256 threads = {threads_256}"
        );
    }

    #[test]
    fn envs_per_actor_one_is_the_identity() {
        let m = model();
        let a = m.steady_state(16);
        let b = m.with_envs_per_actor(1).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
    }

    #[test]
    fn pipeline_depth_one_is_the_identity() {
        let m = model().with_envs_per_actor(8);
        let a = m.steady_state(16);
        let b = m.with_pipeline_depth(1).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.rtt_s, b.rtt_s);
    }

    #[test]
    fn pipeline_depth_overlaps_env_work_with_inference() {
        // At few threads the cycle is latency-bound: splitting each
        // thread's 8 slots into 2 leapfrogging groups must raise the
        // rate, and the gain must not exceed the serialized/pipelined
        // critical-path ratio.
        let m = model().with_envs_per_actor(8);
        let serial = m.steady_state(4);
        let piped = m.with_pipeline_depth(2).steady_state(4);
        assert!(
            piped.env_rate > 1.05 * serial.env_rate,
            "depth 2 {} vs depth 1 {}",
            piped.env_rate,
            serial.env_rate
        );
        assert!(
            piped.env_rate < 2.5 * serial.env_rate,
            "pipelining cannot more than halve the cycle: {} vs {}",
            piped.env_rate,
            serial.env_rate
        );
    }

    #[test]
    fn pipeline_depth_clamps_to_envs_per_actor() {
        // depth > E cannot help: one slot per group is the limit.
        let m = model().with_envs_per_actor(4);
        let a = m.with_pipeline_depth(4).steady_state(8);
        let b = m.with_pipeline_depth(64).steady_state(8);
        assert_eq!(a.env_rate, b.env_rate);
    }

    #[test]
    fn insert_batch_is_identity_at_zero_ingest_cost() {
        let m = model().with_ingest_cost(1.0 / 40.0, 0.0);
        let a = m.steady_state(16);
        let b = m.with_insert_batch(16).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
    }

    #[test]
    fn insert_batch_amortizes_ingest_cost_when_actor_bound() {
        // Crank the per-sequence insert cost until it rivals the env
        // step itself (heavy contention regime): batching the ingest
        // must buy actor rate back, but never more than the serial
        // cycle-time ratio.
        let m = model().with_ingest_cost(0.5, 400e-6);
        let serial = m.steady_state(16);
        let batched = m.with_insert_batch(8).steady_state(16);
        assert!(
            batched.env_rate > 1.05 * serial.env_rate,
            "insert_batch 8 {} vs 1 {}",
            batched.env_rate,
            serial.env_rate
        );
        let t_env = m.cpu.step_cost_us() * 1e-6;
        let cycle_gain = (t_env + m.insert_overhead_s())
            / (t_env + m.with_insert_batch(8).insert_overhead_s());
        assert!(
            batched.env_rate <= serial.env_rate * cycle_gain * 1.05,
            "gain {} exceeds cycle ratio {cycle_gain}",
            batched.env_rate / serial.env_rate
        );
    }

    #[test]
    fn insert_overhead_amortizes_inversely_with_batch() {
        let m = model().with_ingest_cost(0.1, 10e-6);
        let t1 = m.insert_overhead_s();
        let t4 = m.with_insert_batch(4).insert_overhead_s();
        assert!((t1 - 1e-6).abs() < 1e-12);
        assert!((t4 - 0.25e-6).abs() < 1e-12);
    }

    #[test]
    fn insert_amortization_saturates_at_the_shard_count() {
        // A flush never takes fewer locks than min(k, shards): with 4
        // shards, batching 4 buys nothing (locks/seq stays 1.0, the
        // micro_replay counter shape) and batching 16 caps at 4/16.
        let m = model().with_ingest_cost(0.1, 10e-6).with_replay_shards(4);
        let t1 = m.insert_overhead_s();
        let t4 = m.with_insert_batch(4).insert_overhead_s();
        let t16 = m.with_insert_batch(16).insert_overhead_s();
        assert!((t1 - 1e-6).abs() < 1e-12);
        assert!((t4 - 1e-6).abs() < 1e-12, "k <= shards must not amortize");
        assert!((t16 - 0.25e-6).abs() < 1e-12);
    }

    #[test]
    fn batch_native_is_identity_at_zero_dispatch_cost() {
        // The default model carries env_dispatch_s = 0: toggling the
        // engine must change nothing (mirrors the execution-side
        // bit-for-bit equivalence).
        let m = model().with_envs_per_actor(8);
        let a = m.steady_state(16);
        let b = m.with_batch_native(true).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.rtt_s, b.rtt_s);
        assert_eq!(m.phase_shares(16), m.with_batch_native(true).phase_shares(16));
    }

    #[test]
    fn env_dispatch_term_amortizes_over_the_slot_group() {
        // Per-slot: every step pays the full per-call cost. Batch
        // native: one call per group of E/D rows, so each step carries
        // D/E of it; E = 1 makes the engines identical again.
        let m = model().with_env_dispatch(10e-6).with_envs_per_actor(8);
        assert!((m.env_dispatch_term() - 10e-6).abs() < 1e-18);
        let b = m.with_batch_native(true);
        assert!((b.env_dispatch_term() - 10e-6 / 8.0).abs() < 1e-18);
        let piped = b.with_pipeline_depth(2);
        assert!((piped.env_dispatch_term() - 10e-6 * 2.0 / 8.0).abs() < 1e-18);
        let single = m.with_envs_per_actor(1);
        assert_eq!(
            single.env_dispatch_term(),
            single.with_batch_native(true).env_dispatch_term()
        );
    }

    #[test]
    fn batch_native_amortizes_dispatch_cost_when_actor_bound() {
        // Crank the per-call cost until it rivals the env step itself:
        // the SoA engine must buy actor rate back, but never more than
        // the serial cycle-time ratio.
        let m = model().with_env_dispatch(400e-6).with_envs_per_actor(8);
        let per_slot = m.steady_state(16);
        let soa = m.with_batch_native(true).steady_state(16);
        assert!(
            soa.env_rate > 1.05 * per_slot.env_rate,
            "batch-native {} vs per-slot {}",
            soa.env_rate,
            per_slot.env_rate
        );
        let base = m.cpu.step_cost_us() * 1e-6 + m.insert_overhead_s();
        let cycle_gain = (base + m.env_dispatch_term())
            / (base + m.with_batch_native(true).env_dispatch_term());
        assert!(
            soa.env_rate <= per_slot.env_rate * cycle_gain * 1.05,
            "gain {} exceeds cycle ratio {cycle_gain}",
            soa.env_rate / per_slot.env_rate
        );
    }

    #[test]
    fn launch_size_rounds_up_the_bucket_ladder() {
        let m = model().with_batch_buckets(vec![1, 8, 32, 64]);
        assert_eq!(m.launch_size(1), 1);
        assert_eq!(m.launch_size(2), 8);
        assert_eq!(m.launch_size(8), 8);
        assert_eq!(m.launch_size(9), 32);
        assert_eq!(m.launch_size(33), 64);
        // Beyond the ladder (and with no ladder): exact shapes.
        assert_eq!(m.launch_size(70), 70);
        assert_eq!(model().launch_size(40), 40);
        assert!((m.padding_efficiency(5) - 5.0 / 8.0).abs() < 1e-12);
        assert!((model().padding_efficiency(5) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dense_bucket_ladder_is_the_exact_shape_identity() {
        // A bucket for every possible batch size pads nothing: the
        // steady state must be bit-identical to the no-ladder model.
        let m = model();
        let dense = m.with_batch_buckets((1..=m.max_batch).collect());
        let a = m.steady_state(16);
        let b = dense.steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.rtt_s, b.rtt_s);
    }

    #[test]
    fn coarse_buckets_pad_and_cost_rate_at_latency_bound_points() {
        // A single max_batch bucket pads every partial flush to the
        // cap: at few actors (small formed batches, latency-bound
        // cycle) the inflated launch time must cost env rate, and a
        // finer ladder must sit between the two.
        let m = model();
        let exact = m.steady_state(4);
        let fine = m
            .with_batch_buckets(vec![1, 2, 4, 8, 16, 32, 64])
            .steady_state(4);
        let coarse = m.with_batch_buckets(vec![64]).steady_state(4);
        assert!(
            coarse.env_rate < exact.env_rate,
            "padding to the cap must cost rate when latency-bound: \
             coarse {} vs exact {}",
            coarse.env_rate,
            exact.env_rate
        );
        assert!(
            fine.env_rate >= coarse.env_rate,
            "a finer ladder cannot pad more: fine {} vs coarse {}",
            fine.env_rate,
            coarse.env_rate
        );
        assert!(
            coarse.env_rate > 0.1 * exact.env_rate,
            "padding inflates one launch, it does not collapse the system: \
             {} vs {}",
            coarse.env_rate,
            exact.env_rate
        );
    }

    #[test]
    fn network_zero_is_the_identity() {
        // The defaults model the in-process deployment: the explicit
        // zero-network clone must be bit-identical, and the round-trip
        // helper must contribute exactly nothing.
        let m = model().with_envs_per_actor(8);
        assert_eq!(m.net_round_trip_s(8.0), 0.0);
        let a = m.steady_state(16);
        let b = m.with_network(0.0, 0.0, 0.0).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.rtt_s, b.rtt_s);
        // Bytes-per-row without a finite bandwidth is still free.
        let c = m.with_network(0.0, 1e6, 0.0).steady_state(16);
        assert_eq!(a.env_rate, c.env_rate);
        assert_eq!(a.rtt_s, c.rtt_s);
    }

    #[test]
    fn network_rtt_lowers_rate_when_latency_bound() {
        // Few threads, the cycle is latency-bound: a network round trip
        // on every submission must cost env rate, monotonically in the
        // latency, and surface in the actor-visible rtt.
        let m = model().with_envs_per_actor(8);
        let local = m.steady_state(4);
        let lan = m.with_network(200e-6, 0.0, 0.0).steady_state(4);
        let wan = m.with_network(5e-3, 0.0, 0.0).steady_state(4);
        assert!(
            lan.env_rate < local.env_rate,
            "200us rtt must cost rate: {} vs {}",
            lan.env_rate,
            local.env_rate
        );
        assert!(
            wan.env_rate < lan.env_rate,
            "5ms rtt must cost more: {} vs {}",
            wan.env_rate,
            lan.env_rate
        );
        assert!(wan.rtt_s > local.rtt_s + 4e-3);
        // Bandwidth term alone: serializing each submission's bytes on
        // a finite link must also cost rate.
        let thin = m.with_network(0.0, 100e3, 1e9).steady_state(4);
        assert!(
            thin.env_rate < local.env_rate,
            "100kB/row over 1GB/s must cost rate: {} vs {}",
            thin.env_rate,
            local.env_rate
        );
    }

    #[test]
    fn net_round_trip_combines_latency_and_transfer() {
        let m = model().with_network(1e-3, 1000.0, 1e6);
        // 8 rows * 1000 B / 1e6 B/s = 8 ms of transfer + 1 ms fixed.
        assert!((m.net_round_trip_s(8.0) - 9e-3).abs() < 1e-12);
        assert!((m.net_round_trip_s(0.0) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn faults_zero_is_the_identity() {
        // The defaults model the fault-free deployment: the explicit
        // zero-fault clone must be bit-identical, and the availability
        // factor must be exactly 1.
        let m = model().with_envs_per_actor(8);
        assert_eq!(m.fault_slowdown(), 1.0);
        let a = m.steady_state(16);
        let b = m.with_faults(0.0, 0.0).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.rtt_s, b.rtt_s);
        // A fault rate with a zero recovery cost is still free.
        let c = m.with_faults(0.5, 0.0).steady_state(16);
        assert_eq!(a.env_rate, c.env_rate);
        assert_eq!(a.rtt_s, c.rtt_s);
    }

    #[test]
    fn fault_recovery_lowers_rate_and_dilation_bounds_the_damage() {
        // Each fault stalls a thread for the recovery time, so useful
        // rate must fall, monotonically in the rate × recovery product
        // — and the availability dilation bounds how far it can fall
        // (a stall is not a collapse).
        let m = model().with_envs_per_actor(8);
        let clean = m.steady_state(4);
        let flaky = m.with_faults(0.5, 0.2).steady_state(4); // 10% lost
        let broken = m.with_faults(2.0, 0.5).steady_state(4); // 2x dilation
        assert!(
            flaky.env_rate < clean.env_rate,
            "0.5 faults/s x 200ms must cost rate: {} vs {}",
            flaky.env_rate,
            clean.env_rate
        );
        assert!(
            broken.env_rate < flaky.env_rate,
            "2 faults/s x 500ms must cost more: {} vs {}",
            broken.env_rate,
            flaky.env_rate
        );
        let dilation = m.with_faults(2.0, 0.5).fault_slowdown();
        assert!(
            broken.env_rate > clean.env_rate / dilation * 0.5,
            "a 2x dilation cannot collapse the system: {} vs clean {}",
            broken.env_rate,
            clean.env_rate
        );
    }

    #[test]
    fn reloads_zero_is_the_identity() {
        // The defaults model a reload-free run: the explicit zero-reload
        // clone must be bit-identical, and the availability factor must
        // be exactly 1.
        let m = model().with_envs_per_actor(8);
        assert_eq!(m.reload_slowdown(), 1.0);
        let a = m.steady_state(16);
        let b = m.with_reloads(0.0, 0.0).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.rtt_s, b.rtt_s);
        // A reload rate with a zero stall cost is still free.
        let c = m.with_reloads(0.1, 0.0).steady_state(16);
        assert_eq!(a.env_rate, c.env_rate);
    }

    #[test]
    fn reload_stalls_lower_rate_and_compose_with_faults() {
        // Admission pauses fleet-wide per reload: useful rate must fall
        // monotonically in the rate x stall product, and the reload and
        // fault terms compose multiplicatively (independent renewals).
        let m = model().with_envs_per_actor(8);
        let clean = m.steady_state(4);
        let light = m.with_reloads(0.01, 2.0).steady_state(4); // 2% lost
        let heavy = m.with_reloads(0.05, 4.0).steady_state(4); // 20% lost
        assert!(
            light.env_rate < clean.env_rate,
            "reload stalls must cost rate: {} vs {}",
            light.env_rate,
            clean.env_rate
        );
        assert!(
            heavy.env_rate < light.env_rate,
            "more reload stall must cost more: {} vs {}",
            heavy.env_rate,
            light.env_rate
        );
        let both = m.with_faults(0.5, 0.2).with_reloads(0.05, 4.0);
        assert!(
            (both.fault_slowdown() * both.reload_slowdown() - 1.1 * 1.2).abs() < 1e-12,
            "terms must compose multiplicatively"
        );
        let composed = both.steady_state(4);
        assert!(
            composed.env_rate < heavy.env_rate,
            "faults on top of reloads must cost more: {} vs {}",
            composed.env_rate,
            heavy.env_rate
        );
    }

    #[test]
    fn train_cycle_serializes_then_overlaps_learner_cpu_phases() {
        let m = model().with_learner_overhead(1e-3, 4e-3);
        let t_train = m.train_time();
        assert!((m.train_cycle() - (t_train + 5e-3)).abs() < 1e-12);
        let piped = m.with_prefetch_depth(2);
        assert!((piped.train_cycle() - t_train.max(5e-3)).abs() < 1e-12);
        assert!(piped.train_cycle() < m.train_cycle());
    }

    #[test]
    fn prefetch_depth_is_identity_without_learner_cpu_cost() {
        let m = model().with_learner_overhead(0.0, 0.0);
        let a = m.steady_state(16);
        let b = m.with_prefetch_depth(2).steady_state(16);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.batch_size, b.batch_size);
    }

    #[test]
    fn prefetch_depth_raises_rate_when_learner_bound() {
        // CPU-side assembly as heavy as the accelerator step and a
        // replay ratio aggressive enough that the learner cycle caps
        // the whole system: overlapping the CPU phases under the train
        // step must buy rate back — but never more than the
        // serial/overlapped cycle ratio (here exactly 2x).
        let t_train = model().train_time();
        let mut m = model().with_learner_overhead(0.0, t_train);
        m.train_per_env = 1.0 / (800.0 * t_train);
        let serial = m.steady_state(40);
        let piped = m.with_prefetch_depth(2).steady_state(40);
        assert!(
            piped.env_rate > 1.05 * serial.env_rate,
            "prefetch {} vs serial {}",
            piped.env_rate,
            serial.env_rate
        );
        let cycle_gain = m.train_cycle() / m.with_prefetch_depth(2).train_cycle();
        assert!(
            piped.env_rate <= serial.env_rate * cycle_gain * 1.05,
            "gain {} exceeds cycle ratio {cycle_gain}",
            piped.env_rate / serial.env_rate
        );
    }
}
