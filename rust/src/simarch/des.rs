//! Tick-driven discrete-event simulation of the SEED dataflow.
//!
//! Independent validation of the analytic fixed point in [`super::system`]:
//! actors, the batcher, the GPU queue, and the learner are simulated
//! explicitly on a small time quantum. Slower but assumption-light — the
//! integration tests assert the two agree on throughput within tolerance,
//! which guards both models against structural mistakes.

use super::system::SystemModel;

#[derive(Clone, Copy, Debug, PartialEq)]
enum ActorState {
    /// Remaining env work, in dedicated-core seconds.
    EnvWork(f64),
    /// Waiting in the batcher with submit timestamp.
    Pending(f64),
    /// In flight on the GPU.
    OnGpu,
    /// Reply in transit on the fleet transport until this timestamp
    /// (only entered when the model carries a non-zero network term).
    NetDelay(f64),
    /// Stalled recovering from an injected fault until the first
    /// timestamp, carrying the group's preserved remaining env work —
    /// 0 when the fault struck a pending submission, which is lost and
    /// resubmitted after recovery (only entered when the model carries
    /// a non-zero fault rate).
    Recovering(f64, f64),
}

/// DES results over the measurement window.
#[derive(Clone, Debug, Default)]
pub struct DesPoint {
    pub actors: usize,
    pub env_rate: f64,
    pub gpu_util: f64,
    pub mean_batch: f64,
    pub train_steps: u64,
}

/// Simulate `n` actor threads for `sim_seconds` (after an equal warmup)
/// with time quantum `dt`. Each thread drives `model.envs_per_actor`
/// environments vecenv-style, split into `model.pipeline_depth` slot
/// groups that leapfrog (the policy-layer pipeline): a group does E/D
/// serial env steps of CPU work, submits its E/D rows to the batcher,
/// and while those are in flight the thread's other groups keep
/// stepping. The simulation therefore tracks one agent per (thread,
/// group); agents of one thread share that thread's CPU throughput.
pub fn simulate(model: &SystemModel, n: usize, sim_seconds: f64, dt: f64) -> DesPoint {
    let e = model.envs_per_actor.max(1);
    // More groups than slots cannot help (matches the actor's clamp).
    let d = model.pipeline_depth.max(1).min(e);
    let rows_per_group = e as f64 / d as f64; // env steps per group cycle
    // Per-step CPU work includes the (amortized) replay-ingest share
    // and the per-call dispatch share (amortized over the slot group on
    // the batch-native engine), mirroring `SystemModel::steady_state`'s
    // t_env term so the two models stay structurally comparable on the
    // insert_batch and batch_native axes.
    let t_env = model.cpu.step_cost_us() * 1e-6
        + model.insert_overhead_s()
        + model.env_dispatch_term();
    let t_cycle_env = rows_per_group * t_env; // CPU work per group cycle
    let t_train = model.train_time();
    // A train job occupies the learner for the whole train cycle
    // (GPU step + CPU sample/assemble, overlapped when prefetching) but
    // keeps the GPU busy only for the t_train fraction of it — the DES
    // mirror of `SystemModel::train_cycle`. Granularity approximation:
    // the cycle is served on the single GPU queue, so the CPU-side
    // phases also delay queued *inference* batches, which the real
    // coordinator keeps serving; at the default sub-ms learner overhead
    // and paper replay ratios the bias is far inside the structural
    // tolerance the DES is used at (see the batcher note below for the
    // same trade), and modelling the learner as a second server would
    // need per-thread resume tracking.
    // Fleet-transport round trip per submission (DESIGN.md §14): 0 for
    // the in-process deployment, in which case the NetDelay state is
    // never entered and the simulation is bit-for-bit the seed path.
    let t_net = model.net_round_trip_s(rows_per_group);
    // Fault clocks (DESIGN.md §15): with a non-zero fault rate each
    // actor thread draws a fault every 1/rate seconds of wall-clock,
    // staggered across threads so recoveries do not synchronize. A
    // fault kills the thread's link: groups stepping env work stall in
    // place for the recovery time (their progress survives — the
    // ticket deadline resubmits the same observations), groups waiting
    // in the batcher lose the in-flight submission and resubmit it
    // after recovery, and a reply already in GPU service survives (the
    // scatter lands before the reconnect). Recovery consumes no CPU —
    // the thread is blocked on the transport, not working. At the
    // default rate 0 no clock exists and the simulation is bit-for-bit
    // the fault-free path.
    let fault_period = if model.fault_rate > 0.0 {
        1.0 / model.fault_rate
    } else {
        f64::INFINITY
    };
    let t_recover = model.fault_recovery_s.max(0.0);
    let mut next_fault: Vec<f64> = (0..n)
        .map(|t| fault_period * (t as f64 + 1.0) / n.max(1) as f64)
        .collect();
    // Reload clock (DESIGN.md §16): unlike faults, a hot-reload is one
    // GLOBAL event — the serving gate pauses admission fleet-wide while
    // the checkpoint swaps, so every thread's env stepping stalls at
    // once, while work already in the batcher or on the GPU keeps
    // draining (the drain phase completes in-flight tickets). At the
    // default rate 0 no clock exists and the simulation is bit-for-bit
    // the reload-free path.
    let reload_period = if model.reload_rate > 0.0 {
        1.0 / model.reload_rate
    } else {
        f64::INFINITY
    };
    let t_reload = model.reload_stall_s.max(0.0);
    let mut next_reload = reload_period;
    let mut reload_until = f64::NEG_INFINITY;
    let t_train_cycle = model.train_cycle().max(t_train);
    let train_busy_frac = if t_train_cycle > 0.0 {
        (t_train / t_train_cycle).min(1.0)
    } else {
        1.0
    };
    let train_every = if model.train_per_env > 0.0 {
        (1.0 / model.train_per_env).max(1.0)
    } else {
        f64::INFINITY
    };

    // Agent i is group (i % d) of thread (i / d).
    let mut agents = vec![ActorState::EnvWork(t_cycle_env); n * d];
    // Rows still waiting in the batcher per Pending agent (a group's
    // rows can be split across flushes — row-level packing).
    let mut pending_rows = vec![0.0f64; n * d];
    let mut now = 0.0f64;
    // GPU: FIFO queue of (is_train, agents released on completion, rows
    // of real work in the batch) + one in-flight job.
    let mut gpu_queue: std::collections::VecDeque<(bool, Vec<usize>, f64)> =
        std::collections::VecDeque::new();
    let mut gpu_inflight: Option<(f64, bool, Vec<usize>)> = None;

    let warmup = sim_seconds;
    let total = 2.0 * sim_seconds;
    let mut env_steps = 0.0f64;
    let mut env_steps_since_train = 0.0f64;
    let mut gpu_busy = 0.0f64;
    let mut batches = 0u64;
    let mut batch_items = 0.0f64;
    let mut train_steps = 0u64;
    let mut thread_groups_working = vec![0usize; n];

    while now < total {
        let measuring = now >= warmup;

        // 0) Network: release agents whose reply transit has elapsed.
        if t_net > 0.0 {
            for a in agents.iter_mut() {
                if let ActorState::NetDelay(until) = a {
                    if now >= *until {
                        *a = ActorState::EnvWork(t_cycle_env);
                    }
                }
            }
        }

        // 0b) Faults: release recovered agents, then stall due threads.
        if fault_period.is_finite() {
            for i in 0..agents.len() {
                if let ActorState::Recovering(until, rem) = agents[i] {
                    if now >= until {
                        agents[i] = if rem > 0.0 {
                            ActorState::EnvWork(rem)
                        } else {
                            // The lost submission goes back to the
                            // batcher; its env steps were already
                            // counted when the group finished stepping.
                            pending_rows[i] = rows_per_group;
                            ActorState::Pending(now)
                        };
                    }
                }
            }
            for t in 0..n {
                if now >= next_fault[t] {
                    next_fault[t] += fault_period;
                    for g in 0..d {
                        let i = t * d + g;
                        match agents[i] {
                            ActorState::EnvWork(rem) => {
                                agents[i] = ActorState::Recovering(now + t_recover, rem);
                            }
                            ActorState::Pending(_) => {
                                pending_rows[i] = 0.0;
                                agents[i] = ActorState::Recovering(now + t_recover, 0.0);
                            }
                            _ => {}
                        }
                    }
                }
            }
        }

        // 0c) Reloads: the global clock pauses every thread at once.
        if reload_period.is_finite() && now >= next_reload {
            next_reload += reload_period;
            reload_until = now + t_reload;
        }
        let reload_paused = now < reload_until;

        // 1) CPU: distribute capacity among env-working agents. The
        // hardware sees *threads* busy, not groups: a thread's working
        // groups serialize on it and split its share. A reload pause
        // freezes this stage fleet-wide (no env progress) while the
        // stages below keep draining.
        let working: Vec<usize> = agents
            .iter()
            .enumerate()
            .filter_map(|(i, s)| matches!(s, ActorState::EnvWork(_)).then_some(i))
            .collect();
        if !working.is_empty() && !reload_paused {
            thread_groups_working.fill(0);
            for &i in &working {
                thread_groups_working[i / d] += 1;
            }
            let threads_active =
                thread_groups_working.iter().filter(|&&g| g > 0).count();
            let per_thread = (model.cpu.capacity(threads_active)
                / threads_active.max(1) as f64)
                .min(1.0);
            for &i in &working {
                let share = per_thread / thread_groups_working[i / d] as f64 * dt;
                if let ActorState::EnvWork(rem) = &mut agents[i] {
                    *rem -= share;
                    if *rem <= 0.0 {
                        if measuring {
                            env_steps += rows_per_group;
                        }
                        env_steps_since_train += rows_per_group;
                        agents[i] = ActorState::Pending(now);
                        pending_rows[i] = rows_per_group;
                    }
                }
            }
        }

        // 2) Learner: enqueue a train job when enough env steps arrived.
        while env_steps_since_train >= train_every {
            env_steps_since_train -= train_every;
            gpu_queue.push_back((true, Vec::new(), 0.0));
        }

        // 3) Batcher: flush when full or the oldest submit times out.
        // Row-level packing, like the real batcher: rows are taken FIFO
        // (submit order) across group boundaries up to max_batch, so a
        // group's rows can be split across two flushes — the agent
        // stays Pending (original timestamp) until its last row is
        // taken, and returns to EnvWork when the batch holding that row
        // completes. This closes the old whole-group approximation's
        // ~2x occupancy under-report for non-divisor group sizes (e.g.
        // 40-row groups under a 64 cap), pinned by
        // `des_row_packing_fills_batches_for_non_divisor_groups`.
        let mut pending: Vec<(f64, usize)> = agents
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                ActorState::Pending(t) => Some((*t, i)),
                _ => None,
            })
            .collect();
        pending.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total_rows: f64 = pending.iter().map(|&(_, i)| pending_rows[i]).sum();
        let oldest = pending
            .first()
            .map(|&(t, _)| t)
            .unwrap_or(f64::INFINITY);
        let should_flush = total_rows >= model.max_batch as f64
            || (!pending.is_empty() && now - oldest >= model.batch_timeout_s);
        if should_flush {
            let mut capacity = model.max_batch as f64;
            let mut taken = 0.0f64;
            let mut released = Vec::new();
            for &(_, i) in &pending {
                if capacity <= 1e-12 {
                    break;
                }
                let take = pending_rows[i].min(capacity);
                capacity -= take;
                taken += take;
                if take >= pending_rows[i] - 1e-12 {
                    // Last row of this group taken: the agent rides this
                    // batch to the GPU.
                    pending_rows[i] = 0.0;
                    agents[i] = ActorState::OnGpu;
                    released.push(i);
                } else {
                    pending_rows[i] -= take;
                }
            }
            gpu_queue.push_back((false, released, taken));
        }

        // 4) GPU: complete and start jobs.
        if let Some((done_at, is_train, batch)) = &gpu_inflight {
            if now >= *done_at {
                if *is_train && measuring {
                    train_steps += 1;
                }
                for &i in batch {
                    agents[i] = if t_net > 0.0 {
                        ActorState::NetDelay(now + t_net)
                    } else {
                        ActorState::EnvWork(t_cycle_env)
                    };
                }
                gpu_inflight = None;
            }
        }
        if gpu_inflight.is_none() {
            if let Some((is_train, batch, rows_f)) = gpu_queue.pop_front() {
                let service = if is_train {
                    t_train_cycle
                } else {
                    // Row packing bounds every flush at max_batch rows,
                    // so each batch is one GPU call — launched at its
                    // padded AOT bucket shape (`launch_size`; exact when
                    // no ladder is set), the DES mirror of the analytic
                    // bucket-padding term.
                    let rows_f = rows_f.max(1.0);
                    let rows = (rows_f.round().max(1.0) as usize).min(model.max_batch);
                    if measuring {
                        batches += 1;
                        batch_items += rows_f;
                    }
                    model.infer_time(model.launch_size(rows))
                };
                gpu_inflight = Some((now + service, is_train, batch));
            }
        }
        if measuring {
            if let Some((_, is_train, _)) = &gpu_inflight {
                // A train job's CPU-side phases leave the GPU idle.
                gpu_busy += if *is_train { dt * train_busy_frac } else { dt };
            }
        }

        now += dt;
    }

    DesPoint {
        actors: n,
        env_rate: env_steps / sim_seconds,
        gpu_util: gpu_busy / sim_seconds,
        mean_batch: if batches > 0 {
            batch_items / batches as f64
        } else {
            0.0
        },
        train_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simarch::system::default_system;
    use crate::simarch::trace::{synthetic_paper_trace, synthetic_paper_train_trace};

    fn model() -> SystemModel {
        default_system(
            synthetic_paper_trace(1, 1, 64),
            synthetic_paper_train_trace(2, 80, 16),
        )
    }

    #[test]
    fn des_rate_scales_with_actors() {
        let m = model();
        let r4 = simulate(&m, 4, 0.25, 20e-6).env_rate;
        let r32 = simulate(&m, 32, 0.25, 20e-6).env_rate;
        assert!(r32 > 3.0 * r4, "r4={r4} r32={r32}");
    }

    #[test]
    fn des_agrees_with_analytic_model() {
        let m = model();
        for n in [8usize, 40] {
            let des = simulate(&m, n, 0.5, 10e-6);
            let ana = m.steady_state(n);
            let ratio = des.env_rate / ana.env_rate;
            assert!(
                (0.6..1.6).contains(&ratio),
                "n={n}: DES {} vs analytic {} (ratio {ratio})",
                des.env_rate,
                ana.env_rate
            );
        }
    }

    #[test]
    fn des_conservation_trains_proportional_to_steps() {
        let m = model();
        let p = simulate(&m, 16, 0.5, 10e-6);
        let expected = p.env_rate * 0.5 * m.train_per_env;
        assert!(
            (p.train_steps as f64) > 0.3 * expected
                && (p.train_steps as f64) < 3.0 * expected.max(1.0),
            "train {} vs expected {expected}",
            p.train_steps
        );
    }

    #[test]
    fn des_vecenv_raises_rate_and_tracks_analytic_model() {
        let m = model().with_envs_per_actor(8);
        let base = simulate(&model(), 4, 0.25, 20e-6);
        let vec = simulate(&m, 4, 0.25, 20e-6);
        assert!(
            vec.env_rate > 1.5 * base.env_rate,
            "vecenv DES rate {} vs single-env {}",
            vec.env_rate,
            base.env_rate
        );
        assert!(vec.mean_batch > base.mean_batch);
        let ana = m.steady_state(4);
        let ratio = vec.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "DES {} vs analytic {} (ratio {ratio})",
            vec.env_rate,
            ana.env_rate
        );
    }

    #[test]
    fn des_non_divisor_envs_per_actor_stays_within_tolerance() {
        // E = 40 does not divide max_batch = 64: the row-packing
        // batcher fills flushes across group boundaries (like the real
        // one), so DES occupancy approaches the cap just as the
        // analytic model's does. The two must agree structurally, and
        // batches must respect the hard cap.
        let m = model().with_envs_per_actor(40);
        let des = simulate(&m, 4, 0.25, 20e-6);
        let ana = m.steady_state(4);
        let ratio = des.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "e=40: DES {} vs analytic {} (ratio {ratio})",
            des.env_rate,
            ana.env_rate
        );
        assert!(
            des.mean_batch <= m.max_batch as f64 + 1e-9,
            "DES occupancy {} exceeds the max_batch cap {}",
            des.mean_batch,
            m.max_batch
        );
    }

    #[test]
    fn des_row_packing_fills_batches_for_non_divisor_groups() {
        // Regression pin for the old whole-group approximation: with
        // 40-row groups under a 64-row cap it could never form a batch
        // above 40 rows (~2x occupancy under-report at saturation).
        // Row-level packing must push the mean formed batch close to
        // the cap once the timeout is long enough that full flushes
        // dominate — while never exceeding it.
        let mut m = model().with_envs_per_actor(40);
        m.batch_timeout_s = 10e-3;
        let des = simulate(&m, 4, 0.25, 20e-6);
        assert!(
            des.mean_batch > 48.0,
            "row packing should fill batches past the 40-row group size: mean {} vs cap {}",
            des.mean_batch,
            m.max_batch
        );
        assert!(
            des.mean_batch <= m.max_batch as f64 + 1e-9,
            "occupancy {} exceeds the cap {}",
            des.mean_batch,
            m.max_batch
        );
    }

    #[test]
    fn des_batch_native_identity_and_amortized_gain() {
        // Zero dispatch cost: toggling the engine changes nothing (the
        // deterministic simulation must agree exactly). A heavy
        // per-call cost: the SoA engine's amortization must raise the
        // simulated rate, mirroring the analytic term.
        let base = model().with_envs_per_actor(8);
        let a = simulate(&base, 4, 0.25, 20e-6);
        let b = simulate(&base.with_batch_native(true), 4, 0.25, 20e-6);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.gpu_util, b.gpu_util);

        let costed = base.with_env_dispatch(400e-6);
        let per_slot = simulate(&costed, 4, 0.25, 20e-6);
        let soa = simulate(&costed.with_batch_native(true), 4, 0.25, 20e-6);
        assert!(
            soa.env_rate > per_slot.env_rate,
            "batch-native DES rate {} <= per-slot {}",
            soa.env_rate,
            per_slot.env_rate
        );
        let ana = costed.with_batch_native(true).steady_state(4);
        let ratio = soa.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "DES {} vs analytic {} (ratio {ratio})",
            soa.env_rate,
            ana.env_rate
        );
    }

    #[test]
    fn des_pipeline_depth_raises_rate_and_tracks_analytic_model() {
        // Few threads, many slots each: the cycle is latency-bound, so
        // leapfrogging two slot groups per thread must help, and the DES
        // must stay structurally close to the analytic overlap term.
        let base = model().with_envs_per_actor(8);
        let piped = base.with_pipeline_depth(2);
        let serial_des = simulate(&base, 4, 0.25, 20e-6);
        let piped_des = simulate(&piped, 4, 0.25, 20e-6);
        assert!(
            piped_des.env_rate > serial_des.env_rate,
            "depth 2 DES rate {} <= depth 1 {}",
            piped_des.env_rate,
            serial_des.env_rate
        );
        let ana = piped.steady_state(4);
        let ratio = piped_des.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "DES {} vs analytic {} (ratio {ratio})",
            piped_des.env_rate,
            ana.env_rate
        );
        assert!(
            piped_des.mean_batch <= base.max_batch as f64 + 1e-9,
            "pipelined occupancy {} exceeds cap",
            piped_des.mean_batch
        );
    }

    #[test]
    fn des_bucket_padding_identity_with_dense_ladder_and_cost_when_coarse() {
        // Dense ladder = exact shapes: the deterministic simulation must
        // agree bit-for-bit with the no-ladder model. A single-bucket
        // ladder pads every partial flush to the cap, so at few actors
        // (small flushes) the simulated rate must not improve — and the
        // padded run must stay structurally close to the analytic model
        // carrying the same term.
        let base = model();
        let dense = base.with_batch_buckets((1..=base.max_batch).collect());
        let a = simulate(&base, 4, 0.25, 20e-6);
        let b = simulate(&dense, 4, 0.25, 20e-6);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.gpu_util, b.gpu_util);

        let coarse = base.with_batch_buckets(vec![base.max_batch]);
        let padded = simulate(&coarse, 4, 0.25, 20e-6);
        assert!(
            padded.env_rate <= a.env_rate,
            "padding every flush to the cap cannot raise the rate: \
             padded {} vs exact {}",
            padded.env_rate,
            a.env_rate
        );
        let ana = coarse.steady_state(4);
        let ratio = padded.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "padded DES {} vs analytic {} (ratio {ratio})",
            padded.env_rate,
            ana.env_rate
        );
    }

    #[test]
    fn des_network_identity_at_zero_and_delay_costs_rate() {
        // Zero network terms (the default): the NetDelay state is never
        // entered, so the deterministic simulation must agree exactly
        // with the seed path. A real round-trip latency must cost
        // simulated rate at a latency-bound point, and stay structurally
        // close to the analytic model carrying the same term.
        let base = model().with_envs_per_actor(8);
        let a = simulate(&base, 4, 0.25, 20e-6);
        let b = simulate(&base.with_network(0.0, 0.0, 0.0), 4, 0.25, 20e-6);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.gpu_util, b.gpu_util);
        assert_eq!(a.mean_batch, b.mean_batch);

        let wan = base.with_network(5e-3, 0.0, 0.0);
        let delayed = simulate(&wan, 4, 0.25, 20e-6);
        assert!(
            delayed.env_rate < a.env_rate,
            "5ms rtt must cost DES rate: {} vs {}",
            delayed.env_rate,
            a.env_rate
        );
        let ana = wan.steady_state(4);
        let ratio = delayed.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "DES {} vs analytic {} (ratio {ratio})",
            delayed.env_rate,
            ana.env_rate
        );
    }

    #[test]
    fn des_fault_identity_at_zero_and_recovery_costs_rate() {
        // Zero fault rate (the default): no fault clock exists and the
        // Recovering state is never entered, so the deterministic
        // simulation must agree exactly with the fault-free path. A
        // real fault rate must cost simulated rate — threads stall for
        // the recovery time on every fault — and stay structurally
        // close to the analytic model carrying the same availability
        // term.
        let base = model().with_envs_per_actor(8);
        let a = simulate(&base, 4, 0.25, 20e-6);
        let b = simulate(&base.with_faults(0.0, 0.0), 4, 0.25, 20e-6);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.gpu_util, b.gpu_util);
        assert_eq!(a.mean_batch, b.mean_batch);

        // 20 faults/s x 20ms recovery: a 40% availability dilation.
        let flaky = base.with_faults(20.0, 0.02);
        let stalled = simulate(&flaky, 4, 0.25, 20e-6);
        assert!(
            stalled.env_rate < a.env_rate,
            "20 faults/s x 20ms recovery must cost DES rate: {} vs {}",
            stalled.env_rate,
            a.env_rate
        );
        let ana = flaky.steady_state(4);
        let ratio = stalled.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "DES {} vs analytic {} (ratio {ratio})",
            stalled.env_rate,
            ana.env_rate
        );
    }

    #[test]
    fn des_reload_identity_at_zero_and_stall_costs_rate() {
        // Zero reload rate (the default): no global clock exists and
        // the deterministic simulation must agree exactly with the
        // reload-free path. A real reload cadence must cost simulated
        // rate — every thread pauses at once while the checkpoint
        // swaps — and stay structurally close to the analytic model
        // carrying the same fleet-wide availability term.
        let base = model().with_envs_per_actor(8);
        let a = simulate(&base, 4, 0.25, 20e-6);
        let b = simulate(&base.with_reloads(0.0, 0.0), 4, 0.25, 20e-6);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.gpu_util, b.gpu_util);
        assert_eq!(a.mean_batch, b.mean_batch);

        // 8 reloads/s x 25ms stall: a 20% availability dilation.
        let reloading = base.with_reloads(8.0, 0.025);
        let stalled = simulate(&reloading, 4, 0.25, 20e-6);
        assert!(
            stalled.env_rate < a.env_rate,
            "8 reloads/s x 25ms stall must cost DES rate: {} vs {}",
            stalled.env_rate,
            a.env_rate
        );
        let ana = reloading.steady_state(4);
        let ratio = stalled.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "DES {} vs analytic {} (ratio {ratio})",
            stalled.env_rate,
            ana.env_rate
        );
    }

    #[test]
    fn des_gpu_util_bounded() {
        let m = model();
        let p = simulate(&m, 64, 0.25, 20e-6);
        assert!(p.gpu_util >= 0.0 && p.gpu_util <= 1.0);
        assert!(p.mean_batch >= 1.0);
    }

    #[test]
    fn des_prefetch_identity_without_learner_cost() {
        // With no CPU-side learner phases the train cycle is t_train at
        // either depth; the deterministic simulation must agree exactly.
        let base = model().with_learner_overhead(0.0, 0.0);
        let a = simulate(&base, 8, 0.25, 20e-6);
        let b = simulate(&base.with_prefetch_depth(2), 8, 0.25, 20e-6);
        assert_eq!(a.env_rate, b.env_rate);
        assert_eq!(a.gpu_util, b.gpu_util);
        assert_eq!(a.train_steps, b.train_steps);
    }

    #[test]
    fn des_prefetch_depth_raises_rate_when_learner_bound() {
        // Aggressive replay ratio + CPU-side assembly far heavier than
        // the accelerator step: train jobs dominate the queue, so
        // shortening the train cycle by overlapping the CPU phases must
        // raise the simulated env rate. Time scales are relative to the
        // trace's train time so the test holds for any trace magnitude.
        let t = model().train_time();
        let mut base = model().with_learner_overhead(0.0, 4.0 * t);
        base.train_per_env = 1.0 / 8.0;
        let piped = base.with_prefetch_depth(2);
        let sim = 100.0 * t;
        let dt = (t / 50.0).max(1e-6);
        let serial_des = simulate(&base, 4, sim, dt);
        let piped_des = simulate(&piped, 4, sim, dt);
        assert!(
            piped_des.env_rate > serial_des.env_rate,
            "prefetch DES rate {} <= serial {}",
            piped_des.env_rate,
            serial_des.env_rate
        );
        assert!(piped_des.train_steps >= serial_des.train_steps);
        // GPU-busy accounting discounts the CPU-side share of the cycle.
        assert!(serial_des.gpu_util <= 1.0 + 1e-9);
    }
}
