//! Tick-driven discrete-event simulation of the SEED dataflow.
//!
//! Independent validation of the analytic fixed point in [`super::system`]:
//! actors, the batcher, the GPU queue, and the learner are simulated
//! explicitly on a small time quantum. Slower but assumption-light — the
//! integration tests assert the two agree on throughput within tolerance,
//! which guards both models against structural mistakes.

use super::system::SystemModel;

#[derive(Clone, Copy, Debug, PartialEq)]
enum ActorState {
    /// Remaining env work, in dedicated-core seconds.
    EnvWork(f64),
    /// Waiting in the batcher with submit timestamp.
    Pending(f64),
    /// In flight on the GPU.
    OnGpu,
}

/// DES results over the measurement window.
#[derive(Clone, Debug, Default)]
pub struct DesPoint {
    pub actors: usize,
    pub env_rate: f64,
    pub gpu_util: f64,
    pub mean_batch: f64,
    pub train_steps: u64,
}

/// Simulate `n` actors for `sim_seconds` (after an equal warmup) with
/// time quantum `dt`.
pub fn simulate(model: &SystemModel, n: usize, sim_seconds: f64, dt: f64) -> DesPoint {
    let t_env = model.cpu.step_cost_us() * 1e-6;
    let t_train = model.train_time();
    let train_every = if model.train_per_env > 0.0 {
        (1.0 / model.train_per_env).max(1.0)
    } else {
        f64::INFINITY
    };

    let mut actors = vec![ActorState::EnvWork(t_env); n];
    let mut now = 0.0f64;
    // GPU: FIFO queue of (is_train, batch actors) + one in-flight job.
    let mut gpu_queue: std::collections::VecDeque<(bool, Vec<usize>)> =
        std::collections::VecDeque::new();
    let mut gpu_inflight: Option<(f64, bool, Vec<usize>)> = None;

    let warmup = sim_seconds;
    let total = 2.0 * sim_seconds;
    let mut env_steps = 0u64;
    let mut env_steps_since_train = 0.0f64;
    let mut gpu_busy = 0.0f64;
    let mut batches = 0u64;
    let mut batch_items = 0u64;
    let mut train_steps = 0u64;

    while now < total {
        let measuring = now >= warmup;

        // 1) CPU: distribute capacity among env-working actors.
        let working: Vec<usize> = actors
            .iter()
            .enumerate()
            .filter_map(|(i, s)| matches!(s, ActorState::EnvWork(_)).then_some(i))
            .collect();
        if !working.is_empty() {
            let cap = model.cpu.capacity(working.len());
            let per_actor = (cap / working.len() as f64).min(1.0) * dt;
            for &i in &working {
                if let ActorState::EnvWork(rem) = &mut actors[i] {
                    *rem -= per_actor;
                    if *rem <= 0.0 {
                        if measuring {
                            env_steps += 1;
                        }
                        env_steps_since_train += 1.0;
                        actors[i] = ActorState::Pending(now);
                    }
                }
            }
        }

        // 2) Learner: enqueue a train job when enough env steps arrived.
        while env_steps_since_train >= train_every {
            env_steps_since_train -= train_every;
            gpu_queue.push_back((true, Vec::new()));
        }

        // 3) Batcher: flush when full or the oldest submit times out.
        let pending: Vec<usize> = actors
            .iter()
            .enumerate()
            .filter_map(|(i, s)| matches!(s, ActorState::Pending(_)).then_some(i))
            .collect();
        let oldest = pending
            .iter()
            .filter_map(|&i| match actors[i] {
                ActorState::Pending(t) => Some(t),
                _ => None,
            })
            .fold(f64::INFINITY, f64::min);
        let should_flush = pending.len() >= model.max_batch
            || (!pending.is_empty() && now - oldest >= model.batch_timeout_s);
        if should_flush {
            let batch: Vec<usize> =
                pending.into_iter().take(model.max_batch).collect();
            for &i in &batch {
                actors[i] = ActorState::OnGpu;
            }
            gpu_queue.push_back((false, batch));
        }

        // 4) GPU: complete and start jobs.
        if let Some((done_at, is_train, batch)) = &gpu_inflight {
            if now >= *done_at {
                if *is_train && measuring {
                    train_steps += 1;
                }
                for &i in batch {
                    actors[i] = ActorState::EnvWork(t_env);
                }
                gpu_inflight = None;
            }
        }
        if gpu_inflight.is_none() {
            if let Some((is_train, batch)) = gpu_queue.pop_front() {
                let service = if is_train {
                    t_train
                } else {
                    model.infer_time(batch.len().max(1))
                };
                if measuring && !is_train {
                    batches += 1;
                    batch_items += batch.len() as u64;
                }
                gpu_inflight = Some((now + service, is_train, batch));
            }
        }
        if measuring && gpu_inflight.is_some() {
            gpu_busy += dt;
        }

        now += dt;
    }

    DesPoint {
        actors: n,
        env_rate: env_steps as f64 / sim_seconds,
        gpu_util: gpu_busy / sim_seconds,
        mean_batch: if batches > 0 {
            batch_items as f64 / batches as f64
        } else {
            0.0
        },
        train_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simarch::system::default_system;
    use crate::simarch::trace::{synthetic_paper_trace, synthetic_paper_train_trace};

    fn model() -> SystemModel {
        default_system(
            synthetic_paper_trace(1, 1, 64),
            synthetic_paper_train_trace(2, 80, 16),
        )
    }

    #[test]
    fn des_rate_scales_with_actors() {
        let m = model();
        let r4 = simulate(&m, 4, 0.25, 20e-6).env_rate;
        let r32 = simulate(&m, 32, 0.25, 20e-6).env_rate;
        assert!(r32 > 3.0 * r4, "r4={r4} r32={r32}");
    }

    #[test]
    fn des_agrees_with_analytic_model() {
        let m = model();
        for n in [8usize, 40] {
            let des = simulate(&m, n, 0.5, 10e-6);
            let ana = m.steady_state(n);
            let ratio = des.env_rate / ana.env_rate;
            assert!(
                (0.6..1.6).contains(&ratio),
                "n={n}: DES {} vs analytic {} (ratio {ratio})",
                des.env_rate,
                ana.env_rate
            );
        }
    }

    #[test]
    fn des_conservation_trains_proportional_to_steps() {
        let m = model();
        let p = simulate(&m, 16, 0.5, 10e-6);
        let expected = p.env_rate * 0.5 * m.train_per_env;
        assert!(
            (p.train_steps as f64) > 0.3 * expected
                && (p.train_steps as f64) < 3.0 * expected.max(1.0),
            "train {} vs expected {expected}",
            p.train_steps
        );
    }

    #[test]
    fn des_gpu_util_bounded() {
        let m = model();
        let p = simulate(&m, 64, 0.25, 20e-6);
        assert!(p.gpu_util >= 0.0 && p.gpu_util <= 1.0);
        assert!(p.mean_batch >= 1.0);
    }
}
