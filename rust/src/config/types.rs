//! Typed configuration for the whole stack, parsed from TOML (or built
//! programmatically by examples/benches). Every struct has defaults that
//! match DESIGN.md §11 (DGX-1 / V100 machine model + the paper's R2D2
//! hyper-parameters scaled to the CPU testbed).

use crate::util::json::Value;

#[derive(Debug)]
pub enum ConfigError {
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid(msg) => write!(f, "config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

fn get_f64(v: &Value, path: &str, default: f64) -> f64 {
    v.path(path).and_then(|x| x.as_f64()).unwrap_or(default)
}

fn get_usize(v: &Value, path: &str, default: usize) -> usize {
    v.path(path).and_then(|x| x.as_usize()).unwrap_or(default)
}

fn get_str(v: &Value, path: &str, default: &str) -> String {
    v.path(path)
        .and_then(|x| x.as_str())
        .unwrap_or(default)
        .to_string()
}

fn get_bool(v: &Value, path: &str, default: bool) -> bool {
    v.path(path).and_then(|x| x.as_bool()).unwrap_or(default)
}

// ---------------------------------------------------------------------------
// Environment
// ---------------------------------------------------------------------------

/// Environment suite settings (shared by real execution and the DES model).
#[derive(Clone, Debug, PartialEq)]
pub struct EnvConfig {
    /// Registered environment name: grid_pong | breakout | catch | nav_maze.
    pub name: String,
    /// Frame-stack depth (channels of the observation).
    pub frame_stack: usize,
    /// ALE-style sticky-action probability.
    pub sticky_action_prob: f64,
    /// Maximum episode length before truncation.
    pub max_episode_len: usize,
    /// Artificial per-step CPU cost in microseconds (0 = raw env speed).
    /// Calibrates actor-side load to the Atari-frame regime on this host.
    pub step_cost_us: u64,
    /// Environment RNG base seed.
    pub seed: u64,
    /// Step each actor's E slots through the batch-native SoA engine
    /// (`env::soa`): struct-of-arrays state and one vectorized
    /// frame-stack shift per call instead of E per-slot deque
    /// rotations. false (default) = the per-slot `Wrapped` path; the
    /// two are bit-for-bit equivalent (property + e2e tests), so this
    /// knob changes cost only.
    pub batch_native: bool,
}

impl Default for EnvConfig {
    fn default() -> Self {
        Self {
            name: "grid_pong".into(),
            frame_stack: 4,
            sticky_action_prob: 0.25,
            max_episode_len: 2_000,
            step_cost_us: 0,
            seed: 2020,
            batch_native: false,
        }
    }
}

impl EnvConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            name: get_str(v, "env.name", &d.name),
            frame_stack: get_usize(v, "env.frame_stack", d.frame_stack),
            sticky_action_prob: get_f64(
                v,
                "env.sticky_action_prob",
                d.sticky_action_prob,
            ),
            max_episode_len: get_usize(v, "env.max_episode_len", d.max_episode_len),
            step_cost_us: get_f64(v, "env.step_cost_us", d.step_cost_us as f64)
                as u64,
            seed: get_f64(v, "env.seed", d.seed as f64) as u64,
            batch_native: get_bool(v, "env.batch_native", d.batch_native),
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator (SEED-style central inference)
// ---------------------------------------------------------------------------

/// Inference batcher policy.
#[derive(Clone, Debug, PartialEq)]
pub struct BatcherConfig {
    /// Hard upper bound on a batch (must match an AOT'd infer_b{N}).
    pub max_batch: usize,
    /// Flush a partial batch after this timeout.
    pub timeout_us: u64,
    /// Available AOT batch sizes (ascending); requests are padded up to the
    /// smallest size >= the pending count.
    pub batch_sizes: Vec<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            timeout_us: 500,
            batch_sizes: vec![1, 8, 32, 64],
        }
    }
}

impl BatcherConfig {
    /// Launch shape for a flush of `rows`: the smallest configured
    /// bucket that fits (the padded-AOT ladder; DESIGN.md §5). A
    /// validated config always has a bucket >= any flush (`max_batch`
    /// is the largest bucket and flushes never exceed it); the
    /// fallback is defensive. `SystemModel::launch_size` mirrors this
    /// rule on the simulator side (pinned by a unit test below).
    pub fn launch_size(&self, rows: usize) -> usize {
        self.batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= rows)
            .unwrap_or(self.max_batch)
    }

    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        let batch_sizes = v
            .path("batcher.batch_sizes")
            .and_then(|x| x.as_arr())
            .map(|xs| xs.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or(d.batch_sizes.clone());
        Self {
            max_batch: get_usize(v, "batcher.max_batch", d.max_batch),
            timeout_us: get_f64(v, "batcher.timeout_us", d.timeout_us as f64)
                as u64,
            batch_sizes,
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.batch_sizes.is_empty() {
            return Err(ConfigError::Invalid("batch_sizes empty".into()));
        }
        if self.batch_sizes[0] == 0 {
            return Err(ConfigError::Invalid(
                "batch_sizes must be >= 1 (each is a compiled launch shape)".into(),
            ));
        }
        if !self.batch_sizes.windows(2).all(|w| w[0] < w[1]) {
            return Err(ConfigError::Invalid(
                "batch_sizes must be strictly ascending".into(),
            ));
        }
        if *self.batch_sizes.last().unwrap() != self.max_batch {
            return Err(ConfigError::Invalid(
                "max_batch must equal the largest batch size".into(),
            ));
        }
        Ok(())
    }
}

/// Actor pool settings.
#[derive(Clone, Debug, PartialEq)]
pub struct ActorConfig {
    pub num_actors: usize,
    /// Environments driven in lockstep by each actor thread (the vecenv
    /// knob). 1 = the paper's one-env-per-thread baseline; larger values
    /// raise environments-in-flight without consuming more CPU threads.
    pub envs_per_actor: usize,
    /// Software-pipeline depth of the actor loop: the thread's env slots
    /// are split into this many groups, and env stepping for one group
    /// overlaps the in-flight inference of the others (policy layer,
    /// DESIGN.md §5). 1 = the seed's fully serialized loop (bit-for-bit);
    /// values above `envs_per_actor` clamp to it.
    pub pipeline_depth: usize,
    /// Ape-X/R2D2 per-actor epsilon: eps_i = base^(1 + i/(N-1) * alpha).
    /// With vecenv the schedule spans all num_actors * envs_per_actor
    /// environment slots.
    pub epsilon_base: f64,
    pub epsilon_alpha: f64,
    /// Evaluation actors use epsilon 0 (not used in training flow).
    pub num_eval_actors: usize,
}

impl Default for ActorConfig {
    fn default() -> Self {
        Self {
            num_actors: 8,
            envs_per_actor: 1,
            pipeline_depth: 1,
            epsilon_base: 0.4,
            epsilon_alpha: 7.0,
            num_eval_actors: 0,
        }
    }
}

impl ActorConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            num_actors: get_usize(v, "actors.num_actors", d.num_actors),
            envs_per_actor: get_usize(v, "actors.envs_per_actor", d.envs_per_actor),
            pipeline_depth: get_usize(v, "actors.pipeline_depth", d.pipeline_depth),
            epsilon_base: get_f64(v, "actors.epsilon_base", d.epsilon_base),
            epsilon_alpha: get_f64(v, "actors.epsilon_alpha", d.epsilon_alpha),
            num_eval_actors: get_usize(
                v,
                "actors.num_eval_actors",
                d.num_eval_actors,
            ),
        }
    }

    /// Environment slots across the whole pool.
    pub fn total_envs(&self) -> usize {
        self.num_actors * self.envs_per_actor
    }
}

/// Prioritized replay buffer settings (the `[replay]` table). The
/// buffer itself lives in `replay::SequenceReplay`; these are the knobs
/// the coordinator builds it from.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayBufferConfig {
    /// Total ring capacity in sequences (striped across shards).
    pub capacity: usize,
    /// Priority-sampling exponent alpha (0 = uniform).
    pub alpha: f64,
    /// Floor for updated priorities so nothing becomes unsampleable.
    pub min_priority: f64,
    /// Independent ring+sum-tree shards, each behind its own mutex;
    /// must divide `capacity`. 1 = the classic single-mutex buffer.
    pub shards: usize,
    /// Sequences each actor's ingest queue buffers per replay flush
    /// (grouped by shard: one flush takes each shard lock at most
    /// once). 1 = the seed's flush-per-sequence path, bit-for-bit;
    /// must be <= capacity.
    pub insert_batch: usize,
    /// Recycle emitted sequence slabs through a shared `SequencePool`
    /// (replay evictions and learner-released batches feed it). false =
    /// the seed's allocate-per-sequence behavior; the emitted values
    /// are identical either way.
    pub pool: bool,
}

impl Default for ReplayBufferConfig {
    fn default() -> Self {
        Self {
            capacity: 4_096,
            alpha: 0.9,
            min_priority: 1e-3,
            shards: 1,
            insert_batch: 1,
            pool: true,
        }
    }
}

impl ReplayBufferConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            capacity: get_usize(v, "replay.capacity", d.capacity),
            alpha: get_f64(v, "replay.alpha", d.alpha),
            min_priority: get_f64(v, "replay.min_priority", d.min_priority),
            shards: get_usize(v, "replay.shards", d.shards),
            insert_batch: get_usize(v, "replay.insert_batch", d.insert_batch),
            pool: get_bool(v, "replay.pool", d.pool),
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.capacity == 0 {
            return Err(ConfigError::Invalid(
                "replay.capacity must be > 0".into(),
            ));
        }
        if self.alpha < 0.0 {
            return Err(ConfigError::Invalid("replay.alpha must be >= 0".into()));
        }
        if self.min_priority <= 0.0 {
            return Err(ConfigError::Invalid(
                "replay.min_priority must be > 0".into(),
            ));
        }
        if self.shards == 0 {
            return Err(ConfigError::Invalid("replay.shards must be > 0".into()));
        }
        if self.shards > self.capacity
            || self.capacity / self.shards * self.shards != self.capacity
        {
            return Err(ConfigError::Invalid(
                "replay.shards must divide replay.capacity".into(),
            ));
        }
        if self.insert_batch == 0 {
            return Err(ConfigError::Invalid(
                "replay.insert_batch must be > 0 (1 = unbatched)".into(),
            ));
        }
        if self.insert_batch > self.capacity {
            return Err(ConfigError::Invalid(
                "replay.insert_batch must be <= replay.capacity".into(),
            ));
        }
        Ok(())
    }
}

/// Learner settings (R2D2).
#[derive(Clone, Debug, PartialEq)]
pub struct LearnerConfig {
    pub train_batch: usize,
    /// Minimum sequences buffered before training starts.
    pub min_replay: usize,
    /// Copy online -> target params every N learner steps.
    pub target_update_interval: usize,
    /// Max learner steps for a run (examples override).
    pub max_steps: usize,
    /// Split-phase learner pipeline depth: batches sampled + assembled
    /// ahead of the train step (1 = the serialized seed loop; 2 = one
    /// batch prefetched while the backend trains the previous one).
    pub prefetch_depth: usize,
    /// Sequence replay: burn-in + unroll must match the AOT'd train graph.
    pub burn_in: usize,
    pub unroll_len: usize,
    /// Adjacent-sequence overlap when slicing trajectories.
    pub seq_overlap: usize,
    pub gamma: f64,
    pub n_step: usize,
}

impl Default for LearnerConfig {
    fn default() -> Self {
        Self {
            train_batch: 16,
            min_replay: 64,
            target_update_interval: 100,
            max_steps: 200,
            prefetch_depth: 1,
            burn_in: 5,
            unroll_len: 15,
            seq_overlap: 10,
            gamma: 0.997,
            n_step: 3,
        }
    }
}

impl LearnerConfig {
    pub fn seq_len(&self) -> usize {
        self.burn_in + self.unroll_len
    }

    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            train_batch: get_usize(v, "learner.train_batch", d.train_batch),
            min_replay: get_usize(v, "learner.min_replay", d.min_replay),
            target_update_interval: get_usize(
                v,
                "learner.target_update_interval",
                d.target_update_interval,
            ),
            max_steps: get_usize(v, "learner.max_steps", d.max_steps),
            prefetch_depth: get_usize(
                v,
                "learner.prefetch_depth",
                d.prefetch_depth,
            ),
            burn_in: get_usize(v, "learner.burn_in", d.burn_in),
            unroll_len: get_usize(v, "learner.unroll_len", d.unroll_len),
            seq_overlap: get_usize(v, "learner.seq_overlap", d.seq_overlap),
            gamma: get_f64(v, "learner.gamma", d.gamma),
            n_step: get_usize(v, "learner.n_step", d.n_step),
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.seq_overlap >= self.seq_len() {
            return Err(ConfigError::Invalid(
                "seq_overlap must be < seq_len".into(),
            ));
        }
        if self.min_replay < self.train_batch {
            return Err(ConfigError::Invalid(
                "min_replay must be >= train_batch".into(),
            ));
        }
        if self.prefetch_depth == 0 {
            return Err(ConfigError::Invalid(
                "prefetch_depth must be > 0 (1 = serialized)".into(),
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// simarch machine model (DESIGN.md §11)
// ---------------------------------------------------------------------------

/// V100-class GPU timing model parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct GpuModelConfig {
    pub num_sms: usize,
    pub clock_ghz: f64,
    /// FP32 FLOPs per SM per clock (V100: 64 FMA lanes x 2).
    pub flops_per_sm_clk: f64,
    /// HBM2 bandwidth, GB/s.
    pub dram_bw_gbps: f64,
    /// DRAM load-to-use latency, ns.
    pub dram_latency_ns: f64,
    /// L2 size (bytes) and bandwidth (GB/s).
    pub l2_bytes: usize,
    pub l2_bw_gbps: f64,
    /// Kernel launch overhead, us (CUDA ~3-8us; visible at small batches).
    pub launch_overhead_us: f64,
    /// Max thread-blocks' worth of parallelism one SM can overlap (used by
    /// the occupancy/tail model).
    pub threads_per_sm: usize,
}

impl Default for GpuModelConfig {
    fn default() -> Self {
        // NVIDIA V100 (SXM2): 80 SMs @ 1.53 GHz, 15.7 TF fp32, 900 GB/s.
        Self {
            num_sms: 80,
            clock_ghz: 1.53,
            flops_per_sm_clk: 128.0,
            dram_bw_gbps: 900.0,
            dram_latency_ns: 450.0,
            l2_bytes: 6 << 20,
            l2_bw_gbps: 2_200.0,
            launch_overhead_us: 2.5,
            threads_per_sm: 2_048,
        }
    }
}

impl GpuModelConfig {
    /// Peak fp32 throughput in FLOP/s.
    pub fn peak_flops(&self) -> f64 {
        self.num_sms as f64 * self.clock_ghz * 1e9 * self.flops_per_sm_clk
    }

    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            num_sms: get_usize(v, "gpu.num_sms", d.num_sms),
            clock_ghz: get_f64(v, "gpu.clock_ghz", d.clock_ghz),
            flops_per_sm_clk: get_f64(v, "gpu.flops_per_sm_clk", d.flops_per_sm_clk),
            dram_bw_gbps: get_f64(v, "gpu.dram_bw_gbps", d.dram_bw_gbps),
            dram_latency_ns: get_f64(v, "gpu.dram_latency_ns", d.dram_latency_ns),
            l2_bytes: get_usize(v, "gpu.l2_bytes", d.l2_bytes),
            l2_bw_gbps: get_f64(v, "gpu.l2_bw_gbps", d.l2_bw_gbps),
            launch_overhead_us: get_f64(
                v,
                "gpu.launch_overhead_us",
                d.launch_overhead_us,
            ),
            threads_per_sm: get_usize(v, "gpu.threads_per_sm", d.threads_per_sm),
        }
    }
}

/// Host CPU model (actor-side).
#[derive(Clone, Debug, PartialEq)]
pub struct CpuModelConfig {
    /// Hardware threads (DGX-1: 20 cores x 2 SMT = 40).
    pub hw_threads: usize,
    /// Mean env-step latency on one dedicated thread, microseconds.
    pub env_step_us: f64,
    /// Agent-side non-env work per step (obs encode, queueing), us.
    pub actor_overhead_us: f64,
    /// Context-switch penalty when actors oversubscribe threads, us.
    pub ctx_switch_us: f64,
    /// SMT efficiency: throughput factor of 2 threads sharing a core.
    pub smt_efficiency: f64,
}

impl Default for CpuModelConfig {
    fn default() -> Self {
        // E5-2698 v4 running ALE-class envs: ~125 us per 4-frame env step
        // (≈8k env-frames/s/core), measured regime from the SEED-RL paper.
        Self {
            hw_threads: 40,
            env_step_us: 125.0,
            actor_overhead_us: 15.0,
            ctx_switch_us: 5.0,
            smt_efficiency: 0.65,
        }
    }
}

impl CpuModelConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            hw_threads: get_usize(v, "cpu.hw_threads", d.hw_threads),
            env_step_us: get_f64(v, "cpu.env_step_us", d.env_step_us),
            actor_overhead_us: get_f64(
                v,
                "cpu.actor_overhead_us",
                d.actor_overhead_us,
            ),
            ctx_switch_us: get_f64(v, "cpu.ctx_switch_us", d.ctx_switch_us),
            smt_efficiency: get_f64(v, "cpu.smt_efficiency", d.smt_efficiency),
        }
    }
}

/// GPU power model (Fig. 3 right axis).
#[derive(Clone, Debug, PartialEq)]
pub struct PowerModelConfig {
    /// Idle draw, W (paper: ≈70 W at low utilization).
    pub idle_w: f64,
    /// TDP, W (V100: 300).
    pub max_w: f64,
    /// Fraction of dynamic power attributed to SM activity (rest: memory).
    pub sm_dynamic_frac: f64,
    /// Exponent of the utilization->power curve (measured GPUs are
    /// sub-linear: high power at moderate utilization).
    pub util_exponent: f64,
}

impl Default for PowerModelConfig {
    fn default() -> Self {
        Self {
            idle_w: 70.0,
            max_w: 300.0,
            sm_dynamic_frac: 0.6,
            util_exponent: 0.8,
        }
    }
}

impl PowerModelConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            idle_w: get_f64(v, "power.idle_w", d.idle_w),
            max_w: get_f64(v, "power.max_w", d.max_w),
            sm_dynamic_frac: get_f64(v, "power.sm_dynamic_frac", d.sm_dynamic_frac),
            util_exponent: get_f64(v, "power.util_exponent", d.util_exponent),
        }
    }
}

/// Telemetry: span tracing + registry sampling (all off by default; the
/// disabled path is bit-for-bit and allocation-identical to an
/// uninstrumented run).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// Background sampler period for the JSONL time-series.
    pub snapshot_interval_ms: usize,
    /// Spans retained per instrumented thread (ring; oldest overwritten).
    pub trace_capacity: usize,
    /// Chrome trace-event JSON output path; empty = span tracing off.
    pub trace_out: String,
    /// JSONL metrics time-series output path; empty = sampler off.
    pub metrics_out: String,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self {
            snapshot_interval_ms: 200,
            trace_capacity: 16_384,
            trace_out: String::new(),
            metrics_out: String::new(),
        }
    }
}

impl TelemetryConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            snapshot_interval_ms: get_usize(
                v,
                "telemetry.snapshot_interval_ms",
                d.snapshot_interval_ms,
            ),
            trace_capacity: get_usize(
                v,
                "telemetry.trace_capacity",
                d.trace_capacity,
            ),
            trace_out: get_str(v, "telemetry.trace_out", &d.trace_out),
            metrics_out: get_str(v, "telemetry.metrics_out", &d.metrics_out),
        }
    }

    pub fn trace_enabled(&self) -> bool {
        !self.trace_out.is_empty()
    }

    pub fn sampler_enabled(&self) -> bool {
        !self.metrics_out.is_empty()
    }

    pub fn enabled(&self) -> bool {
        self.trace_enabled() || self.sampler_enabled()
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.snapshot_interval_ms == 0 {
            return Err(ConfigError::Invalid(
                "telemetry.snapshot_interval_ms must be > 0".into(),
            ));
        }
        if self.trace_capacity == 0 {
            return Err(ConfigError::Invalid(
                "telemetry.trace_capacity must be > 0".into(),
            ));
        }
        Ok(())
    }
}

/// Fleet transport: the distributed actor data plane (`rlarch serve` /
/// `rlarch actor --connect`; DESIGN.md §14). Both addresses empty (the
/// default) = single-process mode, bit-for-bit the seed path — the
/// transport layer is never constructed.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    /// Coordinator listen address (`tcp:host:port`, `host:port`, or
    /// `uds:/path`). Empty = do not serve.
    pub listen: String,
    /// Worker connect address (same forms). Empty = in-process actors.
    pub connect: String,
    /// Per-connection in-flight row budget on the server; submissions
    /// beyond it are shed with a retryable error reply
    /// (`fleet.shed_rows`) instead of queuing without bound.
    pub max_inflight_rows: usize,
    /// Dial attempts a worker makes beyond the first (connect and
    /// reconnect) before giving up.
    pub connect_retries: usize,
    /// Initial reconnect backoff in milliseconds (doubles per attempt,
    /// capped at 2 s); also the pause before a shed submission retries.
    pub backoff_ms: u64,
    /// Client heartbeat period: an idle-waiting client sends a `Ping`
    /// every this many ms so the server sees it as live. 0 (default) =
    /// no heartbeats — bit-for-bit the PR 8 wire stream.
    pub heartbeat_interval_ms: u64,
    /// Liveness window: the server reaps an infer connection with no
    /// complete frame for this many ms (failing its in-flight tickets
    /// with attribution), and the client arms a per-ticket deadline
    /// floored at this value (seeded from the `fleet.rtt_seconds`
    /// EWMA) that reconnects-and-resubmits instead of hanging. 0
    /// (default) = never reap, never time out — the PR 8 behavior.
    pub liveness_timeout_ms: u64,
    /// Panicked actor threads a worker restarts (with backoff) before
    /// reporting the actor as failed (`fleet.actor_restarts` counts
    /// every restart).
    pub actor_restart_budget: usize,
    /// Coordinator checkpoint directory: empty (default) = no
    /// snapshots. With a directory, `run_serve` snapshots learner
    /// progress every `checkpoint_every` steps and resumes from the
    /// latest snapshot on restart (bumping the handshake generation).
    pub checkpoint_dir: String,
    /// Learner steps between snapshots (when `checkpoint_dir` is set).
    pub checkpoint_every: u64,
    /// Bound on how long a hot-reload or graceful shutdown waits for
    /// in-flight tickets to drain before force-proceeding (stragglers
    /// are failed with an attributed error; `serve.drain_timeouts`).
    pub drain_timeout_ms: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            listen: String::new(),
            connect: String::new(),
            max_inflight_rows: 4_096,
            connect_retries: 40,
            backoff_ms: 50,
            heartbeat_interval_ms: 0,
            liveness_timeout_ms: 0,
            actor_restart_budget: 2,
            checkpoint_dir: String::new(),
            checkpoint_every: 25,
            drain_timeout_ms: 2_000,
        }
    }
}

impl FleetConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            listen: get_str(v, "fleet.listen", &d.listen),
            connect: get_str(v, "fleet.connect", &d.connect),
            max_inflight_rows: get_usize(
                v,
                "fleet.max_inflight_rows",
                d.max_inflight_rows,
            ),
            connect_retries: get_usize(
                v,
                "fleet.connect_retries",
                d.connect_retries,
            ),
            backoff_ms: get_f64(v, "fleet.backoff_ms", d.backoff_ms as f64)
                as u64,
            heartbeat_interval_ms: get_f64(
                v,
                "fleet.heartbeat_interval_ms",
                d.heartbeat_interval_ms as f64,
            ) as u64,
            liveness_timeout_ms: get_f64(
                v,
                "fleet.liveness_timeout_ms",
                d.liveness_timeout_ms as f64,
            ) as u64,
            actor_restart_budget: get_usize(
                v,
                "fleet.actor_restart_budget",
                d.actor_restart_budget,
            ),
            checkpoint_dir: get_str(v, "fleet.checkpoint_dir", &d.checkpoint_dir),
            checkpoint_every: get_f64(
                v,
                "fleet.checkpoint_every",
                d.checkpoint_every as f64,
            ) as u64,
            drain_timeout_ms: get_f64(
                v,
                "fleet.drain_timeout_ms",
                d.drain_timeout_ms as f64,
            ) as u64,
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_inflight_rows == 0 {
            return Err(ConfigError::Invalid(
                "fleet.max_inflight_rows must be > 0".into(),
            ));
        }
        if self.backoff_ms == 0 {
            return Err(ConfigError::Invalid(
                "fleet.backoff_ms must be > 0".into(),
            ));
        }
        if self.heartbeat_interval_ms > 0
            && self.liveness_timeout_ms > 0
            && self.liveness_timeout_ms <= self.heartbeat_interval_ms
        {
            return Err(ConfigError::Invalid(
                "fleet.liveness_timeout_ms must exceed heartbeat_interval_ms \
                 (a healthy client must fit a ping inside the window)"
                    .into(),
            ));
        }
        if !self.checkpoint_dir.is_empty() && self.checkpoint_every == 0 {
            return Err(ConfigError::Invalid(
                "fleet.checkpoint_every must be > 0 when checkpoint_dir is set"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Deterministic fault injection (`[faults]`; DESIGN.md §15). All rates
/// zero and `panic_actor < 0` (the default) = the plan is never
/// constructed and every path is bit-for-bit the fault-free one —
/// pinned by the PR 9 equivalence test. Rates are per-frame (or
/// per-infer-call for `stall_rate`) Bernoulli probabilities drawn from
/// a PCG stream seeded by `seed`, so a given plan replays exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Root seed of the fault plan's PCG streams.
    pub seed: u64,
    /// Probability a received frame is silently dropped (the client's
    /// ticket deadline is what notices).
    pub drop_rate: f64,
    /// Probability a received frame is delayed by `delay_ms`.
    pub delay_rate: f64,
    pub delay_ms: u64,
    /// Probability a received frame is truncated before parsing
    /// (always rejected: counted in `fleet.bad_frames`).
    pub truncate_rate: f64,
    /// Probability a received frame's header magic is corrupted
    /// (always rejected: counted in `fleet.bad_frames`).
    pub corrupt_rate: f64,
    /// Probability a received frame kills its connection outright.
    pub kill_rate: f64,
    /// Probability one mock inference call stalls for `stall_ms`.
    pub stall_rate: f64,
    pub stall_ms: u64,
    /// Fleet-global actor id whose thread panics (-1 = none).
    pub panic_actor: i64,
    /// Submit round at which that actor panics (one-shot).
    pub panic_at_step: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            seed: 2020,
            drop_rate: 0.0,
            delay_rate: 0.0,
            delay_ms: 5,
            truncate_rate: 0.0,
            corrupt_rate: 0.0,
            kill_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: 20,
            panic_actor: -1,
            panic_at_step: 3,
        }
    }
}

impl FaultsConfig {
    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            seed: get_f64(v, "faults.seed", d.seed as f64) as u64,
            drop_rate: get_f64(v, "faults.drop_rate", d.drop_rate),
            delay_rate: get_f64(v, "faults.delay_rate", d.delay_rate),
            delay_ms: get_f64(v, "faults.delay_ms", d.delay_ms as f64) as u64,
            truncate_rate: get_f64(v, "faults.truncate_rate", d.truncate_rate),
            corrupt_rate: get_f64(v, "faults.corrupt_rate", d.corrupt_rate),
            kill_rate: get_f64(v, "faults.kill_rate", d.kill_rate),
            stall_rate: get_f64(v, "faults.stall_rate", d.stall_rate),
            stall_ms: get_f64(v, "faults.stall_ms", d.stall_ms as f64) as u64,
            panic_actor: get_f64(v, "faults.panic_actor", d.panic_actor as f64)
                as i64,
            panic_at_step: get_f64(
                v,
                "faults.panic_at_step",
                d.panic_at_step as f64,
            ) as u64,
        }
    }

    /// Whether any fault is configured at all (false = the plan is
    /// never built and the injection seams cost nothing).
    pub fn enabled(&self) -> bool {
        self.drop_rate > 0.0
            || self.delay_rate > 0.0
            || self.truncate_rate > 0.0
            || self.corrupt_rate > 0.0
            || self.kill_rate > 0.0
            || self.stall_rate > 0.0
            || self.panic_actor >= 0
    }

    /// Parse a compact CLI spec: `"seed=7,corrupt_rate=0.02,kill_rate=0.01"`.
    /// Keys mirror the `[faults]` section exactly; unknown keys are errors.
    pub fn from_spec(spec: &str) -> Result<Self, ConfigError> {
        let mut cfg = Self::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (k, v) = part.split_once('=').ok_or_else(|| {
                ConfigError::Invalid(format!("faults spec `{part}`: want key=value"))
            })?;
            let num = v.trim().parse::<f64>().map_err(|_| {
                ConfigError::Invalid(format!("faults spec `{part}`: bad number"))
            })?;
            match k.trim() {
                "seed" => cfg.seed = num as u64,
                "drop_rate" => cfg.drop_rate = num,
                "delay_rate" => cfg.delay_rate = num,
                "delay_ms" => cfg.delay_ms = num as u64,
                "truncate_rate" => cfg.truncate_rate = num,
                "corrupt_rate" => cfg.corrupt_rate = num,
                "kill_rate" => cfg.kill_rate = num,
                "stall_rate" => cfg.stall_rate = num,
                "stall_ms" => cfg.stall_ms = num as u64,
                "panic_actor" => cfg.panic_actor = num as i64,
                "panic_at_step" => cfg.panic_at_step = num as u64,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown faults spec key `{other}`"
                    )))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        for (name, r) in [
            ("drop_rate", self.drop_rate),
            ("delay_rate", self.delay_rate),
            ("truncate_rate", self.truncate_rate),
            ("corrupt_rate", self.corrupt_rate),
            ("kill_rate", self.kill_rate),
            ("stall_rate", self.stall_rate),
        ] {
            if !(0.0..=1.0).contains(&r) {
                return Err(ConfigError::Invalid(format!(
                    "faults.{name} must be in [0, 1], got {r}"
                )));
            }
        }
        if self.panic_actor >= 0 && self.panic_at_step == 0 {
            return Err(ConfigError::Invalid(
                "faults.panic_at_step must be >= 1 when panic_actor is set"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Resilient policy serving (`[serve]`; DESIGN.md §16): the control
/// socket, circuit breaker, and admission-control knobs around
/// `rlarch serve`. Everything defaults off — with this section at its
/// defaults the serving gate is never constructed and the data plane
/// is bit-for-bit the PR 9 path.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Control-plane listen address (`tcp:host:port` / `uds:/path`);
    /// empty (default) = no control socket.
    pub control: String,
    /// Consecutive backend errors that trip the circuit breaker open
    /// (fail-fast shed replies while open). 0 (default) = no breaker.
    pub backend_failure_threshold: usize,
    /// How long an open breaker waits before admitting one half-open
    /// probe to the backend.
    pub breaker_cooloff_ms: u64,
    /// Bound on fleet-wide admitted-and-unreplied rows; non-`actor`
    /// submissions beyond it are shed. 0 (default) = unbounded.
    pub admission_rows: usize,
    /// Sliding window of the overload detector (8 buckets).
    pub overload_window_ms: u64,
    /// Admitted rows per window at which the overload ladder starts
    /// shedding: `bulk` at 1x, `eval` too at 1.5x, `actor` never.
    /// 0 (default) = detector off.
    pub overload_rows: usize,
    /// Deadline target for non-`actor` traffic: shed when the queued
    /// backlog divided by observed window throughput exceeds this.
    /// 0 (default) = no deadline shedding.
    pub deadline_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            control: String::new(),
            backend_failure_threshold: 0,
            breaker_cooloff_ms: 1_000,
            admission_rows: 0,
            overload_window_ms: 1_000,
            overload_rows: 0,
            deadline_ms: 0,
        }
    }
}

impl ServeConfig {
    /// Whether any serving feature is configured (false = the serve
    /// gate is never built; bit-for-bit the PR 9 data plane).
    pub fn enabled(&self) -> bool {
        !self.control.is_empty()
            || self.backend_failure_threshold > 0
            || self.admission_rows > 0
            || self.overload_rows > 0
            || self.deadline_ms > 0
    }

    pub fn from_value(v: &Value) -> Self {
        let d = Self::default();
        Self {
            control: get_str(v, "serve.control", &d.control),
            backend_failure_threshold: get_usize(
                v,
                "serve.backend_failure_threshold",
                d.backend_failure_threshold,
            ),
            breaker_cooloff_ms: get_f64(
                v,
                "serve.breaker_cooloff_ms",
                d.breaker_cooloff_ms as f64,
            ) as u64,
            admission_rows: get_usize(v, "serve.admission_rows", d.admission_rows),
            overload_window_ms: get_f64(
                v,
                "serve.overload_window_ms",
                d.overload_window_ms as f64,
            ) as u64,
            overload_rows: get_usize(v, "serve.overload_rows", d.overload_rows),
            deadline_ms: get_f64(v, "serve.deadline_ms", d.deadline_ms as f64)
                as u64,
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.backend_failure_threshold > 0 && self.breaker_cooloff_ms == 0 {
            return Err(ConfigError::Invalid(
                "serve.breaker_cooloff_ms must be > 0 when \
                 backend_failure_threshold is set"
                    .into(),
            ));
        }
        if (self.overload_rows > 0 || self.deadline_ms > 0)
            && self.overload_window_ms == 0
        {
            return Err(ConfigError::Invalid(
                "serve.overload_window_ms must be > 0 when overload_rows or \
                 deadline_ms is set"
                    .into(),
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Top-level
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
pub enum InferenceMode {
    /// SEED-style: observations travel to a central batched inference
    /// engine colocated with the learner (GPU-side).
    Central,
    /// IMPALA-style: each actor runs its own (CPU) inference locally.
    Local,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    pub run_name: String,
    pub seed: u64,
    pub mode: InferenceMode,
    pub artifacts_dir: String,
    pub env: EnvConfig,
    pub actors: ActorConfig,
    pub batcher: BatcherConfig,
    pub learner: LearnerConfig,
    pub replay: ReplayBufferConfig,
    pub gpu: GpuModelConfig,
    pub cpu: CpuModelConfig,
    pub power: PowerModelConfig,
    pub telemetry: TelemetryConfig,
    pub fleet: FleetConfig,
    pub faults: FaultsConfig,
    pub serve: ServeConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            run_name: "rlarch".into(),
            seed: 2020,
            mode: InferenceMode::Central,
            artifacts_dir: "artifacts".into(),
            env: EnvConfig::default(),
            actors: ActorConfig::default(),
            batcher: BatcherConfig::default(),
            learner: LearnerConfig::default(),
            replay: ReplayBufferConfig::default(),
            gpu: GpuModelConfig::default(),
            cpu: CpuModelConfig::default(),
            power: PowerModelConfig::default(),
            telemetry: TelemetryConfig::default(),
            fleet: FleetConfig::default(),
            faults: FaultsConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

/// Scalar keys allowed at the top level of a config file.
const TOP_LEVEL_KEYS: &[&str] = &["run_name", "seed", "mode", "artifacts_dir"];

/// Allowed `[section]` tables and their keys. `from_value` rejects
/// anything outside this schema so typos surface as errors instead of
/// silently falling back to defaults.
const SECTION_KEYS: &[(&str, &[&str])] = &[
    (
        "env",
        &[
            "name",
            "frame_stack",
            "sticky_action_prob",
            "max_episode_len",
            "step_cost_us",
            "seed",
            "batch_native",
        ],
    ),
    (
        "actors",
        &[
            "num_actors",
            "envs_per_actor",
            "pipeline_depth",
            "epsilon_base",
            "epsilon_alpha",
            "num_eval_actors",
        ],
    ),
    ("batcher", &["max_batch", "timeout_us", "batch_sizes"]),
    (
        "learner",
        &[
            "train_batch",
            "min_replay",
            "target_update_interval",
            "max_steps",
            "prefetch_depth",
            "burn_in",
            "unroll_len",
            "seq_overlap",
            "gamma",
            "n_step",
        ],
    ),
    (
        "replay",
        &[
            "capacity",
            "alpha",
            "min_priority",
            "shards",
            "insert_batch",
            "pool",
        ],
    ),
    (
        "gpu",
        &[
            "num_sms",
            "clock_ghz",
            "flops_per_sm_clk",
            "dram_bw_gbps",
            "dram_latency_ns",
            "l2_bytes",
            "l2_bw_gbps",
            "launch_overhead_us",
            "threads_per_sm",
        ],
    ),
    (
        "cpu",
        &[
            "hw_threads",
            "env_step_us",
            "actor_overhead_us",
            "ctx_switch_us",
            "smt_efficiency",
        ],
    ),
    ("power", &["idle_w", "max_w", "sm_dynamic_frac", "util_exponent"]),
    (
        "telemetry",
        &[
            "snapshot_interval_ms",
            "trace_capacity",
            "trace_out",
            "metrics_out",
        ],
    ),
    (
        "fleet",
        &[
            "listen",
            "connect",
            "max_inflight_rows",
            "connect_retries",
            "backoff_ms",
            "heartbeat_interval_ms",
            "liveness_timeout_ms",
            "actor_restart_budget",
            "checkpoint_dir",
            "checkpoint_every",
            "drain_timeout_ms",
        ],
    ),
    (
        "serve",
        &[
            "control",
            "backend_failure_threshold",
            "breaker_cooloff_ms",
            "admission_rows",
            "overload_window_ms",
            "overload_rows",
            "deadline_ms",
        ],
    ),
    (
        "faults",
        &[
            "seed",
            "drop_rate",
            "delay_rate",
            "delay_ms",
            "truncate_rate",
            "corrupt_rate",
            "kill_rate",
            "stall_rate",
            "stall_ms",
            "panic_actor",
            "panic_at_step",
        ],
    ),
];

impl SystemConfig {
    pub fn from_value(v: &Value) -> Result<Self, ConfigError> {
        super::toml::check_known_keys(v, TOP_LEVEL_KEYS, SECTION_KEYS)
            .map_err(ConfigError::Invalid)?;
        let d = Self::default();
        let mode = match get_str(v, "mode", "central").as_str() {
            "central" => InferenceMode::Central,
            "local" => InferenceMode::Local,
            other => {
                return Err(ConfigError::Invalid(format!(
                    "mode must be central|local, got `{other}`"
                )))
            }
        };
        let cfg = Self {
            run_name: get_str(v, "run_name", &d.run_name),
            seed: get_f64(v, "seed", d.seed as f64) as u64,
            mode,
            artifacts_dir: get_str(v, "artifacts_dir", &d.artifacts_dir),
            env: EnvConfig::from_value(v),
            actors: ActorConfig::from_value(v),
            batcher: BatcherConfig::from_value(v),
            learner: LearnerConfig::from_value(v),
            replay: ReplayBufferConfig::from_value(v),
            gpu: GpuModelConfig::from_value(v),
            cpu: CpuModelConfig::from_value(v),
            power: PowerModelConfig::from_value(v),
            telemetry: TelemetryConfig::from_value(v),
            fleet: FleetConfig::from_value(v),
            faults: FaultsConfig::from_value(v),
            serve: ServeConfig::from_value(v),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml(text: &str) -> Result<Self, ConfigError> {
        let v = super::toml::parse(text)
            .map_err(|e| ConfigError::Invalid(e.to_string()))?;
        Self::from_value(&v)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        self.batcher.validate()?;
        self.learner.validate()?;
        self.replay.validate()?;
        self.telemetry.validate()?;
        self.fleet.validate()?;
        self.faults.validate()?;
        self.serve.validate()?;
        // Cross-section: the buffer must be able to hold a train batch
        // and the fill threshold the learner waits for.
        if self.replay.capacity < self.learner.train_batch {
            return Err(ConfigError::Invalid(
                "replay.capacity must be >= learner.train_batch".into(),
            ));
        }
        if self.replay.capacity < self.learner.min_replay {
            return Err(ConfigError::Invalid(
                "replay.capacity must be >= learner.min_replay".into(),
            ));
        }
        if self.actors.num_actors == 0 {
            return Err(ConfigError::Invalid("num_actors must be > 0".into()));
        }
        if self.actors.envs_per_actor == 0 {
            return Err(ConfigError::Invalid(
                "envs_per_actor must be > 0".into(),
            ));
        }
        if self.actors.pipeline_depth == 0 {
            return Err(ConfigError::Invalid(
                "pipeline_depth must be > 0 (1 = serialized)".into(),
            ));
        }
        if self.gpu.num_sms == 0 || self.cpu.hw_threads == 0 {
            return Err(ConfigError::Invalid(
                "gpu.num_sms and cpu.hw_threads must be > 0".into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.env.sticky_action_prob) {
            return Err(ConfigError::Invalid(
                "sticky_action_prob must be in [0,1]".into(),
            ));
        }
        Ok(())
    }

    /// The paper's system design metric: CPU hardware threads / GPU SMs.
    pub fn cpu_gpu_ratio(&self) -> f64 {
        self.cpu.hw_threads as f64 / self.gpu.num_sms as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_dgx1_like() {
        let cfg = SystemConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.gpu.num_sms, 80);
        assert_eq!(cfg.cpu.hw_threads, 40);
        // Single-V100 slice of a DGX-1: ratio 1/2 (paper Fig. 4 baseline).
        assert!((cfg.cpu_gpu_ratio() - 0.5).abs() < 1e-12);
        // Peak fp32 ≈ 15.7 TFLOP/s.
        assert!((cfg.gpu.peak_flops() / 1e12 - 15.67).abs() < 0.1);
    }

    #[test]
    fn from_toml_overrides() {
        let cfg = SystemConfig::from_toml(
            r#"
run_name = "sweep"
mode = "local"
[actors]
num_actors = 64
[gpu]
num_sms = 40
[cpu]
hw_threads = 40
"#,
        )
        .unwrap();
        assert_eq!(cfg.run_name, "sweep");
        assert_eq!(cfg.mode, InferenceMode::Local);
        assert_eq!(cfg.actors.num_actors, 64);
        assert!((cfg.cpu_gpu_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_mode_and_bounds() {
        assert!(SystemConfig::from_toml("mode = \"hybrid\"\n").is_err());
        assert!(SystemConfig::from_toml("[env]\nsticky_action_prob = 1.5\n")
            .is_err());
        assert!(SystemConfig::from_toml("[actors]\nnum_actors = 0\n").is_err());
        assert!(
            SystemConfig::from_toml("[actors]\nenvs_per_actor = 0\n").is_err()
        );
    }

    #[test]
    fn parses_envs_per_actor() {
        let cfg = SystemConfig::from_toml("[actors]\nenvs_per_actor = 8\n")
            .unwrap();
        assert_eq!(cfg.actors.envs_per_actor, 8);
        assert_eq!(cfg.actors.total_envs(), 8 * cfg.actors.num_actors);
        assert_eq!(SystemConfig::default().actors.envs_per_actor, 1);
    }

    #[test]
    fn parses_pipeline_depth_and_rejects_zero() {
        let cfg = SystemConfig::from_toml(
            "[actors]\nenvs_per_actor = 8\npipeline_depth = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.actors.pipeline_depth, 2);
        // 1 (the serialized seed loop) is the default.
        assert_eq!(SystemConfig::default().actors.pipeline_depth, 1);
        let err = SystemConfig::from_toml("[actors]\npipeline_depth = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("pipeline_depth"), "got: {err}");
    }

    #[test]
    fn rejects_unknown_keys_with_section_context() {
        let err = SystemConfig::from_toml("[env]\nsticky_prob = 0.3\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown key `sticky_prob` in section `env`"),
            "got: {err}"
        );
        let err = SystemConfig::from_toml("sede = 3\n").unwrap_err().to_string();
        assert!(err.contains("unknown key `sede`"), "got: {err}");
        let err = SystemConfig::from_toml("[actor]\nnum_actors = 4\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown key `actor`"), "got: {err}");
        // Every documented key parses cleanly.
        SystemConfig::from_toml(
            "[actors]\nnum_actors = 2\nenvs_per_actor = 4\n\
             [batcher]\nmax_batch = 8\nbatch_sizes = [1, 8]\n",
        )
        .unwrap();
    }

    #[test]
    fn batcher_validation() {
        let mut b = BatcherConfig::default();
        b.validate().unwrap();
        b.batch_sizes = vec![8, 1];
        assert!(b.validate().is_err());
        b.batch_sizes = vec![1, 8];
        b.max_batch = 64;
        assert!(b.validate().is_err());
        // A zero bucket is not a compilable launch shape.
        b.batch_sizes = vec![0, 64];
        assert!(b.validate().is_err());
        // The seed flush policy: one bucket equal to the cap.
        b.batch_sizes = vec![64];
        b.validate().unwrap();
    }

    #[test]
    fn launch_size_rounds_up_to_the_smallest_fitting_bucket() {
        // The one bucket-rounding rule, shared in spirit with
        // `SystemModel::launch_size`: smallest bucket >= the flush.
        let b = BatcherConfig {
            max_batch: 64,
            timeout_us: 500,
            batch_sizes: vec![1, 8, 32, 64],
        };
        assert_eq!(b.launch_size(1), 1);
        assert_eq!(b.launch_size(2), 8);
        assert_eq!(b.launch_size(8), 8);
        assert_eq!(b.launch_size(9), 32);
        assert_eq!(b.launch_size(33), 64);
        assert_eq!(b.launch_size(64), 64);
        let cap_only = BatcherConfig {
            max_batch: 4,
            timeout_us: 500,
            batch_sizes: vec![4],
        };
        for n in 1..=4 {
            assert_eq!(cap_only.launch_size(n), 4);
        }
    }

    #[test]
    fn learner_validation() {
        let mut l = LearnerConfig::default();
        l.validate().unwrap();
        assert_eq!(l.seq_len(), 20);
        l.seq_overlap = 25;
        assert!(l.validate().is_err());
    }

    #[test]
    fn parses_replay_section_and_prefetch_depth() {
        let cfg = SystemConfig::from_toml(
            "[replay]\ncapacity = 1024\nalpha = 0.5\nmin_priority = 0.01\n\
             shards = 4\n[learner]\nprefetch_depth = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.replay.capacity, 1024);
        assert!((cfg.replay.alpha - 0.5).abs() < 1e-12);
        assert!((cfg.replay.min_priority - 0.01).abs() < 1e-12);
        assert_eq!(cfg.replay.shards, 4);
        assert_eq!(cfg.learner.prefetch_depth, 2);
        // The serialized seed paths are the defaults.
        let d = SystemConfig::default();
        assert_eq!(d.replay.shards, 1);
        assert_eq!(d.learner.prefetch_depth, 1);
    }

    #[test]
    fn replay_validation_bounds() {
        // capacity must hold a train batch.
        let err = SystemConfig::from_toml(
            "[replay]\ncapacity = 8\n[learner]\ntrain_batch = 16\n\
             min_replay = 16\n",
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("replay.capacity must be >= learner.train_batch"),
            "got: {err}"
        );
        // ...and the learner's fill threshold.
        let err = SystemConfig::from_toml(
            "[replay]\ncapacity = 32\n[learner]\ntrain_batch = 16\n\
             min_replay = 64\n",
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("replay.capacity must be >= learner.min_replay"),
            "got: {err}"
        );
        let err = SystemConfig::from_toml("[replay]\nalpha = -0.1\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("replay.alpha must be >= 0"), "got: {err}");
        let err = SystemConfig::from_toml("[replay]\nmin_priority = 0.0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("replay.min_priority must be > 0"), "got: {err}");
        let err = SystemConfig::from_toml("[replay]\nshards = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("replay.shards must be > 0"), "got: {err}");
        // Shards must stripe the capacity evenly.
        for bad in ["capacity = 4096\nshards = 3\n", "capacity = 4\nshards = 8\n"]
        {
            let err =
                SystemConfig::from_toml(&format!("[replay]\n{bad}"))
                    .unwrap_err()
                    .to_string();
            assert!(
                err.contains("replay.shards must divide replay.capacity"),
                "got: {err}"
            );
        }
        let err = SystemConfig::from_toml("[learner]\nprefetch_depth = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("prefetch_depth"), "got: {err}");
    }

    #[test]
    fn parses_batch_native() {
        let cfg = SystemConfig::from_toml("[env]\nbatch_native = true\n").unwrap();
        assert!(cfg.env.batch_native);
        // The per-slot `Wrapped` path stays the default (bit-for-bit
        // reference; the SoA engine is opt-in).
        assert!(!SystemConfig::default().env.batch_native);
    }

    #[test]
    fn parses_insert_batch_and_pool() {
        let cfg = SystemConfig::from_toml(
            "[replay]\ninsert_batch = 8\npool = false\n",
        )
        .unwrap();
        assert_eq!(cfg.replay.insert_batch, 8);
        assert!(!cfg.replay.pool);
        // Seed-equivalent ingest (flush-per-sequence) is the default;
        // pooling is on by default (it never changes emitted values).
        let d = SystemConfig::default();
        assert_eq!(d.replay.insert_batch, 1);
        assert!(d.replay.pool);
    }

    #[test]
    fn insert_batch_validation_bounds() {
        let err = SystemConfig::from_toml("[replay]\ninsert_batch = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("replay.insert_batch must be > 0"), "got: {err}");
        let err = SystemConfig::from_toml(
            "[replay]\ncapacity = 64\ninsert_batch = 128\n",
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("replay.insert_batch must be <= replay.capacity"),
            "got: {err}"
        );
    }

    #[test]
    fn parses_telemetry_section() {
        let cfg = SystemConfig::from_toml(
            "[telemetry]\nsnapshot_interval_ms = 50\ntrace_capacity = 1024\n\
             trace_out = \"/tmp/trace.json\"\nmetrics_out = \"/tmp/m.jsonl\"\n",
        )
        .unwrap();
        assert_eq!(cfg.telemetry.snapshot_interval_ms, 50);
        assert_eq!(cfg.telemetry.trace_capacity, 1024);
        assert!(cfg.telemetry.trace_enabled());
        assert!(cfg.telemetry.sampler_enabled());
        // Telemetry is off by default: empty output paths.
        let d = SystemConfig::default();
        assert!(!d.telemetry.enabled());
        assert_eq!(d.telemetry.snapshot_interval_ms, 200);

        let err = SystemConfig::from_toml("[telemetry]\ntrace_file = \"x\"\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown key `trace_file` in section `telemetry`"),
            "got: {err}"
        );
        let err =
            SystemConfig::from_toml("[telemetry]\nsnapshot_interval_ms = 0\n")
                .unwrap_err()
                .to_string();
        assert!(
            err.contains("telemetry.snapshot_interval_ms must be > 0"),
            "got: {err}"
        );
    }

    #[test]
    fn parses_serve_section_and_defaults_off() {
        let cfg = SystemConfig::from_toml(
            "[serve]\ncontrol = \"uds:/tmp/ctl.sock\"\n\
             backend_failure_threshold = 3\nbreaker_cooloff_ms = 250\n\
             admission_rows = 512\noverload_window_ms = 400\n\
             overload_rows = 1000\ndeadline_ms = 50\n\
             [fleet]\ndrain_timeout_ms = 750\n",
        )
        .unwrap();
        assert_eq!(cfg.serve.control, "uds:/tmp/ctl.sock");
        assert_eq!(cfg.serve.backend_failure_threshold, 3);
        assert_eq!(cfg.serve.breaker_cooloff_ms, 250);
        assert_eq!(cfg.serve.admission_rows, 512);
        assert_eq!(cfg.serve.overload_window_ms, 400);
        assert_eq!(cfg.serve.overload_rows, 1000);
        assert_eq!(cfg.serve.deadline_ms, 50);
        assert_eq!(cfg.fleet.drain_timeout_ms, 750);
        assert!(cfg.serve.enabled());
        // Everything off by default: the PR 9 identity path.
        let d = SystemConfig::default();
        assert!(!d.serve.enabled());
        assert_eq!(d.fleet.drain_timeout_ms, 2_000);

        let err = SystemConfig::from_toml("[serve]\ncontorl = \"x\"\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown key `contorl` in section `serve`"),
            "got: {err}"
        );
        let err = SystemConfig::from_toml(
            "[serve]\nbackend_failure_threshold = 2\nbreaker_cooloff_ms = 0\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("breaker_cooloff_ms"), "got: {err}");
        let err = SystemConfig::from_toml(
            "[serve]\noverload_rows = 10\noverload_window_ms = 0\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("overload_window_ms"), "got: {err}");
    }

    #[test]
    fn replay_section_rejects_unknown_and_stale_keys() {
        let err = SystemConfig::from_toml("[replay]\ncapcity = 64\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown key `capcity` in section `replay`"),
            "got: {err}"
        );
        // The pre-split learner spellings moved to [replay]; they must
        // fail loudly, not silently fall back to defaults.
        let err = SystemConfig::from_toml("[learner]\nreplay_capacity = 64\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown key `replay_capacity` in section `learner`"),
            "got: {err}"
        );
        let err = SystemConfig::from_toml("[learner]\npriority_exponent = 0.5\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown key `priority_exponent` in section `learner`"),
            "got: {err}"
        );
    }
}
