//! Configuration: TOML-subset parser + typed config structs with
//! validation. `SystemConfig::from_toml` is the single entrypoint the CLI
//! and examples use; benches construct configs programmatically.

pub mod toml;
pub mod types;

pub use types::{
    ActorConfig, BatcherConfig, ConfigError, CpuModelConfig, EnvConfig, FaultsConfig,
    FleetConfig, GpuModelConfig, InferenceMode, LearnerConfig, PowerModelConfig,
    ReplayBufferConfig, ServeConfig, SystemConfig, TelemetryConfig,
};

use std::path::Path;

/// Load a SystemConfig from a TOML file.
pub fn load(path: &Path) -> Result<SystemConfig, ConfigError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError::Invalid(format!("read {path:?}: {e}")))?;
    SystemConfig::from_toml(&text)
}
