//! TOML-subset parser for run configuration files.
//!
//! Supports the subset our configs use: `[section]` and `[a.b]` tables,
//! `key = value` with string / integer / float / bool / array values,
//! comments, and blank lines. Values land in a `util::json::Value` tree so
//! the typed config layer (config::types) shares one accessor API with the
//! JSON artifacts. Unsupported TOML (multi-line strings, dates, inline
//! tables, arrays-of-tables) is rejected with a line-numbered error.

use crate::util::json::Value;

#[derive(Debug)]
pub enum TomlError {
    Syntax(usize, String),
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TomlError::Syntax(line, msg) => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for TomlError {}

pub fn parse(text: &str) -> Result<Value, TomlError> {
    let mut root: Vec<(String, Value)> = Vec::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if line.starts_with("[[") {
                return Err(TomlError::Syntax(
                    lineno + 1,
                    "arrays of tables are not supported".into(),
                ));
            }
            let inner = line
                .strip_prefix('[')
                .and_then(|s| s.strip_suffix(']'))
                .ok_or_else(|| {
                    TomlError::Syntax(lineno + 1, "malformed table header".into())
                })?;
            current_path = inner
                .split('.')
                .map(|s| s.trim().to_string())
                .collect();
            if current_path.iter().any(|s| s.is_empty()) {
                return Err(TomlError::Syntax(
                    lineno + 1,
                    "empty table-name segment".into(),
                ));
            }
            ensure_table(&mut root, &current_path, lineno + 1)?;
            continue;
        }
        let eq = line.find('=').ok_or_else(|| {
            TomlError::Syntax(lineno + 1, "expected `key = value`".into())
        })?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        if key.is_empty() {
            return Err(TomlError::Syntax(lineno + 1, "empty key".into()));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno + 1)?;
        insert(&mut root, &current_path, key, value, lineno + 1)?;
    }
    Ok(Value::Obj(root))
}

/// Check a parsed config tree against a flat schema: `top` lists the
/// scalar keys allowed at the top level, `sections` maps each allowed
/// `[section]` to its allowed keys. Unknown keys are rejected instead of
/// silently ignored (a misspelt knob must not silently fall back to its
/// default). Returns the first offending key as a descriptive error.
pub fn check_known_keys(
    v: &Value,
    top: &[&str],
    sections: &[(&str, &[&str])],
) -> Result<(), String> {
    let Value::Obj(kvs) = v else { return Ok(()) };
    for (key, val) in kvs {
        if top.contains(&key.as_str()) {
            continue;
        }
        let Some((section, allowed)) =
            sections.iter().find(|(s, _)| s == key)
        else {
            return Err(format!(
                "unknown key `{key}` at the top level (sections: {:?})",
                sections.iter().map(|(s, _)| *s).collect::<Vec<_>>()
            ));
        };
        let Value::Obj(inner) = val else {
            return Err(format!("`{section}` must be a [{section}] table"));
        };
        for (ik, _) in inner {
            if !allowed.contains(&ik.as_str()) {
                return Err(format!(
                    "unknown key `{ik}` in section `{section}`"
                ));
            }
        }
    }
    Ok(())
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value, TomlError> {
    let t = text.trim();
    if t.is_empty() {
        return Err(TomlError::Syntax(lineno, "missing value".into()));
    }
    if t.starts_with('"') {
        let inner = t
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .ok_or_else(|| TomlError::Syntax(lineno, "unterminated string".into()))?;
        return Ok(Value::Str(unescape(inner)));
    }
    if t.starts_with('[') {
        let inner = t
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| TomlError::Syntax(lineno, "unterminated array".into()))?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = t.replace('_', "");
    if let Ok(x) = cleaned.parse::<f64>() {
        return Ok(Value::Num(x));
    }
    Err(TomlError::Syntax(lineno, format!("cannot parse value `{t}`")))
}

fn split_array_items(inner: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in inner.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                items.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        items.push(cur);
    }
    items
}

fn unescape(s: &str) -> String {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

fn ensure_table(
    root: &mut Vec<(String, Value)>,
    path: &[String],
    lineno: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for seg in path {
        let pos = cur.iter().position(|(k, _)| k == seg);
        let idx = match pos {
            Some(i) => i,
            None => {
                cur.push((seg.clone(), Value::Obj(Vec::new())));
                cur.len() - 1
            }
        };
        cur = match &mut cur[idx].1 {
            Value::Obj(kvs) => kvs,
            _ => {
                return Err(TomlError::Syntax(
                    lineno,
                    format!("`{seg}` is both a value and a table"),
                ))
            }
        };
    }
    Ok(())
}

fn insert(
    root: &mut Vec<(String, Value)>,
    path: &[String],
    key: String,
    value: Value,
    lineno: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for seg in path {
        let idx = cur
            .iter()
            .position(|(k, _)| k == seg)
            .expect("table created by ensure_table");
        cur = match &mut cur[idx].1 {
            Value::Obj(kvs) => kvs,
            _ => {
                return Err(TomlError::Syntax(
                    lineno,
                    format!("`{seg}` is not a table"),
                ))
            }
        };
    }
    if cur.iter().any(|(k, _)| *k == key) {
        return Err(TomlError::Syntax(lineno, format!("duplicate key `{key}`")));
    }
    cur.push((key, value));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let v = parse(
            r#"
# comment
name = "run1"
actors = 40          # trailing comment
ratio = 0.5
flag = true

[gpu]
sms = 80
mem_bw_gbps = 900.0

[sim.cpu]
threads = 40
"#,
        )
        .unwrap();
        assert_eq!(v.path("name").unwrap().as_str(), Some("run1"));
        assert_eq!(v.path("actors").unwrap().as_u64(), Some(40));
        assert_eq!(v.path("gpu.sms").unwrap().as_u64(), Some(80));
        assert_eq!(v.path("sim.cpu.threads").unwrap().as_u64(), Some(40));
        assert_eq!(v.path("flag").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_arrays() {
        let v = parse("xs = [1, 2, 3]\nnames = [\"a\", \"b\"]\n").unwrap();
        let xs = v.path("xs").unwrap().as_arr().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_u64(), Some(3));
        assert_eq!(
            v.path("names").unwrap().idx(1).unwrap().as_str(),
            Some("b")
        );
    }

    #[test]
    fn underscore_numbers() {
        let v = parse("big = 1_000_000\n").unwrap();
        assert_eq!(v.path("big").unwrap().as_u64(), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let v = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(v.path("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("novalue =\n").is_err());
        assert!(parse("[[t]]\n").is_err());
        assert!(parse("x ~ 3\n").is_err());
    }

    #[test]
    fn value_table_conflict() {
        assert!(parse("a = 1\n[a]\nb = 2\n").is_err());
    }
}
