//! Vectorized environment engine: one actor thread drives E environments.
//!
//! The paper's central lever is the CPU/GPU ratio — how much environment
//! throughput backs each unit of accelerator capacity. The seed design
//! pinned exactly one environment to one OS thread, so the only way to
//! raise the env-step rate was to spawn more threads (the Fig. 3 actor
//! sweep). `VecEnv` decouples the two axes, CuLE-style: a single engine
//! owns E fully-wrapped environment instances (frame stack, sticky
//! actions, step cost, episode bookkeeping — one [`Wrapped`] per slot),
//! steps them in lockstep through [`VecEnv::step_all`], and writes all
//! observations into one contiguous `[E, S, S, K]` buffer that maps 1:1
//! onto E rows of a batched inference request.
//!
//! Slots auto-reset on episode end (inherited from [`Wrapped`]), so the
//! engine never stalls; per-slot episode state stays readable through
//! [`VecEnv::slot`] for return tracking and stats.
//!
//! With `envs_per_actor = 1` a `VecEnv` is bit-for-bit the seed's
//! single-env actor: slot seeds, sticky-action RNG streams, and reset
//! semantics are identical (asserted by the tests below).
//!
//! `VecEnv` is also the dispatch seam for the batch-native SoA engine
//! (DESIGN.md §13): with `env.batch_native = true` the E slots live in
//! one [`BatchEnv`] stepping struct-of-arrays state in a single call
//! per group, instead of E per-slot [`Wrapped`] instances. The two
//! engines share the seed layout and are bit-for-bit equivalent
//! (property + e2e tests); the knob changes cost only.

use crate::config::EnvConfig;
use crate::env::soa::{make_batch_env, BatchEnv};
use crate::env::wrappers::Wrapped;
use crate::env::Step;

/// The two interchangeable stepping engines behind [`VecEnv`].
enum Engine {
    /// E independent `Wrapped` instances, stepped slot-by-slot (the
    /// bit-for-bit reference path; default).
    PerSlot(Vec<Wrapped>),
    /// The batch-native SoA engine (`env::soa`): one call steps a whole
    /// slot range over struct-of-arrays state. Opt-in via
    /// `env.batch_native`.
    Batch(Box<dyn BatchEnv>),
}

/// A batched environment engine: E env instances stepped in lockstep,
/// rendering into one contiguous observation buffer.
pub struct VecEnv {
    engine: Engine,
    num_envs: usize,
    obs_len: usize,
    last_steps: Vec<Step>,
}

impl VecEnv {
    /// Build `num_envs` env slots. Slot `i` gets instance seed
    /// `base_instance_seed + i`, so a pool of actors can hand out
    /// disjoint seed ranges (actor `a` with E envs uses base
    /// `a * E + 1`, matching the seed layout of `a + 1` at E = 1).
    /// `cfg.batch_native` selects the engine; both use the same
    /// per-slot seed layout, so the choice is invisible to callers.
    pub fn from_config(
        cfg: &EnvConfig,
        num_envs: usize,
        base_instance_seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(num_envs > 0, "vecenv needs at least one environment");
        let engine = if cfg.batch_native {
            Engine::Batch(make_batch_env(cfg, num_envs, base_instance_seed)?)
        } else {
            let mut slots = Vec::with_capacity(num_envs);
            for i in 0..num_envs {
                slots.push(Wrapped::from_config(cfg, base_instance_seed + i as u64)?);
            }
            Engine::PerSlot(slots)
        };
        let obs_len = match &engine {
            Engine::PerSlot(slots) => slots[0].obs_len(),
            Engine::Batch(b) => b.obs_len(),
        };
        Ok(Self {
            engine,
            num_envs,
            obs_len,
            last_steps: Vec::with_capacity(num_envs),
        })
    }

    /// Environments in flight behind this engine.
    pub fn num_envs(&self) -> usize {
        self.num_envs
    }

    /// Per-slot observation length (S * S * K floats).
    pub fn obs_len(&self) -> usize {
        self.obs_len
    }

    /// Length of the full `[E, S, S, K]` observation buffer.
    pub fn obs_batch_len(&self) -> usize {
        self.num_envs * self.obs_len
    }

    /// Allocate a zeroed observation batch of the right size.
    pub fn new_obs_batch(&self) -> Vec<f32> {
        vec![0.0; self.obs_batch_len()]
    }

    /// Reset every slot; write all initial observations into `obs_batch`.
    pub fn reset_all(&mut self, obs_batch: &mut [f32]) {
        assert_eq!(obs_batch.len(), self.obs_batch_len(), "obs batch size");
        match &mut self.engine {
            Engine::PerSlot(slots) => {
                for (slot, obs) in slots
                    .iter_mut()
                    .zip(obs_batch.chunks_exact_mut(self.obs_len))
                {
                    slot.reset(obs);
                }
            }
            Engine::Batch(b) => b.reset_all(obs_batch),
        }
    }

    /// Step every slot with its action; write each slot's post-step
    /// observation into its row of `obs_batch`. Slots whose episode ends
    /// auto-reset (their row holds the next episode's initial
    /// observation, and the returned `Step` has `done = true`). Returns
    /// one `Step` per slot, in slot order.
    pub fn step_all(&mut self, actions: &[usize], obs_batch: &mut [f32]) -> &[Step] {
        assert_eq!(actions.len(), self.num_envs, "one action per slot");
        assert_eq!(obs_batch.len(), self.obs_batch_len(), "obs batch size");
        self.step_range(0, actions, obs_batch)
    }

    /// Step the contiguous slot range `start .. start + actions.len()`
    /// with one action per slot; write each slot's post-step observation
    /// into its row of `obs_rows` (a `[k, S, S, K]` sub-slab). The
    /// pipelined actor uses this to advance one slot group while another
    /// group's inference is in flight; `step_all` is the whole-pool
    /// special case.
    pub fn step_range(
        &mut self,
        start: usize,
        actions: &[usize],
        obs_rows: &mut [f32],
    ) -> &[Step] {
        let k = actions.len();
        assert!(start + k <= self.num_envs, "slot range out of bounds");
        assert_eq!(obs_rows.len(), k * self.obs_len, "obs rows size");
        self.last_steps.clear();
        match &mut self.engine {
            Engine::PerSlot(slots) => {
                for ((slot, &action), obs) in slots[start..start + k]
                    .iter_mut()
                    .zip(actions)
                    .zip(obs_rows.chunks_exact_mut(self.obs_len))
                {
                    self.last_steps.push(slot.step(action, obs));
                }
            }
            Engine::Batch(b) => b.step_range(start, actions, obs_rows, &mut self.last_steps),
        }
        &self.last_steps
    }

    /// Per-slot episode state (returns, lengths, counters). Only the
    /// per-slot engine exposes `Wrapped` internals; callers that need
    /// engine-independent state use [`VecEnv::last_return`] and the
    /// aggregate counters.
    pub fn slot(&self, i: usize) -> &Wrapped {
        match &self.engine {
            Engine::PerSlot(slots) => &slots[i],
            Engine::Batch(_) => panic!(
                "per-slot state is not exposed by the batch-native engine; \
                 use last_return()/total_steps()/episodes_completed()"
            ),
        }
    }

    /// Return of slot `i`'s last completed episode (engine-independent).
    pub fn last_return(&self, i: usize) -> f32 {
        match &self.engine {
            Engine::PerSlot(slots) => slots[i].last_return,
            Engine::Batch(b) => b.last_return(i),
        }
    }

    /// Total env steps across all slots.
    pub fn total_steps(&self) -> u64 {
        match &self.engine {
            Engine::PerSlot(slots) => slots.iter().map(|s| s.total_steps).sum(),
            Engine::Batch(b) => b.total_steps(),
        }
    }

    /// Completed episodes across all slots.
    pub fn episodes_completed(&self) -> u64 {
        match &self.engine {
            Engine::PerSlot(slots) => slots.iter().map(|s| s.episodes_completed).sum(),
            Engine::Batch(b) => b.episodes_completed(),
        }
    }

    /// Environment name (shared by every slot).
    pub fn name(&self) -> &'static str {
        match &self.engine {
            Engine::PerSlot(slots) => slots[0].name(),
            Engine::Batch(b) => b.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(name: &str) -> EnvConfig {
        EnvConfig {
            name: name.into(),
            frame_stack: 4,
            sticky_action_prob: 0.25,
            max_episode_len: 100,
            step_cost_us: 0,
            seed: 7,
            batch_native: false,
        }
    }

    #[test]
    fn single_slot_matches_wrapped_exactly() {
        // envs_per_actor = 1 must reproduce the seed's single-env actor:
        // same instance seed => identical observations, rewards, dones,
        // and counters at every step.
        let c = cfg("catch");
        let mut venv = VecEnv::from_config(&c, 1, 3).unwrap();
        let mut solo = Wrapped::from_config(&c, 3).unwrap();

        let mut obs_v = venv.new_obs_batch();
        let mut obs_s = vec![0.0f32; solo.obs_len()];
        venv.reset_all(&mut obs_v);
        solo.reset(&mut obs_s);
        assert_eq!(obs_v, obs_s);

        for i in 0..200usize {
            let a = i % 3;
            let sv = venv.step_all(&[a], &mut obs_v)[0].clone();
            let ss = solo.step(a, &mut obs_s);
            assert_eq!(sv, ss, "step {i}");
            assert_eq!(obs_v, obs_s, "obs diverged at step {i}");
        }
        assert_eq!(venv.total_steps(), solo.total_steps);
        assert_eq!(venv.episodes_completed(), solo.episodes_completed);
        assert_eq!(venv.slot(0).last_return, solo.last_return);
    }

    #[test]
    fn slots_match_independent_wrapped_instances() {
        // The batched engine must be observationally equivalent to E
        // independent single-env instances with the same seed layout.
        let c = cfg("grid_pong");
        let e = 3;
        let mut venv = VecEnv::from_config(&c, e, 10).unwrap();
        let mut solos: Vec<Wrapped> = (0..e)
            .map(|i| Wrapped::from_config(&c, 10 + i as u64).unwrap())
            .collect();

        let mut obs_v = venv.new_obs_batch();
        venv.reset_all(&mut obs_v);
        let obs_len = venv.obs_len();
        let mut obs_s = vec![vec![0.0f32; obs_len]; e];
        for (s, o) in solos.iter_mut().zip(&mut obs_s) {
            s.reset(o);
        }

        for i in 0..150usize {
            let actions: Vec<usize> = (0..e).map(|k| (i + k) % 4).collect();
            let steps: Vec<Step> = venv.step_all(&actions, &mut obs_v).to_vec();
            for k in 0..e {
                let ss = solos[k].step(actions[k], &mut obs_s[k]);
                assert_eq!(steps[k], ss, "slot {k} step {i}");
                assert_eq!(
                    obs_v[k * obs_len..(k + 1) * obs_len],
                    obs_s[k][..],
                    "slot {k} obs at step {i}"
                );
            }
        }
    }

    #[test]
    fn slots_are_decorrelated_by_seed() {
        // Different slots must not play identical episodes.
        let c = cfg("breakout");
        let mut venv = VecEnv::from_config(&c, 2, 1).unwrap();
        let mut obs = venv.new_obs_batch();
        venv.reset_all(&mut obs);
        let n = venv.obs_len();
        let mut diverged = false;
        for _ in 0..50 {
            venv.step_all(&[1, 1], &mut obs);
            if obs[..n] != obs[n..] {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "slots played identical trajectories");
    }

    #[test]
    fn auto_reset_keeps_all_slots_running() {
        let c = cfg("catch"); // catch episodes are ~9 steps
        let e = 4;
        let mut venv = VecEnv::from_config(&c, e, 1).unwrap();
        let mut obs = venv.new_obs_batch();
        venv.reset_all(&mut obs);
        let mut dones = 0u64;
        for _ in 0..100 {
            dones += venv
                .step_all(&vec![0; e], &mut obs)
                .iter()
                .filter(|s| s.done)
                .count() as u64;
        }
        assert_eq!(venv.total_steps(), 100 * e as u64);
        assert_eq!(venv.episodes_completed(), dones);
        assert!(dones >= 4 * 9, "catch should complete many episodes: {dones}");
    }

    #[test]
    fn determinism_under_fixed_seed() {
        let c = cfg("nav_maze");
        let run = || {
            let mut venv = VecEnv::from_config(&c, 3, 5).unwrap();
            let mut obs = venv.new_obs_batch();
            venv.reset_all(&mut obs);
            let mut rewards = Vec::new();
            for i in 0..120usize {
                let actions = [i % 4, (i + 1) % 4, (i + 2) % 4];
                for s in venv.step_all(&actions, &mut obs) {
                    rewards.push(s.reward);
                }
            }
            (obs, rewards)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn step_range_matches_step_all_per_group() {
        // Stepping [0..2) then [2..4) must equal one step_all over 4
        // slots: same Steps, same obs rows (slots are independent).
        let c = cfg("grid_pong");
        let e = 4;
        let mut whole = VecEnv::from_config(&c, e, 9).unwrap();
        let mut split = VecEnv::from_config(&c, e, 9).unwrap();
        let mut obs_w = whole.new_obs_batch();
        let mut obs_s = split.new_obs_batch();
        whole.reset_all(&mut obs_w);
        split.reset_all(&mut obs_s);
        let n = whole.obs_len();
        for i in 0..80usize {
            let actions: Vec<usize> = (0..e).map(|k| (i + k) % 4).collect();
            let steps_w: Vec<Step> = whole.step_all(&actions, &mut obs_w).to_vec();
            let mut steps_s: Vec<Step> = Vec::new();
            for (start, len) in [(0usize, 2usize), (2, 2)] {
                steps_s.extend_from_slice(&split.step_range(
                    start,
                    &actions[start..start + len],
                    &mut obs_s[start * n..(start + len) * n],
                ));
            }
            assert_eq!(steps_w, steps_s, "step {i}");
            assert_eq!(obs_w, obs_s, "obs at step {i}");
        }
        assert_eq!(whole.total_steps(), split.total_steps());
    }

    #[test]
    #[should_panic(expected = "one action per slot")]
    fn wrong_action_count_panics() {
        let mut venv = VecEnv::from_config(&cfg("catch"), 2, 1).unwrap();
        let mut obs = venv.new_obs_batch();
        venv.reset_all(&mut obs);
        venv.step_all(&[0], &mut obs);
    }

    #[test]
    fn batch_native_engine_matches_per_slot_bit_for_bit() {
        // The dispatch seam: the same VecEnv API over either engine
        // must produce identical observations, Steps, and counters for
        // every env in the suite.
        for name in ["catch", "grid_pong", "breakout", "nav_maze"] {
            let per_slot_cfg = cfg(name);
            let batch_cfg = EnvConfig {
                batch_native: true,
                ..cfg(name)
            };
            let e = 3;
            let mut a = VecEnv::from_config(&per_slot_cfg, e, 4).unwrap();
            let mut b = VecEnv::from_config(&batch_cfg, e, 4).unwrap();
            let mut obs_a = a.new_obs_batch();
            let mut obs_b = b.new_obs_batch();
            a.reset_all(&mut obs_a);
            b.reset_all(&mut obs_b);
            assert_eq!(obs_a, obs_b, "{name} reset obs");
            for i in 0..150usize {
                let actions: Vec<usize> = (0..e).map(|k| (i + 2 * k) % 4).collect();
                let sa: Vec<Step> = a.step_all(&actions, &mut obs_a).to_vec();
                let sb: Vec<Step> = b.step_all(&actions, &mut obs_b).to_vec();
                assert_eq!(sa, sb, "{name} steps at {i}");
                assert_eq!(obs_a, obs_b, "{name} obs at {i}");
            }
            assert_eq!(a.total_steps(), b.total_steps(), "{name}");
            assert_eq!(a.episodes_completed(), b.episodes_completed(), "{name}");
            for s in 0..e {
                assert_eq!(a.last_return(s), b.last_return(s), "{name} slot {s}");
            }
        }
    }

    #[test]
    fn batch_native_step_range_matches_per_slot_groups() {
        // pipeline_depth grouping goes through step_range on both
        // engines; group-wise stepping must agree across the seam.
        let per_slot_cfg = cfg("breakout");
        let batch_cfg = EnvConfig {
            batch_native: true,
            ..cfg("breakout")
        };
        let e = 5;
        let mut a = VecEnv::from_config(&per_slot_cfg, e, 2).unwrap();
        let mut b = VecEnv::from_config(&batch_cfg, e, 2).unwrap();
        let mut obs_a = a.new_obs_batch();
        let mut obs_b = b.new_obs_batch();
        a.reset_all(&mut obs_a);
        b.reset_all(&mut obs_b);
        let n = a.obs_len();
        for i in 0..120usize {
            let actions: Vec<usize> = (0..e).map(|k| (i + k) % 4).collect();
            for (start, len) in [(0usize, 2usize), (2, 3)] {
                let sa: Vec<Step> = a
                    .step_range(start, &actions[start..start + len], &mut obs_a[start * n..(start + len) * n])
                    .to_vec();
                let sb: Vec<Step> = b
                    .step_range(start, &actions[start..start + len], &mut obs_b[start * n..(start + len) * n])
                    .to_vec();
                assert_eq!(sa, sb, "group ({start},{len}) at {i}");
            }
            assert_eq!(obs_a, obs_b, "obs at {i}");
        }
    }

    #[test]
    #[should_panic(expected = "not exposed by the batch-native engine")]
    fn slot_access_panics_on_batch_engine() {
        let c = EnvConfig {
            batch_native: true,
            ..cfg("catch")
        };
        let venv = VecEnv::from_config(&c, 2, 1).unwrap();
        let _ = venv.slot(0);
    }
}
