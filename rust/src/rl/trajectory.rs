//! Trajectory types and the R2D2 sequence slicer, arena-backed.
//!
//! Actors produce transitions; the learner consumes fixed-length
//! sequences (burn_in + unroll) with the recurrent state snapshotted at
//! the sequence start and adjacent sequences overlapping (R2D2 uses
//! 80/40; our AOT default is 20/10, same ratio).
//!
//! ## Padding contract
//!
//! Every emitted [`Sequence`] carries full `seq_len`-sized buffers, no
//! matter how short the real data is: a sequence cut by an episode end
//! (or a shutdown [`SequenceBuilder::flush`]) is zero-padded past
//! `valid_len` — obs rows 0.0, actions 0, rewards 0.0, discounts 0.0 —
//! so the AOT train graph sees one fixed shape and the discount-0 pad
//! masks itself out of the loss. Consumers must treat `valid_len`, not
//! `seq_len()`, as the data length. `flush` additionally *drops the
//! overlap invariant*: the partial sequence it emits is final, and the
//! builder restarts empty — a builder reused after `flush` begins a
//! fresh trajectory with no overlap carried from before the flush
//! (asserted by `flush_then_reuse_starts_clean`).
//!
//! ## The zero-allocation path
//!
//! The builder writes transitions straight into the time-major slab of
//! the `Sequence` it will eventually emit — there is no intermediate
//! `Vec<Transition>` ring. [`SequenceBuilder::push_slices`] borrows the
//! caller's obs/h/c rows (the actor hands it slices of its slot slabs),
//! so in steady state a transition costs only `memcpy`s into
//! preallocated buffers. Emitted slabs are drawn from a shared
//! [`SequencePool`] when one is attached (`with_pool`): replay evictions
//! and learner-released batches feed buffers back, and the hit/miss
//! counters behind `actor.pool_hit_rate` expose how often the pool
//! actually short-circuits the allocator. Without a pool the builder
//! allocates a fresh slab per emitted sequence — the seed behavior —
//! and either way the emitted *values* are identical bit-for-bit
//! (property-tested against a verbatim seed replica in
//! `tests/property_invariants.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One actor transition: the observation fed to inference, the action
/// taken, and the immediate outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub obs: Vec<f32>,
    pub action: i32,
    pub reward: f32,
    /// gamma * (1 - done): 0 at terminals.
    pub discount: f32,
    /// Recurrent state *before* this observation was processed.
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// A fixed-length training sequence (the replay/learner unit).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Sequence {
    /// [T * obs_len], time-major.
    pub obs: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub discounts: Vec<f32>,
    /// Recurrent state at sequence start.
    pub h0: Vec<f32>,
    pub c0: Vec<f32>,
    pub actor_id: usize,
    /// Real (non-padded) steps.
    pub valid_len: usize,
}

impl Sequence {
    pub fn seq_len(&self) -> usize {
        self.actions.len()
    }

    /// Undiscounted reward sum over valid steps (diagnostics).
    pub fn reward_sum(&self) -> f64 {
        self.rewards[..self.valid_len]
            .iter()
            .map(|&r| r as f64)
            .sum()
    }
}

/// Recycling arena for [`Sequence`] slab buffers.
///
/// Builders `acquire` zeroed, exact-size slabs; replay evictions and
/// learner-released batches `release` their `Arc<Sequence>` handles back
/// (the buffer recycles once the last holder lets go), and tests or
/// benches can `put` owned sequences directly. Hit/miss counters feed
/// the `actor.pool_hit_rate` gauge: a hit means the allocator was never
/// involved in producing a sequence slab.
pub struct SequencePool {
    free: Mutex<Vec<Sequence>>,
    /// Free-list cap; `put` beyond it drops the buffer instead of
    /// growing without bound.
    max_free: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for SequencePool {
    fn default() -> Self {
        Self::new()
    }
}

impl SequencePool {
    pub fn new() -> Self {
        // Generous default: a full default replay ring's worth of slabs.
        Self::with_capacity(4_096)
    }

    pub fn with_capacity(max_free: usize) -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            max_free,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Take a zeroed `Sequence` with exactly the requested shape,
    /// reusing a recycled slab's buffers when one is available (no
    /// allocation when the recycled capacities already fit).
    pub fn acquire(
        &self,
        seq_len: usize,
        obs_len: usize,
        hidden: usize,
        actor_id: usize,
    ) -> Sequence {
        let recycled = self.free.lock().unwrap().pop();
        let mut s = match recycled {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Sequence::default()
            }
        };
        s.obs.clear();
        s.obs.resize(seq_len * obs_len, 0.0);
        s.actions.clear();
        s.actions.resize(seq_len, 0);
        s.rewards.clear();
        s.rewards.resize(seq_len, 0.0);
        s.discounts.clear();
        s.discounts.resize(seq_len, 0.0);
        s.h0.clear();
        s.h0.resize(hidden, 0.0);
        s.c0.clear();
        s.c0.resize(hidden, 0.0);
        s.actor_id = actor_id;
        s.valid_len = 0;
        s
    }

    /// Return an owned sequence's buffers to the free list (dropped if
    /// the list is at capacity).
    pub fn put(&self, seq: Sequence) {
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_free {
            free.push(seq);
        }
    }

    /// Recycle a shared handle if this is the last one (replay already
    /// evicted the slot, or the learner was the final holder); a still-
    /// shared handle is simply dropped.
    pub fn release(&self, seq: Arc<Sequence>) {
        if let Ok(s) = Arc::try_unwrap(seq) {
            self.put(s);
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fraction of acquires served from recycled buffers (0 when the
    /// pool has never been asked).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits();
        let m = self.misses();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Buffers currently parked on the free list (diagnostic/test API).
    pub fn free_len(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// Slices one actor's transition stream into overlapping sequences,
/// writing each transition directly into the time-major slab of the
/// `Sequence` under construction (see the module docs for the padding
/// contract and the allocation story).
pub struct SequenceBuilder {
    seq_len: usize,
    overlap: usize,
    obs_len: usize,
    hidden: usize,
    actor_id: usize,
    pool: Option<Arc<SequencePool>>,
    /// The slab being filled; emitted (and replaced) when complete.
    cur: Sequence,
    /// Transitions currently written into `cur`.
    len: usize,
    /// Recurrent state before each buffered transition, time-major
    /// [seq_len, hidden]: `hs[i]` is the `h` the actor held when it
    /// pushed `cur`'s transition `i`. Kept outside the `Sequence` (which
    /// only stores the step-0 snapshot) so the overlap tail carried into
    /// the next sequence still knows its start state.
    hs: Vec<f32>,
    cs: Vec<f32>,
}

impl SequenceBuilder {
    pub fn new(
        seq_len: usize,
        overlap: usize,
        obs_len: usize,
        hidden: usize,
        actor_id: usize,
    ) -> Self {
        assert!(overlap < seq_len, "overlap must be < seq_len");
        let mut b = Self {
            seq_len,
            overlap,
            obs_len,
            hidden,
            actor_id,
            pool: None,
            cur: Sequence::default(),
            len: 0,
            hs: vec![0.0; seq_len * hidden],
            cs: vec![0.0; seq_len * hidden],
        };
        b.cur = b.fresh_slab();
        b
    }

    /// Draw emitted slabs from (and thereby recycle through) `pool`.
    pub fn with_pool(mut self, pool: Arc<SequencePool>) -> Self {
        self.pool = Some(pool);
        self
    }

    fn fresh_slab(&self) -> Sequence {
        match &self.pool {
            Some(p) => p.acquire(self.seq_len, self.obs_len, self.hidden, self.actor_id),
            None => Sequence {
                obs: vec![0.0; self.seq_len * self.obs_len],
                actions: vec![0; self.seq_len],
                rewards: vec![0.0; self.seq_len],
                discounts: vec![0.0; self.seq_len],
                h0: vec![0.0; self.hidden],
                c0: vec![0.0; self.hidden],
                actor_id: self.actor_id,
                valid_len: 0,
            },
        }
    }

    /// Feed one owned transition; returns a completed sequence when
    /// available. Compatibility wrapper over [`Self::push_slices`].
    pub fn push(&mut self, t: Transition) -> Option<Sequence> {
        self.push_slices(&t.obs, t.action, t.reward, t.discount, &t.h, &t.c)
    }

    /// Feed one transition as borrowed rows — the zero-copy entry point:
    /// the actor passes slices of its slot slabs and nothing is
    /// heap-allocated on the way in.
    pub fn push_slices(
        &mut self,
        obs: &[f32],
        action: i32,
        reward: f32,
        discount: f32,
        h: &[f32],
        c: &[f32],
    ) -> Option<Sequence> {
        debug_assert_eq!(obs.len(), self.obs_len);
        debug_assert_eq!(h.len(), self.hidden);
        debug_assert_eq!(c.len(), self.hidden);
        let i = self.len;
        let ol = self.obs_len;
        let hd = self.hidden;
        self.cur.obs[i * ol..(i + 1) * ol].copy_from_slice(obs);
        self.cur.actions[i] = action;
        self.cur.rewards[i] = reward;
        self.cur.discounts[i] = discount;
        self.hs[i * hd..(i + 1) * hd].copy_from_slice(h);
        self.cs[i * hd..(i + 1) * hd].copy_from_slice(c);
        self.len += 1;
        if self.len == self.seq_len {
            return Some(self.emit_full());
        }
        if discount == 0.0 {
            // Terminal short of the boundary: the slab's tail is already
            // zeroed (padding contract) — emit and start fresh.
            return Some(self.emit_and_reset());
        }
        None
    }

    /// Flush a partial buffer at shutdown (None if empty). Drops the
    /// overlap invariant: see the module docs.
    pub fn flush(&mut self) -> Option<Sequence> {
        if self.len == 0 {
            return None;
        }
        Some(self.emit_and_reset())
    }

    pub fn buffered(&self) -> usize {
        self.len
    }

    /// Emit the full slab, seeding the next one with the overlap tail.
    fn emit_full(&mut self) -> Sequence {
        let stride = self.seq_len - self.overlap;
        let (ol, hd) = (self.obs_len, self.hidden);
        let mut next = self.fresh_slab();
        next.obs[..self.overlap * ol]
            .copy_from_slice(&self.cur.obs[stride * ol..]);
        next.actions[..self.overlap].copy_from_slice(&self.cur.actions[stride..]);
        next.rewards[..self.overlap].copy_from_slice(&self.cur.rewards[stride..]);
        next.discounts[..self.overlap]
            .copy_from_slice(&self.cur.discounts[stride..]);
        self.cur.h0.copy_from_slice(&self.hs[..hd]);
        self.cur.c0.copy_from_slice(&self.cs[..hd]);
        self.cur.valid_len = self.seq_len;
        // Keep hs/cs aligned with the carried-over tail rows.
        self.hs.copy_within(stride * hd.., 0);
        self.cs.copy_within(stride * hd.., 0);
        self.len = self.overlap;
        std::mem::replace(&mut self.cur, next)
    }

    /// Emit the (partial, zero-padded) slab and restart empty — the
    /// terminal / flush path, which carries no overlap forward.
    fn emit_and_reset(&mut self) -> Sequence {
        let next = self.fresh_slab();
        self.cur.h0.copy_from_slice(&self.hs[..self.hidden]);
        self.cur.c0.copy_from_slice(&self.cs[..self.hidden]);
        self.cur.valid_len = self.len;
        self.len = 0;
        std::mem::replace(&mut self.cur, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(v: f32, discount: f32) -> Transition {
        Transition {
            obs: vec![v; 4],
            action: v as i32,
            reward: v,
            discount,
            h: vec![v; 2],
            c: vec![-v; 2],
        }
    }

    #[test]
    fn emits_full_sequences_with_overlap() {
        let mut b = SequenceBuilder::new(4, 2, 4, 2, 0);
        let mut seqs = Vec::new();
        for i in 0..10 {
            if let Some(s) = b.push(tr(i as f32, 0.99)) {
                seqs.push(s);
            }
        }
        // Starts at 0, 2, 4, 6: 4 sequences from 10 steps.
        assert_eq!(seqs.len(), 4);
        assert_eq!(seqs[0].actions, vec![0, 1, 2, 3]);
        assert_eq!(seqs[1].actions, vec![2, 3, 4, 5]);
        assert_eq!(seqs[1].h0, vec![2.0, 2.0]);
        assert_eq!(seqs[0].valid_len, 4);
    }

    #[test]
    fn deep_overlap_chains_recurrent_state() {
        // overlap > stride: the next sequence's start state comes from a
        // transition that itself arrived as carried-over tail — the
        // staging shift must keep h0 exact across chained overlaps.
        let mut b = SequenceBuilder::new(4, 3, 4, 2, 0);
        let mut seqs = Vec::new();
        for i in 0..8 {
            if let Some(s) = b.push(tr(i as f32, 0.99)) {
                seqs.push(s);
            }
        }
        // Starts at 0, 1, 2, 3, 4: 5 sequences from 8 steps.
        assert_eq!(seqs.len(), 5);
        for (k, s) in seqs.iter().enumerate() {
            assert_eq!(
                s.actions,
                (k as i32..k as i32 + 4).collect::<Vec<_>>(),
                "sequence {k}"
            );
            assert_eq!(s.h0, vec![k as f32; 2], "sequence {k} start state");
            assert_eq!(s.c0, vec![-(k as f32); 2], "sequence {k} start state");
        }
    }

    #[test]
    fn terminal_pads_and_resets() {
        let mut b = SequenceBuilder::new(5, 2, 4, 2, 1);
        assert!(b.push(tr(1.0, 0.99)).is_none());
        let s = b.push(tr(2.0, 0.0)).expect("terminal flush");
        assert_eq!(s.valid_len, 2);
        assert_eq!(s.actions, vec![1, 2, 0, 0, 0]);
        assert_eq!(s.discounts, vec![0.99, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(s.rewards[2], 0.0);
        assert_eq!(b.buffered(), 0);
        // Next sequence starts from scratch.
        assert!(b.push(tr(3.0, 0.99)).is_none());
    }

    #[test]
    fn terminal_exactly_at_boundary_not_double_emitted() {
        let mut b = SequenceBuilder::new(3, 1, 4, 2, 0);
        assert!(b.push(tr(1.0, 0.9)).is_none());
        assert!(b.push(tr(2.0, 0.9)).is_none());
        let s = b.push(tr(3.0, 0.0)).unwrap();
        assert_eq!(s.valid_len, 3);
        // Overlap tail retained (terminal transition carried into overlap
        // is acceptable: its discount 0 cuts bootstrap).
        assert_eq!(b.buffered(), 1);
    }

    #[test]
    fn flush_returns_partial() {
        let mut b = SequenceBuilder::new(4, 1, 4, 2, 0);
        b.push(tr(1.0, 0.9));
        let s = b.flush().unwrap();
        assert_eq!(s.valid_len, 1);
        assert!(b.flush().is_none());
    }

    #[test]
    fn flush_then_reuse_starts_clean() {
        // Padding-contract regression: a builder reused after flush must
        // start a fresh trajectory — no overlap tail, no stale slab
        // rows, no stale recurrent state leaking from before the flush.
        let mut b = SequenceBuilder::new(4, 2, 4, 2, 0);
        b.push(tr(7.0, 0.9));
        b.push(tr(8.0, 0.9));
        b.push(tr(9.0, 0.9));
        let flushed = b.flush().unwrap();
        assert_eq!(flushed.valid_len, 3);
        assert_eq!(b.buffered(), 0);
        let mut seqs = Vec::new();
        for i in 0..4 {
            if let Some(s) = b.push(tr(i as f32, 0.9)) {
                seqs.push(s);
            }
        }
        assert_eq!(seqs.len(), 1);
        // Entirely the new transitions: nothing from 7/8/9 leaked.
        assert_eq!(seqs[0].actions, vec![0, 1, 2, 3]);
        assert_eq!(seqs[0].h0, vec![0.0, 0.0]);
        assert_eq!(seqs[0].obs[..4], [0.0; 4]);
        assert_eq!(seqs[0].valid_len, 4);
    }

    #[test]
    fn reward_sum_ignores_padding() {
        let mut b = SequenceBuilder::new(5, 1, 4, 2, 0);
        b.push(tr(2.0, 0.9));
        let s = b.push(tr(3.0, 0.0)).unwrap();
        assert_eq!(s.reward_sum(), 5.0);
    }

    #[test]
    fn push_slices_matches_push() {
        let mut a = SequenceBuilder::new(4, 2, 3, 2, 5);
        let mut b = SequenceBuilder::new(4, 2, 3, 2, 5);
        for i in 0..13 {
            let t = Transition {
                obs: vec![i as f32; 3],
                action: i,
                reward: i as f32 * 0.5,
                discount: if i % 5 == 4 { 0.0 } else { 0.97 },
                h: vec![i as f32 * 0.1; 2],
                c: vec![i as f32 * -0.1; 2],
            };
            let sa = a.push_slices(
                &t.obs, t.action, t.reward, t.discount, &t.h, &t.c,
            );
            let sb = b.push(t);
            assert_eq!(sa, sb, "step {i}");
        }
        assert_eq!(a.flush(), b.flush());
    }

    #[test]
    fn pool_recycles_and_counts() {
        let pool = Arc::new(SequencePool::with_capacity(8));
        let mut b =
            SequenceBuilder::new(3, 1, 2, 2, 0).with_pool(pool.clone());
        let mut emitted = Vec::new();
        for i in 0..9 {
            if let Some(s) = b.push(tr(i as f32, 0.9)) {
                emitted.push(s);
            }
        }
        assert!(!emitted.is_empty());
        // Nothing returned yet: every slab was a miss.
        assert_eq!(pool.hits(), 0);
        assert!(pool.misses() > 0);
        for s in emitted {
            pool.put(s);
        }
        let parked = pool.free_len();
        assert!(parked > 0);
        // With buffers parked, the next emits are hits, and acquire
        // hands back fully zeroed, right-sized slabs.
        let miss_before = pool.misses();
        for i in 0..9 {
            if let Some(s) = b.push(tr(i as f32, 0.9)) {
                assert_eq!(s.seq_len(), 3);
                assert_eq!(s.obs.len(), 6);
                pool.put(s);
            }
        }
        assert!(pool.hits() > 0);
        assert_eq!(pool.misses(), miss_before, "no new allocations");
        assert!(pool.hit_rate() > 0.0);
    }

    #[test]
    fn pooled_acquire_zeroes_stale_data() {
        let pool = SequencePool::with_capacity(4);
        pool.put(Sequence {
            obs: vec![9.0; 6],
            actions: vec![9; 3],
            rewards: vec![9.0; 3],
            discounts: vec![9.0; 3],
            h0: vec![9.0; 2],
            c0: vec![9.0; 2],
            actor_id: 7,
            valid_len: 3,
        });
        let s = pool.acquire(3, 2, 2, 1);
        assert_eq!(s.obs, vec![0.0; 6]);
        assert_eq!(s.actions, vec![0; 3]);
        assert_eq!(s.rewards, vec![0.0; 3]);
        assert_eq!(s.discounts, vec![0.0; 3]);
        assert_eq!(s.h0, vec![0.0; 2]);
        assert_eq!(s.actor_id, 1);
        assert_eq!(s.valid_len, 0);
        assert_eq!(pool.hits(), 1);
    }

    #[test]
    fn release_recycles_only_last_handle() {
        let pool = SequencePool::with_capacity(4);
        let a = Arc::new(Sequence::default());
        let b = a.clone();
        pool.release(a); // still shared: dropped, not recycled
        assert_eq!(pool.free_len(), 0);
        pool.release(b); // last handle: recycled
        assert_eq!(pool.free_len(), 1);
    }
}
