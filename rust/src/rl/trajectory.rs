//! Trajectory types and the R2D2 sequence slicer.
//!
//! Actors produce transitions; the learner consumes fixed-length
//! sequences (burn_in + unroll) with the recurrent state snapshotted at
//! the sequence start and adjacent sequences overlapping (R2D2 uses
//! 80/40; our AOT default is 20/10, same ratio). Episode ends are
//! zero-padded (discount 0 masks the pad in the loss).

/// One actor transition: the observation fed to inference, the action
/// taken, and the immediate outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub obs: Vec<f32>,
    pub action: i32,
    pub reward: f32,
    /// gamma * (1 - done): 0 at terminals.
    pub discount: f32,
    /// Recurrent state *before* this observation was processed.
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// A fixed-length training sequence (the replay/learner unit).
#[derive(Clone, Debug, PartialEq)]
pub struct Sequence {
    /// [T * obs_len], time-major.
    pub obs: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub discounts: Vec<f32>,
    /// Recurrent state at sequence start.
    pub h0: Vec<f32>,
    pub c0: Vec<f32>,
    pub actor_id: usize,
    /// Real (non-padded) steps.
    pub valid_len: usize,
}

impl Sequence {
    pub fn seq_len(&self) -> usize {
        self.actions.len()
    }

    /// Undiscounted reward sum over valid steps (diagnostics).
    pub fn reward_sum(&self) -> f64 {
        self.rewards[..self.valid_len]
            .iter()
            .map(|&r| r as f64)
            .sum()
    }
}

/// Slices one actor's transition stream into overlapping sequences.
pub struct SequenceBuilder {
    seq_len: usize,
    overlap: usize,
    obs_len: usize,
    hidden: usize,
    actor_id: usize,
    buf: Vec<Transition>,
}

impl SequenceBuilder {
    pub fn new(
        seq_len: usize,
        overlap: usize,
        obs_len: usize,
        hidden: usize,
        actor_id: usize,
    ) -> Self {
        assert!(overlap < seq_len, "overlap must be < seq_len");
        Self {
            seq_len,
            overlap,
            obs_len,
            hidden,
            actor_id,
            buf: Vec::with_capacity(seq_len),
        }
    }

    /// Feed one transition; returns a completed sequence when available.
    pub fn push(&mut self, t: Transition) -> Option<Sequence> {
        debug_assert_eq!(t.obs.len(), self.obs_len);
        debug_assert_eq!(t.h.len(), self.hidden);
        let terminal = t.discount == 0.0;
        self.buf.push(t);
        if self.buf.len() == self.seq_len {
            let seq = self.emit(self.seq_len);
            // Keep the overlap tail for the next sequence.
            self.buf.drain(..self.seq_len - self.overlap);
            return Some(seq);
        }
        if terminal {
            // Pad out the remainder and start fresh.
            let seq = self.emit(self.buf.len());
            self.buf.clear();
            return Some(seq);
        }
        None
    }

    /// Flush a partial buffer at shutdown (None if empty).
    pub fn flush(&mut self) -> Option<Sequence> {
        if self.buf.is_empty() {
            return None;
        }
        let seq = self.emit(self.buf.len());
        self.buf.clear();
        Some(seq)
    }

    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    fn emit(&self, valid: usize) -> Sequence {
        let t_len = self.seq_len;
        let mut obs = vec![0.0f32; t_len * self.obs_len];
        let mut actions = vec![0i32; t_len];
        let mut rewards = vec![0.0f32; t_len];
        let mut discounts = vec![0.0f32; t_len];
        for (i, tr) in self.buf.iter().take(valid).enumerate() {
            obs[i * self.obs_len..(i + 1) * self.obs_len].copy_from_slice(&tr.obs);
            actions[i] = tr.action;
            rewards[i] = tr.reward;
            discounts[i] = tr.discount;
        }
        Sequence {
            obs,
            actions,
            rewards,
            discounts,
            h0: self.buf[0].h.clone(),
            c0: self.buf[0].c.clone(),
            actor_id: self.actor_id,
            valid_len: valid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(v: f32, discount: f32) -> Transition {
        Transition {
            obs: vec![v; 4],
            action: v as i32,
            reward: v,
            discount,
            h: vec![v; 2],
            c: vec![-v; 2],
        }
    }

    #[test]
    fn emits_full_sequences_with_overlap() {
        let mut b = SequenceBuilder::new(4, 2, 4, 2, 0);
        let mut seqs = Vec::new();
        for i in 0..10 {
            if let Some(s) = b.push(tr(i as f32, 0.99)) {
                seqs.push(s);
            }
        }
        // Starts at 0, 2, 4, 6: 4 sequences from 10 steps.
        assert_eq!(seqs.len(), 4);
        assert_eq!(seqs[0].actions, vec![0, 1, 2, 3]);
        assert_eq!(seqs[1].actions, vec![2, 3, 4, 5]);
        assert_eq!(seqs[1].h0, vec![2.0, 2.0]);
        assert_eq!(seqs[0].valid_len, 4);
    }

    #[test]
    fn terminal_pads_and_resets() {
        let mut b = SequenceBuilder::new(5, 2, 4, 2, 1);
        assert!(b.push(tr(1.0, 0.99)).is_none());
        let s = b.push(tr(2.0, 0.0)).expect("terminal flush");
        assert_eq!(s.valid_len, 2);
        assert_eq!(s.actions, vec![1, 2, 0, 0, 0]);
        assert_eq!(s.discounts, vec![0.99, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(s.rewards[2], 0.0);
        assert_eq!(b.buffered(), 0);
        // Next sequence starts from scratch.
        assert!(b.push(tr(3.0, 0.99)).is_none());
    }

    #[test]
    fn terminal_exactly_at_boundary_not_double_emitted() {
        let mut b = SequenceBuilder::new(3, 1, 4, 2, 0);
        assert!(b.push(tr(1.0, 0.9)).is_none());
        assert!(b.push(tr(2.0, 0.9)).is_none());
        let s = b.push(tr(3.0, 0.0)).unwrap();
        assert_eq!(s.valid_len, 3);
        // Overlap tail retained (terminal transition carried into overlap
        // is acceptable: its discount 0 cuts bootstrap).
        assert_eq!(b.buffered(), 1);
    }

    #[test]
    fn flush_returns_partial() {
        let mut b = SequenceBuilder::new(4, 1, 4, 2, 0);
        b.push(tr(1.0, 0.9));
        let s = b.flush().unwrap();
        assert_eq!(s.valid_len, 1);
        assert!(b.flush().is_none());
    }

    #[test]
    fn reward_sum_ignores_padding() {
        let mut b = SequenceBuilder::new(5, 1, 4, 2, 0);
        b.push(tr(2.0, 0.9));
        let s = b.push(tr(3.0, 0.0)).unwrap();
        assert_eq!(s.reward_sum(), 5.0);
    }
}
