//! RL algorithm utilities: exploration schedules, return math, and the
//! trajectory -> sequence slicing that feeds R2D2's replay.
//!
//! The learner's loss itself lives in the AOT'd JAX graph (L2); this
//! module is the Rust-side mirror used by actors, tests, and diagnostics.

pub mod epsilon;
pub mod returns;
pub mod trajectory;

pub use epsilon::{actor_epsilon, LinearDecay};
pub use returns::{episode_return, n_step_return, value_rescale, value_rescale_inv};
pub use trajectory::{Sequence, SequenceBuilder, SequencePool, Transition};

/// Greedy argmax over a q-row; ties break to the lowest index.
pub fn argmax(q: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in q.iter().enumerate() {
        if v > q[best] {
            best = i;
        }
    }
    best
}

/// Epsilon-greedy action selection.
pub fn epsilon_greedy(
    q: &[f32],
    epsilon: f64,
    rng: &mut crate::util::prng::Pcg32,
) -> usize {
    if rng.chance(epsilon) {
        rng.index(q.len())
    } else {
        argmax(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[0.5, 0.5]), 0);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn epsilon_zero_is_greedy() {
        let mut rng = Pcg32::seeded(0);
        for _ in 0..100 {
            assert_eq!(epsilon_greedy(&[0.0, 1.0, 0.5], 0.0, &mut rng), 1);
        }
    }

    #[test]
    fn epsilon_one_is_uniform() {
        let mut rng = Pcg32::seeded(1);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[epsilon_greedy(&[9.0, 0.0, 0.0], 1.0, &mut rng)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }
}
