//! Per-actor exploration schedules (Ape-X / R2D2 form).
//!
//! Actor i of N uses a fixed epsilon
//!     eps_i = base^(1 + alpha * i / (N - 1))
//! so the pool spans a spectrum from greedy-ish (i=0) to exploratory.
//! R2D2 uses base = 0.4, alpha = 7 over 256 actors; we keep the same
//! functional form at any pool size.

/// Epsilon for actor `i` in a pool of `n`.
pub fn actor_epsilon(i: usize, n: usize, base: f64, alpha: f64) -> f64 {
    debug_assert!(i < n.max(1));
    if n <= 1 {
        return base;
    }
    let exponent = 1.0 + alpha * i as f64 / (n - 1) as f64;
    base.powf(exponent)
}

/// Linearly decaying epsilon (used by single-actor examples).
#[derive(Clone, Debug)]
pub struct LinearDecay {
    pub start: f64,
    pub end: f64,
    pub steps: u64,
}

impl LinearDecay {
    pub fn at(&self, step: u64) -> f64 {
        if step >= self.steps {
            return self.end;
        }
        let frac = step as f64 / self.steps as f64;
        self.start + (self.end - self.start) * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrum_is_monotone_decreasing() {
        let n = 64;
        let eps: Vec<f64> = (0..n).map(|i| actor_epsilon(i, n, 0.4, 7.0)).collect();
        for w in eps.windows(2) {
            assert!(w[0] > w[1]);
        }
        // First actor: base^1 = 0.4; last: base^8 ≈ 0.00066.
        assert!((eps[0] - 0.4).abs() < 1e-12);
        assert!((eps[n - 1] - 0.4f64.powf(8.0)).abs() < 1e-9);
    }

    #[test]
    fn single_actor_uses_base() {
        assert_eq!(actor_epsilon(0, 1, 0.4, 7.0), 0.4);
    }

    #[test]
    fn all_epsilons_in_unit_interval() {
        for n in [1, 2, 8, 256] {
            for i in 0..n {
                let e = actor_epsilon(i, n, 0.4, 7.0);
                assert!((0.0..=1.0).contains(&e), "n={n} i={i} e={e}");
            }
        }
    }

    #[test]
    fn linear_decay_endpoints() {
        let d = LinearDecay {
            start: 1.0,
            end: 0.05,
            steps: 100,
        };
        assert_eq!(d.at(0), 1.0);
        assert!((d.at(50) - 0.525).abs() < 1e-12);
        assert_eq!(d.at(100), 0.05);
        assert_eq!(d.at(10_000), 0.05);
    }
}
