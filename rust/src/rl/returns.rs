//! Return/value utilities mirrored from the L2 loss (Rust side is used
//! for actor-side diagnostics and tests; the learner math runs in the AOT
//! graph). Mirroring lets integration tests cross-check the two layers.

/// R2D2 invertible value rescaling h(x) = sign(x)(sqrt(|x|+1)-1) + eps*x.
pub fn value_rescale(x: f64, eps: f64) -> f64 {
    x.signum() * ((x.abs() + 1.0).sqrt() - 1.0) + eps * x
}

/// Inverse of `value_rescale` (closed form from the R2D2 paper).
pub fn value_rescale_inv(x: f64, eps: f64) -> f64 {
    let a = (1.0 + 4.0 * eps * (x.abs() + 1.0 + eps)).sqrt();
    x.signum() * (((a - 1.0) / (2.0 * eps)).powi(2) - 1.0)
}

/// Discounted n-step return over a window:
/// G_t = sum_{k<n} (prod_{j<k} d_{t+j}) r_{t+k} + (prod_{j<n} d_{t+j}) * boot
/// where `d` are per-step discounts (gamma * (1-done)) and `boot` the
/// bootstrap value at t+n. Inputs index from t; panics if the window is
/// shorter than n.
pub fn n_step_return(rewards: &[f32], discounts: &[f32], n: usize, bootstrap: f64) -> f64 {
    assert!(rewards.len() >= n && discounts.len() >= n);
    let mut ret = 0.0;
    let mut cum = 1.0;
    for k in 0..n {
        ret += cum * rewards[k] as f64;
        cum *= discounts[k] as f64;
    }
    ret + cum * bootstrap
}

/// Monte-Carlo episode return (diagnostics).
pub fn episode_return(rewards: &[f32]) -> f64 {
    rewards.iter().map(|&r| r as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, prop_close};

    #[test]
    fn rescale_roundtrip_property() {
        forall(300, |g| {
            let x = g.f64(-1e4..1e4);
            let y = value_rescale_inv(value_rescale(x, 1e-3), 1e-3);
            prop_close(y, x, 1e-6)
        });
    }

    #[test]
    fn rescale_compresses() {
        assert!(value_rescale(100.0, 1e-3) < 100.0);
        assert!(value_rescale(0.0, 1e-3) == 0.0);
        assert!(value_rescale(-100.0, 1e-3) > -100.0);
    }

    #[test]
    fn n_step_matches_hand_computation() {
        let r = [1.0f32, 2.0, 3.0];
        let d = [0.9f32, 0.9, 0.9];
        // G = 1 + .9*2 + .81*3 + .729*10 = 1+1.8+2.43+7.29
        let g = n_step_return(&r, &d, 3, 10.0);
        assert!((g - (1.0 + 1.8 + 2.43 + 7.29)).abs() < 1e-5); // f32 discounts
    }

    #[test]
    fn terminal_cuts_bootstrap() {
        let r = [1.0f32, 1.0];
        let d = [0.0f32, 0.9]; // terminal after first step
        let g = n_step_return(&r, &d, 2, 100.0);
        assert!((g - 1.0).abs() < 1e-9);
    }

    #[test]
    fn n_one_is_td_target() {
        let g = n_step_return(&[2.0], &[0.5], 1, 8.0);
        assert!((g - (2.0 + 0.5 * 8.0)).abs() < 1e-12);
    }
}
