//! The fleet transport layer: slab frames over TCP / Unix-domain
//! sockets (DESIGN.md §14).
//!
//! This is ROADMAP open item 1 — scaling the SEED-style central-
//! inference architecture past one process, the SRL direction
//! (PAPERS.md, 2306.16688). The layer has three stories:
//!
//! * [`frame`] — the codec: length-prefixed frames whose payloads are
//!   the pooled slab protocol's buffers serialized verbatim (obs
//!   submissions out, `ReplyRange`-shaped reply chunks back,
//!   ticket-tagged), plus sequence-ingest and hello/goodbye control
//!   frames. Encode and decode reuse caller buffers: the wire path is
//!   allocation-free in steady state, like the in-process path it
//!   mirrors (`micro_transport --quick` gate).
//! * [`client`] — the worker side: [`RemoteClient`] implements the
//!   split-phase [`crate::policy::PolicyClient`] over a socket (so
//!   `coordinator::actor` runs unmodified in a worker process) with
//!   reconnect-with-backoff and in-flight resubmission, and
//!   [`RemoteIngest`] ships completed sequences to the central replay
//!   through the same [`crate::replay::SequenceSink`] seam the local
//!   buffer implements.
//! * [`server`] — the coordinator side: [`FleetServer`] multiplexes
//!   many remote actor connections into the existing pooled batcher
//!   (one reader + one writer thread per connection, recycled slabs,
//!   per-connection mailboxes), with bounded in-flight rows per
//!   connection (excess submissions are shed as error replies and
//!   counted in `fleet.shed_rows`, never a stall) and a clean drain on
//!   shutdown (flush outstanding replies, send goodbye, close).
//!
//! This module holds what both sides share: the `tcp:`/`uds:` address
//! scheme, the [`Stream`]/[`Listener`] abstraction over the two socket
//! families, dial-with-backoff, and the timeout-tolerant
//! [`FrameReader`] both ends read frames through.

pub mod client;
pub mod frame;
pub mod liveness;
pub mod server;

pub use client::{RemoteClient, RemoteClientOpts, RemoteIngest};
pub use liveness::{DeadlineEwma, Heartbeat, Liveness};
pub use server::{ConnRegistry, FleetServer, FleetServerOpts};

use crate::exec::ShutdownToken;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// A parsed fleet address: `tcp:host:port` (or bare `host:port`) for
/// TCP, `uds:/path` (or `unix:/path`) for Unix-domain sockets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Addr {
    Tcp(String),
    Unix(PathBuf),
}

impl Addr {
    pub fn parse(s: &str) -> anyhow::Result<Addr> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty fleet address");
        if let Some(p) = s.strip_prefix("uds:").or_else(|| s.strip_prefix("unix:")) {
            anyhow::ensure!(!p.is_empty(), "empty uds path in fleet address `{s}`");
            return Ok(Addr::Unix(PathBuf::from(p)));
        }
        let hp = s.strip_prefix("tcp:").unwrap_or(s);
        anyhow::ensure!(
            hp.rsplit_once(':')
                .is_some_and(|(h, p)| !h.is_empty() && p.parse::<u16>().is_ok()),
            "fleet address `{s}` is not tcp:host:port or uds:/path"
        );
        Ok(Addr::Tcp(hp.to_string()))
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Tcp(hp) => write!(f, "tcp:{hp}"),
            Addr::Unix(p) => write!(f, "uds:{}", p.display()),
        }
    }
}

/// One connected socket of either family. Delegates `Read`/`Write`;
/// `try_clone` splits it into independently-owned read/write halves.
pub enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    pub fn try_clone(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    /// Bound the blocking window of reads so readers can poll a
    /// shutdown token between attempts (the [`FrameReader`] resumes a
    /// partial frame across timeouts without losing sync).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    pub fn set_write_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(d),
            Stream::Unix(s) => s.set_write_timeout(d),
        }
    }

    /// Disable Nagle on TCP (latency over throughput: inference
    /// round-trips are the actor's critical path); no-op on UDS.
    pub fn set_nodelay(&self) {
        if let Stream::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }

    /// Half-close the write side so the peer's reader sees EOF.
    pub fn shutdown_write(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Write),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Write),
        };
    }

    /// Close both directions: the peer's blocked reads fail immediately
    /// (liveness reaping uses this so a reaped-but-alive client notices
    /// at its next read slice instead of at its next write).
    pub fn shutdown_both(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }

    /// Human-readable peer identity for error attribution (`conn N
    /// (<peer>)` in fleet errors). Allocates; error/log paths only.
    pub fn peer_desc(&self) -> String {
        match self {
            Stream::Tcp(s) => s
                .peer_addr()
                .map(|a| format!("tcp:{a}"))
                .unwrap_or_else(|_| "tcp:?".into()),
            Stream::Unix(s) => s
                .peer_addr()
                .ok()
                .and_then(|a| a.as_pathname().map(|p| format!("uds:{}", p.display())))
                .unwrap_or_else(|| "uds:@".into()),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound, non-blocking listener of either family; the server's accept
/// loop polls it between shutdown checks.
pub enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    /// Bind `addr`. A stale UDS socket file from a previous run is
    /// removed first (the standard idiom — nothing can be connected to
    /// it once its listener is gone).
    pub fn bind(addr: &Addr) -> anyhow::Result<Listener> {
        let l = match addr {
            Addr::Tcp(hp) => Listener::Tcp(
                TcpListener::bind(hp)
                    .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?,
            ),
            Addr::Unix(p) => {
                let _ = std::fs::remove_file(p);
                Listener::Unix(
                    UnixListener::bind(p)
                        .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?,
                )
            }
        };
        match &l {
            Listener::Tcp(s) => s.set_nonblocking(true)?,
            Listener::Unix(s) => s.set_nonblocking(true)?,
        }
        Ok(l)
    }

    /// The actual bound TCP address (port 0 resolution for tests); the
    /// configured path for UDS.
    pub fn local_addr(&self) -> anyhow::Result<Addr> {
        Ok(match self {
            Listener::Tcp(s) => Addr::Tcp(s.local_addr()?.to_string()),
            Listener::Unix(s) => Addr::Unix(
                s.local_addr()?
                    .as_pathname()
                    .map(PathBuf::from)
                    .unwrap_or_default(),
            ),
        })
    }

    /// Non-blocking accept: `Ok(None)` when nothing is pending.
    pub fn poll_accept(&self) -> std::io::Result<Option<Stream>> {
        let r = match self {
            Listener::Tcp(s) => s.accept().map(|(c, _)| Stream::Tcp(c)),
            Listener::Unix(s) => s.accept().map(|(c, _)| Stream::Unix(c)),
        };
        match r {
            Ok(c) => {
                // Accepted sockets inherit non-blocking on some
                // platforms: force blocking, reads are timeout-bounded.
                match &c {
                    Stream::Tcp(s) => s.set_nonblocking(false)?,
                    Stream::Unix(s) => s.set_nonblocking(false)?,
                }
                Ok(Some(c))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }
}

/// Dial `addr`, retrying with exponential backoff (`backoff_ms`
/// doubling per attempt, capped at 2 s) up to `retries + 1` attempts.
/// A signalled shutdown token aborts the wait early.
pub fn dial(
    addr: &Addr,
    retries: usize,
    backoff_ms: u64,
    shutdown: Option<&ShutdownToken>,
) -> anyhow::Result<Stream> {
    let mut wait = Duration::from_millis(backoff_ms.max(1));
    let cap = Duration::from_secs(2);
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..=retries {
        if let Some(t) = shutdown {
            if t.is_signalled() {
                anyhow::bail!("dial {addr}: shutdown signalled");
            }
        }
        let r = match addr {
            Addr::Tcp(hp) => TcpStream::connect(hp).map(Stream::Tcp),
            Addr::Unix(p) => UnixStream::connect(p).map(Stream::Unix),
        };
        match r {
            Ok(s) => {
                s.set_nodelay();
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
        if attempt < retries {
            match shutdown {
                Some(t) => {
                    if t.sleep_interruptible(wait) {
                        anyhow::bail!("dial {addr}: shutdown signalled");
                    }
                }
                None => std::thread::sleep(wait),
            }
            wait = (wait * 2).min(cap);
        }
    }
    anyhow::bail!(
        "dial {addr}: {} (after {} attempts)",
        last.expect("at least one attempt"),
        retries + 1
    )
}

/// Why [`FrameReader::read_frame`] returned without a frame.
#[derive(Debug, PartialEq, Eq)]
pub enum ReadOutcome {
    /// A whole frame is in the reader's buffer.
    Frame,
    /// Clean EOF on a frame boundary (peer closed).
    Eof,
    /// The stop predicate fired between read attempts.
    Stopped,
    /// The caller's wake-up instant passed without a complete frame
    /// ([`FrameReader::read_frame_until`] only; partial progress is
    /// retained and the next call resumes the same frame). Heartbeat
    /// sends, ticket deadlines, and liveness reaping all hang off this.
    TimedOut,
}

/// Reads length-prefixed frames off a [`Stream`], tolerant of read
/// timeouts (a partial frame resumes across them — sync is never lost)
/// and polling a caller predicate so a blocked reader can notice
/// shutdown. The frame buffer is reused across reads: steady state
/// allocates nothing once capacity covers the largest frame seen.
/// Partial progress (length prefix and body position) lives in the
/// reader itself, so a [`ReadOutcome::TimedOut`] return mid-frame
/// resumes at the exact byte on the next call — deadline wake-ups
/// never desynchronize the stream.
pub struct FrameReader {
    stream: Stream,
    buf: Vec<u8>,
    /// Partial length prefix (valid up to `at` while `!in_body`).
    len4: [u8; 4],
    /// Resume offset into `len4` or `buf`.
    at: usize,
    in_body: bool,
}

impl FrameReader {
    pub fn new(stream: Stream) -> Self {
        Self {
            stream,
            buf: Vec::new(),
            len4: [0u8; 4],
            at: 0,
            in_body: false,
        }
    }

    /// Read one whole frame into the internal buffer. On
    /// [`ReadOutcome::Frame`], [`Self::frame`] holds the header +
    /// payload bytes (the length prefix already consumed and
    /// validated).
    pub fn read_frame(&mut self, stop: &dyn Fn() -> bool) -> anyhow::Result<ReadOutcome> {
        self.read_frame_until(stop, None)
    }

    /// [`Self::read_frame`] with a wake-up: once `wake` passes without
    /// a complete frame, returns [`ReadOutcome::TimedOut`] (checked at
    /// read-timeout granularity — the socket's read timeout, 50 ms on
    /// fleet connections, bounds the overshoot). State is kept so the
    /// caller can act (send a ping, fail a deadline, reap) and call
    /// again without losing a partially-received frame.
    pub fn read_frame_until(
        &mut self,
        stop: &dyn Fn() -> bool,
        wake: Option<Instant>,
    ) -> anyhow::Result<ReadOutcome> {
        if !self.in_body {
            while self.at < 4 {
                let at = self.at;
                match self.stream.read(&mut self.len4[at..]) {
                    Ok(0) => {
                        if at == 0 {
                            return Ok(ReadOutcome::Eof);
                        }
                        anyhow::bail!("connection closed mid-frame ({at} bytes in)");
                    }
                    Ok(n) => self.at += n,
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        if stop() {
                            return Ok(ReadOutcome::Stopped);
                        }
                        if wake.is_some_and(|w| Instant::now() >= w) {
                            return Ok(ReadOutcome::TimedOut);
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(anyhow::anyhow!("read failed: {e}")),
                }
            }
            let len = u32::from_le_bytes(self.len4) as usize;
            anyhow::ensure!(
                (frame::HEADER_LEN..=frame::MAX_FRAME_LEN).contains(&len),
                "frame length {len} out of bounds"
            );
            self.buf.clear();
            self.buf.resize(len, 0);
            self.at = 0;
            self.in_body = true;
        }
        while self.at < self.buf.len() {
            let at = self.at;
            match self.stream.read(&mut self.buf[at..]) {
                Ok(0) => anyhow::bail!("connection closed mid-frame ({} bytes in)", at + 4),
                Ok(n) => self.at += n,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if stop() {
                        return Ok(ReadOutcome::Stopped);
                    }
                    if wake.is_some_and(|w| Instant::now() >= w) {
                        return Ok(ReadOutcome::TimedOut);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow::anyhow!("read failed: {e}")),
            }
        }
        self.at = 0;
        self.in_body = false;
        Ok(ReadOutcome::Frame)
    }

    /// The bytes of the last frame read (header + payload).
    pub fn frame(&self) -> &[u8] {
        &self.buf
    }

    /// Tear the underlying socket down in both directions (liveness
    /// reaping and injected kills): the peer's blocked reads and
    /// writes fail immediately instead of at their next timeout.
    pub fn shutdown_both(&self) {
        self.stream.shutdown_both();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parses_both_schemes() {
        assert_eq!(
            Addr::parse("tcp:127.0.0.1:7777").unwrap(),
            Addr::Tcp("127.0.0.1:7777".into())
        );
        assert_eq!(
            Addr::parse("127.0.0.1:7777").unwrap(),
            Addr::Tcp("127.0.0.1:7777".into())
        );
        assert_eq!(
            Addr::parse("uds:/tmp/x.sock").unwrap(),
            Addr::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert_eq!(
            Addr::parse("unix:/tmp/x.sock").unwrap(),
            Addr::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert!(Addr::parse("").is_err());
        assert!(Addr::parse("uds:").is_err());
        assert!(Addr::parse("no-port-here").is_err());
        assert!(Addr::parse("host:notaport").is_err());
    }

    #[test]
    fn frame_reader_roundtrips_over_uds() {
        let dir = std::env::temp_dir().join("rlarch_transport_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("rt_{}.sock", std::process::id()));
        let addr = Addr::Unix(path.clone());
        let listener = Listener::bind(&addr).unwrap();
        let client = dial(&addr, 0, 1, None).unwrap();
        let served = loop {
            if let Some(s) = listener.poll_accept().unwrap() {
                break s;
            }
        };
        // Client writes two frames; server reads them back intact.
        let mut w = client;
        let mut buf = Vec::new();
        frame::encode_goodbye(&mut buf);
        std::io::Write::write_all(&mut w, &buf).unwrap();
        frame::encode_submit(&mut buf, 5, 1, &[1.0, 2.0], &[3.0], &[4.0]);
        std::io::Write::write_all(&mut w, &buf).unwrap();
        drop(w);

        let mut r = FrameReader::new(served);
        assert_eq!(r.read_frame(&|| false).unwrap(), ReadOutcome::Frame);
        assert_eq!(
            frame::parse_header(r.frame()).unwrap().kind,
            frame::FrameKind::Goodbye
        );
        assert_eq!(r.read_frame(&|| false).unwrap(), ReadOutcome::Frame);
        let hd = frame::parse_header(r.frame()).unwrap();
        assert_eq!((hd.kind, hd.ticket), (frame::FrameKind::Submit, 5));
        // Peer gone: clean EOF on the boundary.
        assert_eq!(r.read_frame(&|| false).unwrap(), ReadOutcome::Eof);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn dial_fails_after_retries() {
        let addr = Addr::Unix(PathBuf::from("/nonexistent/rlarch/fleet.sock"));
        let err = dial(&addr, 2, 1, None).unwrap_err().to_string();
        assert!(err.contains("3 attempts"), "{err}");
    }
}
