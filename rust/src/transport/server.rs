//! The coordinator side of the fleet: [`FleetServer`] multiplexes many
//! remote actor connections into the existing pooled batcher and the
//! central replay (DESIGN.md §14; fault tolerance §15).
//!
//! Topology: one non-blocking accept loop; per connection a reader
//! thread (the connection's own thread) and, for infer connections, one
//! writer thread. The reader decodes `Submit` frames straight into
//! recycled [`InferSlab`]s and submits them to the batcher exactly like
//! a local policy client — same `InferItem`, same validation, same
//! reply mailbox pattern (a counted channel whose senders ride inside
//! the queued items, so the writer's drain ends precisely when every
//! outstanding reply has been routed). The writer serializes
//! [`ReplyChunk`]s back onto the wire borrowing rows directly from the
//! batch's shared output slab — the socket path adds zero copies over
//! the in-process scatter.
//!
//! Backpressure: each connection carries a bounded in-flight row budget
//! (`fleet.max_inflight_rows`). A submission that would exceed it is
//! *shed* — answered immediately with a `shed:`-prefixed error reply
//! the client retries after a pause — and counted in `fleet.shed_rows`:
//! a slow consumer costs itself a counter and a delay, never a stall of
//! the batcher or of other connections.
//!
//! Lifecycle: a connection ends cleanly on a `Goodbye` frame; a bare
//! EOF or read error is an unexpected death (`fleet.disconnects`) whose
//! in-flight replies are drained to a dead socket and counted as
//! `fleet.shed_inflight_rows`. An accept arriving after any death
//! increments `fleet.reconnects`. On server shutdown the readers stop
//! accepting new work, the writers drain every outstanding reply, send
//! `Goodbye`, and close — the clean-drain handshake the workers' clients
//! turn into their own shutdown. `Goodbye` is *only* sent on a clean
//! end (drain or peer goodbye): a connection that dies mid-stream is
//! torn down without one, so the worker's client recovers and resubmits
//! instead of mistaking the death for a fleet shutdown.
//!
//! Liveness (DESIGN.md §15): with `fleet.liveness_timeout_ms` set, a
//! client heartbeats idle infer connections with `Ping` frames the
//! reader answers with `Pong`; any completed inbound frame counts as
//! proof of life. A connection silent past the window is *reaped* —
//! counted in `fleet.reaped`, its first error attributed (`conn N
//! (<peer>)`), its in-flight replies shed, and its socket shut down so
//! a live-but-wedged peer notices immediately.
//!
//! Fault injection (DESIGN.md §15): when a seeded
//! [`crate::fault::FaultPlan`] is armed, the reader consults a
//! per-connection schedule after every received frame and kills,
//! drops, delays, truncates, or corrupts it before processing.
//! Mutated frames are guaranteed decode rejections, so every one lands
//! in `fleet.bad_frames` — the chaos tests reconcile the metrics
//! against the plan's ledger exactly.

use super::frame::{self, FrameKind, Role};
use super::{Addr, FrameReader, Listener, Liveness, ReadOutcome, Stream};
use crate::coordinator::batcher::{BatcherHandle, InferItem, ReplyChunk};
use crate::exec::channel::channel;
use crate::exec::ShutdownToken;
use crate::fault::{ConnFaults, FaultPlan, FrameFault};
use crate::metrics::Registry;
use crate::replay::{IngestQueue, SequenceSink};
use crate::serve::{AdmissionDecision, PriorityClass, ServeGate, SHED_BREAKER, SHED_PAUSED};
use crate::transport::client::{SHED_PREFIX, STALE_GEN_PREFIX};
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a blocked connection read may hold the socket before the
/// reader polls the shutdown token.
const READ_SLICE: Duration = Duration::from_millis(50);

/// Server-side fleet knobs (mirrors the `[fleet]` config section).
#[derive(Clone)]
pub struct FleetServerOpts {
    /// Per-connection in-flight row budget; submissions beyond it are
    /// shed (error reply + counter), not queued.
    pub max_inflight_rows: usize,
    /// Ingest batching into the replay (one `add_batch` per this many
    /// received sequences; same knob as `replay.insert_batch`).
    pub insert_batch: usize,
    /// Reap an infer connection silent for this long (0 = never; the
    /// client heartbeats with `Ping` at a shorter interval).
    pub liveness_timeout_ms: u64,
    /// Server incarnation tag echoed in `Hello` acks; a worker whose
    /// hello carries a different non-zero generation is refused with a
    /// `stale generation` error until it resyncs at 0. Bumped by
    /// checkpoint resume so restarted servers shed stale workers. This
    /// is the *initial* value: the live cell ([`FleetServer::generation`])
    /// moves on when a hot-reload bumps the fence under traffic.
    pub generation: u32,
    /// The armed fault schedule, if any (`None` = the bit-for-bit
    /// fault-free wire path).
    pub faults: Option<Arc<FaultPlan>>,
    /// The serving gate (admission / pause / breaker), if the `[serve]`
    /// control plane is on (`None` = the bit-for-bit PR 9 data path).
    pub gate: Option<Arc<ServeGate>>,
}

impl Default for FleetServerOpts {
    fn default() -> Self {
        Self {
            max_inflight_rows: 4096,
            insert_batch: 1,
            liveness_timeout_ms: 0,
            generation: 0,
            faults: None,
            gate: None,
        }
    }
}

/// Cloneable registry of live infer data sockets. Checkpoint hot-reload
/// severs them all after the generation bump: each worker's client takes
/// its proven broken-socket path — reconnect, get refused with `stale
/// generation`, resync at 0, adopt the new fence — exactly as after a
/// checkpoint restore. Ingest sockets are *not* registered: severing a
/// one-way ingest stream would lose in-flight sequences for nothing.
#[derive(Clone, Default)]
pub struct ConnRegistry {
    inner: Arc<Mutex<Vec<(u64, Stream)>>>,
}

impl ConnRegistry {
    fn register(&self, id: u64, stream: Stream) {
        self.inner.lock().unwrap().push((id, stream));
    }

    fn unregister(&self, id: u64) {
        self.inner.lock().unwrap().retain(|(i, _)| *i != id);
    }

    /// Shut both halves of every registered socket; returns how many.
    /// Readers see EOF, clients reconnect and resync the generation.
    pub fn sever_all(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        for (_, s) in g.iter() {
            s.shutdown_both();
        }
        let n = g.len();
        g.clear();
        n
    }
}

/// Live control-plane state shared by every connection thread: the
/// current generation fence and the registry of severable infer conns.
#[derive(Clone)]
struct ServerShared {
    generation: Arc<AtomicU32>,
    registry: ConnRegistry,
}

/// Record the first attributed fleet error; later errors only show up
/// in counters. The message closure runs only when the slot is empty.
fn note_first(slot: &Mutex<Option<String>>, msg: impl FnOnce() -> String) {
    let mut g = slot.lock().unwrap();
    if g.is_none() {
        *g = Some(msg());
    }
}

/// The fleet-aware server. `spawn` starts the accept loop; `join`
/// (after the shared token is signalled) waits for the drain.
pub struct FleetServer {
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    uds_path: Option<std::path::PathBuf>,
    errors: Arc<Mutex<Option<String>>>,
    shared: ServerShared,
}

impl FleetServer {
    pub fn spawn(
        listener: Listener,
        handle: BatcherHandle,
        sink: Arc<dyn SequenceSink>,
        opts: FleetServerOpts,
        metrics: Registry,
        shutdown: ShutdownToken,
    ) -> FleetServer {
        let uds_path = match listener.local_addr() {
            Ok(Addr::Unix(p)) => Some(p),
            _ => None,
        };
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let errors: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let shared = ServerShared {
            generation: Arc::new(AtomicU32::new(opts.generation)),
            registry: ConnRegistry::default(),
        };
        let conns2 = conns.clone();
        let errors2 = errors.clone();
        let shared2 = shared.clone();
        let spawn_failures = metrics.counter("fleet.spawn_failures");
        let accept = match std::thread::Builder::new()
            .name("rlarch-fleet-accept".into())
            .spawn(move || {
                accept_loop(
                    listener, handle, sink, opts, metrics, shutdown, conns2, errors2, shared2,
                )
            }) {
            Ok(h) => Some(h),
            Err(e) => {
                // No accept loop means no fleet — decline gracefully
                // instead of panicking; the report surfaces the error.
                spawn_failures.inc();
                note_first(&errors, || format!("spawn fleet accept loop: {e}"));
                None
            }
        };
        FleetServer {
            accept,
            conns,
            uds_path,
            errors,
            shared,
        }
    }

    /// Shared slot holding the first attributed fleet error (clone it
    /// before [`Self::join`] consumes the server; read it after).
    pub fn error_slot(&self) -> Arc<Mutex<Option<String>>> {
        self.errors.clone()
    }

    /// The live generation fence. Handshakes read it per connection;
    /// hot-reload bumps it, then severs the data conns so every worker
    /// resyncs behind the new fence. Clone before [`Self::join`].
    pub fn generation_cell(&self) -> Arc<AtomicU32> {
        self.shared.generation.clone()
    }

    /// The live infer-connection registry (hot-reload severs through
    /// it). Clone before [`Self::join`] consumes the server.
    pub fn conn_registry(&self) -> ConnRegistry {
        self.shared.registry.clone()
    }

    /// Wait for the accept loop and every connection thread to finish
    /// (signal the shared shutdown token first).
    pub fn join(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        if let Some(p) = self.uds_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: Listener,
    handle: BatcherHandle,
    sink: Arc<dyn SequenceSink>,
    opts: FleetServerOpts,
    metrics: Registry,
    shutdown: ShutdownToken,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    errors: Arc<Mutex<Option<String>>>,
    shared: ServerShared,
) {
    let accepts = metrics.counter("fleet.accepts");
    let disconnects = metrics.counter("fleet.disconnects");
    let reconnects = metrics.counter("fleet.reconnects");
    let spawn_failures = metrics.counter("fleet.spawn_failures");
    let connections = metrics.gauge("fleet.connections");
    connections.set(0.0);
    let mut reconnects_counted = 0u64;
    let mut conn_id = 0u64;
    while !shutdown.is_signalled() {
        match listener.poll_accept() {
            Ok(Some(stream)) => {
                accepts.inc();
                conn_id += 1;
                // An accept arriving after an unexpected death is a
                // worker coming back: the kill-and-reconnect signal.
                if disconnects.get() > reconnects_counted {
                    reconnects.inc();
                    reconnects_counted += 1;
                }
                let id = conn_id;
                let handle = handle.clone();
                let sink = sink.clone();
                let opts = opts.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let errors2 = errors.clone();
                let shared2 = shared.clone();
                let spawned = std::thread::Builder::new()
                    .name("rlarch-fleet-conn".into())
                    .spawn(move || {
                        serve_conn(
                            stream, id, handle, sink, opts, metrics, shutdown, errors2, shared2,
                        )
                    });
                match spawned {
                    Ok(h) => conns.lock().unwrap().push(h),
                    Err(e) => {
                        // Declined: the moved-in stream was dropped, so
                        // the peer sees EOF and retries with backoff.
                        spawn_failures.inc();
                        note_first(&errors, || {
                            format!("conn {id}: spawn connection thread: {e}")
                        });
                    }
                }
            }
            Ok(None) | Err(_) => {
                if shutdown.sleep_interruptible(Duration::from_millis(5)) {
                    break;
                }
            }
        }
    }
}

/// Handshake, then dispatch on the connection's declared role.
#[allow(clippy::too_many_arguments)]
fn serve_conn(
    stream: Stream,
    conn_id: u64,
    handle: BatcherHandle,
    sink: Arc<dyn SequenceSink>,
    opts: FleetServerOpts,
    metrics: Registry,
    shutdown: ShutdownToken,
    errors: Arc<Mutex<Option<String>>>,
    shared: ServerShared,
) {
    let connections = metrics.gauge("fleet.connections");
    connections.add(1.0);
    let clean = serve_conn_inner(
        stream, conn_id, handle, sink, opts, &metrics, shutdown, &errors, &shared,
    );
    connections.add(-1.0);
    if !clean {
        metrics.counter("fleet.disconnects").inc();
    }
}

/// Returns whether the connection ended cleanly (goodbye or refused
/// handshake, as opposed to dying mid-stream).
#[allow(clippy::too_many_arguments)]
fn serve_conn_inner(
    stream: Stream,
    conn_id: u64,
    handle: BatcherHandle,
    sink: Arc<dyn SequenceSink>,
    opts: FleetServerOpts,
    metrics: &Registry,
    shutdown: ShutdownToken,
    errors: &Mutex<Option<String>>,
    shared: &ServerShared,
) -> bool {
    let peer = stream.peer_desc();
    if stream.set_read_timeout(Some(READ_SLICE)).is_err()
        || stream.set_write_timeout(Some(Duration::from_secs(5))).is_err()
    {
        return false;
    }
    stream.set_nodelay();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return false,
    };
    let mut reader = FrameReader::new(stream);
    let sd = shutdown.clone();
    let stop = move || sd.is_signalled();
    // A connection that never completes a hello inside the liveness
    // window is holding a thread hostage: reap it like any stale conn.
    let hello_wake = (opts.liveness_timeout_ms > 0)
        .then(|| Instant::now() + Duration::from_millis(opts.liveness_timeout_ms));
    match reader.read_frame_until(&stop, hello_wake) {
        Ok(ReadOutcome::Frame) => {}
        Ok(ReadOutcome::TimedOut) => {
            metrics.counter("fleet.reaped").inc();
            note_first(errors, || {
                format!("conn {conn_id} ({peer}): reaped before handshake")
            });
            reader.shutdown_both();
            return true; // nothing was in flight
        }
        _ => return true, // never got a hello: nothing was in flight
    }
    let hello = match frame::parse_header(reader.frame()).and_then(|hd| {
        anyhow::ensure!(hd.kind == FrameKind::Hello, "expected hello, got {:?}", hd.kind);
        frame::decode_hello(frame::payload(reader.frame()))
    }) {
        Ok(h) => h,
        Err(e) => {
            metrics.counter("fleet.bad_frames").inc();
            note_first(errors, || format!("conn {conn_id} ({peer}): bad hello: {e}"));
            return false;
        }
    };
    let d = handle.dims();
    let mut buf = Vec::new();
    let dims_ok = hello.obs_len as usize == d.obs_len
        && hello.hidden as usize == d.hidden
        && hello.num_actions as usize == d.num_actions
        && hello.seq_len as usize == d.seq_len;
    if !dims_ok {
        note_first(errors, || {
            format!("conn {conn_id} ({peer}): model dims mismatch: server {d:?}, worker hello {hello:?}")
        });
        frame::encode_reply_err(
            &mut buf,
            0,
            0,
            0,
            &format!(
                "model dims mismatch: server {d:?}, worker hello {hello:?}"
            ),
        );
        let _ = writer.write_all(&buf);
        return true; // refused up front: clean
    }
    // Generation fence: a worker synced to a previous server
    // incarnation is refused until it re-handshakes fresh (generation
    // 0), so a restored checkpoint never mixes in stale in-flight work.
    // Read the *live* cell: a hot-reload moves it under traffic.
    let generation = shared.generation.load(Ordering::Acquire);
    if hello.generation != 0 && hello.generation != generation {
        note_first(errors, || {
            format!(
                "conn {conn_id} ({peer}): stale generation {} (server at {})",
                hello.generation, generation
            )
        });
        frame::encode_reply_err(
            &mut buf,
            0,
            0,
            0,
            &format!(
                "{STALE_GEN_PREFIX}: server is at generation {}, worker synced to {}",
                generation, hello.generation
            ),
        );
        let _ = writer.write_all(&buf);
        return true; // refused up front: clean
    }
    // Priority class rides a hello pad byte; an unknown byte is a
    // protocol mismatch, refused up front like a dims mismatch.
    let class = match PriorityClass::from_u8(hello.class) {
        Some(c) => c,
        None => {
            note_first(errors, || {
                format!(
                    "conn {conn_id} ({peer}): unknown priority class byte {}",
                    hello.class
                )
            });
            frame::encode_reply_err(
                &mut buf,
                0,
                0,
                0,
                &format!("unknown priority class byte {}", hello.class),
            );
            let _ = writer.write_all(&buf);
            return true; // refused up front: clean
        }
    };
    // Ack with the server's dims and generation (echoing the worker's
    // actor id); the worker adopts the generation for reconnects.
    let ack = frame::Hello {
        role: hello.role,
        actor_id: hello.actor_id,
        obs_len: d.obs_len as u32,
        hidden: d.hidden as u32,
        num_actions: d.num_actions as u32,
        seq_len: d.seq_len as u32,
        generation,
        class: hello.class,
    };
    frame::encode_hello(&mut buf, &ack);
    if writer.write_all(&buf).is_err() {
        return false;
    }
    match hello.role {
        Role::Infer => {
            // Register a severable handle so a hot-reload can force
            // this worker through reconnect → resync; best-effort (a
            // failed clone just means this conn rides out the reload).
            if let Ok(s) = writer.try_clone() {
                shared.registry.register(conn_id, s);
            }
            let clean = serve_infer(InferConn {
                reader,
                writer,
                conn_id,
                peer,
                actor: hello.actor_id as usize,
                class,
                handle,
                opts,
                metrics,
                shutdown,
                errors,
            });
            shared.registry.unregister(conn_id);
            clean
        }
        Role::Ingest => serve_ingest(reader, conn_id, peer, sink, d, opts, metrics, shutdown, errors),
    }
}

/// Everything one infer connection's reader needs (bundled so the
/// serve function stays inside the argument-count lint).
struct InferConn<'a> {
    reader: FrameReader,
    writer: Stream,
    conn_id: u64,
    peer: String,
    actor: usize,
    class: PriorityClass,
    handle: BatcherHandle,
    opts: FleetServerOpts,
    metrics: &'a Registry,
    shutdown: ShutdownToken,
    errors: &'a Mutex<Option<String>>,
}

/// One remote actor's inference connection: reader decodes submissions
/// into the batcher; a writer thread routes reply chunks back.
fn serve_infer(conn: InferConn<'_>) -> bool {
    let InferConn {
        mut reader,
        writer,
        conn_id,
        peer,
        actor,
        class,
        handle,
        opts,
        metrics,
        shutdown,
        errors,
    } = conn;
    let d = handle.dims();
    let pool = handle.slab_pool();
    let rx_frames = metrics.counter("fleet.rx_frames");
    let rx_bytes = metrics.counter("fleet.rx_bytes");
    let shed_rows = metrics.counter("fleet.shed_rows");
    let bad_frames = metrics.counter("fleet.bad_frames");
    let reaped = metrics.counter("fleet.reaped");
    let decode_time = metrics.timer("fleet.decode_seconds");
    let gate = opts.gate.clone();
    // Gate shed taxonomy: admission-policy decisions per class, the
    // reload-drain pause, and the open breaker each get their own
    // counter, so "zero actor-class admission sheds" stays assertable
    // even when a reload pause sheds uniformly. No gate, no `serve.*`
    // metrics: the PR 9 registry is untouched.
    let gate_counters = gate.as_ref().map(|_| {
        (
            metrics.counter("serve.breaker_sheds"),
            metrics.counter("serve.paused_sheds"),
            [
                metrics.counter("serve.admission_sheds_actor"),
                metrics.counter("serve.admission_sheds_eval"),
                metrics.counter("serve.admission_sheds_bulk"),
            ],
        )
    });
    // The reply route: the reader holds the root sender and clones it
    // into every queued item; the writer drains the receiver until all
    // senders are gone — i.e. the reader exited AND every outstanding
    // submission was answered. That disconnect IS the drain barrier.
    let (tx, rx) = channel::<ReplyChunk>(64);
    let rows_inflight = Arc::new(AtomicUsize::new(0));
    // The write half is shared: the writer thread serializes reply
    // chunks through it, the reader answers `Ping` with `Pong` (the
    // mutex is uncontended — pings only flow on idle connections).
    let writer = Arc::new(Mutex::new(writer));
    // Set by the reader before it releases the drain barrier: goodbye
    // is only for clean ends, never for a death the client must treat
    // as a reconnect signal.
    let goodbye_ok = Arc::new(AtomicBool::new(false));

    let writer2 = writer.clone();
    let goodbye_ok2 = goodbye_ok.clone();
    let writer_rows_inflight = rows_inflight.clone();
    let writer_gate = gate.clone();
    let tx_frames = metrics.counter("fleet.tx_frames");
    let tx_bytes = metrics.counter("fleet.tx_bytes");
    let shed_inflight = metrics.counter("fleet.shed_inflight_rows");
    let encode_time = metrics.timer("fleet.encode_seconds");
    let spawned = std::thread::Builder::new()
        .name("rlarch-fleet-writer".into())
        .spawn(move || {
            let (na, hid) = (d.num_actions, d.hidden);
            let mut wbuf = Vec::new();
            let mut broken = false;
            while let Some(chunk) = rx.recv() {
                match &chunk.result {
                    Ok(range) => {
                        let (k, r0) = (chunk.rows, range.row0);
                        encode_time.time(|| {
                            frame::encode_reply_ok(
                                &mut wbuf,
                                chunk.ticket as u64,
                                chunk.slot0 as u32,
                                k,
                                &range.slab.q[r0 * na..(r0 + k) * na],
                                &range.slab.h[r0 * hid..(r0 + k) * hid],
                                &range.slab.c[r0 * hid..(r0 + k) * hid],
                            )
                        });
                    }
                    Err(msg) => frame::encode_reply_err(
                        &mut wbuf,
                        chunk.ticket as u64,
                        chunk.slot0 as u32,
                        chunk.rows,
                        msg,
                    ),
                }
                if broken || writer2.lock().unwrap().write_all(&wbuf).is_err() {
                    // Dead socket: keep draining so in-flight rows keep
                    // releasing, but count what the peer never saw.
                    broken = true;
                    shed_inflight.add(chunk.rows as u64);
                } else {
                    tx_frames.inc();
                    tx_bytes.add(wbuf.len() as u64);
                }
                writer_rows_inflight.fetch_sub(chunk.rows, Ordering::AcqRel);
                if let Some(g) = writer_gate.as_ref() {
                    // Every chunk releases its rows (shed chunks were
                    // counted too, so the balance holds), and genuine
                    // backend outcomes — never our own synthetic sheds
                    // — feed the circuit breaker.
                    g.end_rows(chunk.rows as u64);
                    match &chunk.result {
                        Ok(_) => g.breaker_on_success(),
                        Err(msg) if !msg.starts_with(SHED_PREFIX) => {
                            g.breaker_on_failure(Instant::now())
                        }
                        Err(_) => {}
                    }
                }
            }
            // Drain complete. Best-effort goodbye on a *clean* end only
            // (server shutdown or peer goodbye): it is the clean-drain
            // marker the worker turns into its own exit. A death stays
            // a death — the peer recovers instead of shutting down.
            let mut w = writer2.lock().unwrap();
            if !broken && goodbye_ok2.load(Ordering::Acquire) {
                frame::encode_goodbye(&mut wbuf);
                let _ = w.write_all(&wbuf);
            }
            w.shutdown_write();
        });
    let writer_thread = match spawned {
        Ok(h) => h,
        Err(e) => {
            metrics.counter("fleet.spawn_failures").inc();
            note_first(errors, || {
                format!("conn {conn_id} ({peer}): spawn reply writer: {e}")
            });
            return false; // decline: nothing was submitted yet
        }
    };

    let pong_tx_frames = metrics.counter("fleet.tx_frames");
    let pong_tx_bytes = metrics.counter("fleet.tx_bytes");
    let mut pong_buf = Vec::new();
    let mut faults = opts.faults.as_ref().map(|p| p.conn(actor as u64 + 1));
    let mut scratch: Vec<u8> = Vec::new();
    let mut liveness = (opts.liveness_timeout_ms > 0).then(|| {
        Liveness::new(
            Duration::from_millis(opts.liveness_timeout_ms),
            Instant::now(),
        )
    });
    let sd = shutdown.clone();
    let stop = move || sd.is_signalled();
    let mut clean = false;
    loop {
        let wake = liveness.as_ref().map(|l| l.deadline());
        match reader.read_frame_until(&stop, wake) {
            Ok(ReadOutcome::Frame) => {
                if let Some(l) = liveness.as_mut() {
                    l.touch(Instant::now());
                }
            }
            Ok(ReadOutcome::Stopped) => {
                // Server drain: stop accepting submissions; the writer
                // flushes what's in flight and says goodbye.
                clean = true;
                break;
            }
            Ok(ReadOutcome::TimedOut) => {
                let l = liveness.as_ref().expect("timeout implies liveness");
                let silent = l.silent_for(Instant::now()).as_millis();
                reaped.inc();
                note_first(errors, || {
                    format!(
                        "conn {conn_id} ({peer}, infer actor {actor}) reaped: \
                         no frames for {silent} ms"
                    )
                });
                // Shut the socket down so a wedged-but-alive peer sees
                // the reap now; in-flight replies shed to it uniformly.
                reader.shutdown_both();
                break;
            }
            Ok(ReadOutcome::Eof) => {
                note_first(errors, || {
                    format!("conn {conn_id} ({peer}, infer actor {actor}): unexpected eof")
                });
                break;
            }
            Err(e) => {
                note_first(errors, || {
                    format!("conn {conn_id} ({peer}, infer actor {actor}): {e}")
                });
                break;
            }
        }
        rx_frames.inc();
        rx_bytes.add((reader.frame().len() + 4) as u64);
        // Armed fault plan: decide this frame's fate before processing.
        let mut mutated = false;
        if let Some(cf) = faults.as_mut() {
            match cf.sample() {
                FrameFault::Deliver => {}
                FrameFault::Kill => {
                    note_first(errors, || {
                        format!("conn {conn_id} ({peer}): injected kill")
                    });
                    reader.shutdown_both();
                    break;
                }
                FrameFault::Drop => continue,
                FrameFault::Delay(dur) => std::thread::sleep(dur),
                f @ (FrameFault::Truncate | FrameFault::Corrupt) => {
                    scratch.clear();
                    scratch.extend_from_slice(reader.frame());
                    cf.mutate(&mut scratch, f);
                    mutated = true;
                }
            }
        }
        let fr: &[u8] = if mutated { &scratch } else { reader.frame() };
        let hd = match frame::parse_header(fr) {
            Ok(hd) => hd,
            Err(e) => {
                bad_frames.inc();
                note_first(errors, || {
                    format!("conn {conn_id} ({peer}, infer actor {actor}): bad frame: {e}")
                });
                break;
            }
        };
        match hd.kind {
            FrameKind::Goodbye => {
                clean = true;
                break;
            }
            FrameKind::Ping => {
                // Proof of life; echo the nonce through the shared
                // write half (reusing the buffer: zero-alloc).
                frame::encode_pong(&mut pong_buf, hd.ticket);
                if writer.lock().unwrap().write_all(&pong_buf).is_ok() {
                    pong_tx_frames.inc();
                    pong_tx_bytes.add(pong_buf.len() as u64);
                }
                continue;
            }
            FrameKind::Submit => {}
            _ => {
                note_first(errors, || {
                    format!(
                        "conn {conn_id} ({peer}, infer actor {actor}): \
                         protocol violation: unexpected {:?}",
                        hd.kind
                    )
                });
                break;
            }
        }
        let rows = hd.rows as usize;
        let mut slab = pool.acquire();
        let decoded = decode_time.time(|| {
            frame::decode_submit(
                frame::payload(fr),
                rows,
                d.obs_len,
                d.hidden,
                &mut slab.obs,
                &mut slab.h,
                &mut slab.c,
            )
        });
        if let Err(e) = decoded {
            pool.release(slab);
            bad_frames.inc();
            note_first(errors, || {
                format!("conn {conn_id} ({peer}, infer actor {actor}): bad submit: {e}")
            });
            break; // garbage payload: kill the connection
        }
        // Budget and gate checks. Both counts are incremented for shed
        // submissions too — their synthetic error chunk flows through
        // the writer, which decrements uniformly per chunk.
        let before = rows_inflight.fetch_add(rows, Ordering::AcqRel);
        let queued = gate.as_ref().map_or(0, |g| g.begin_rows(rows as u64));
        // Serving gate: breaker first (fail fast while the backend is
        // down), then the reload-drain pause, then the admission
        // policy's overload/queue/deadline ladder. Every refusal is a
        // `shed:` reply the client already knows how to retry.
        let gate_shed: Option<String> = match (gate.as_ref(), gate_counters.as_ref()) {
            (Some(g), Some((breaker_sheds, paused_sheds, admission_sheds))) => {
                let now = Instant::now();
                if !g.breaker_allow(now) {
                    breaker_sheds.inc();
                    Some(SHED_BREAKER.to_string())
                } else if !g.is_admitting() {
                    paused_sheds.inc();
                    Some(SHED_PAUSED.to_string())
                } else {
                    match g.decide(class, rows as u64, queued, now) {
                        AdmissionDecision::Admit => None,
                        AdmissionDecision::Shed(reason) => {
                            admission_sheds[class.as_u8() as usize].inc();
                            Some(reason.to_string())
                        }
                    }
                }
            }
            _ => None,
        };
        if let Some(reason) = gate_shed {
            pool.release(slab);
            let _ = tx.send(ReplyChunk {
                ticket: hd.ticket as usize,
                slot0: 0,
                rows,
                result: Err(format!("{SHED_PREFIX} {reason}")),
            });
            continue;
        }
        if before + rows > opts.max_inflight_rows {
            shed_rows.add(rows as u64);
            pool.release(slab);
            let _ = tx.send(ReplyChunk {
                ticket: hd.ticket as usize,
                slot0: 0,
                rows,
                result: Err(format!(
                    "{SHED_PREFIX} connection over its {} in-flight row budget",
                    opts.max_inflight_rows
                )),
            });
            continue;
        }
        if let Err(e) = handle.submit(InferItem {
            actor,
            ticket: hd.ticket as usize,
            rows,
            slab,
            reply: tx.clone(),
        }) {
            // Batcher gone (or refused the item — it released the slab
            // either way): answer with the error instead of stalling.
            note_first(errors, || {
                format!("conn {conn_id} ({peer}, infer actor {actor}): submit: {e}")
            });
            let _ = tx.send(ReplyChunk {
                ticket: hd.ticket as usize,
                slot0: 0,
                rows,
                result: Err(e.to_string()),
            });
        }
    }
    goodbye_ok.store(clean, Ordering::Release);
    drop(tx);
    let _ = writer_thread.join();
    clean
}

/// One worker process's sequence-ingest connection: decode `Sequence`
/// frames into recycled slabs and batch them into the central replay.
#[allow(clippy::too_many_arguments)]
fn serve_ingest(
    mut reader: FrameReader,
    conn_id: u64,
    peer: String,
    sink: Arc<dyn SequenceSink>,
    d: crate::runtime::ModelDims,
    opts: FleetServerOpts,
    metrics: &Registry,
    shutdown: ShutdownToken,
    errors: &Mutex<Option<String>>,
) -> bool {
    let rx_frames = metrics.counter("fleet.rx_frames");
    let rx_bytes = metrics.counter("fleet.rx_bytes");
    let rx_seqs = metrics.counter("fleet.rx_sequences");
    let bad_frames = metrics.counter("fleet.bad_frames");
    let decode_time = metrics.timer("fleet.decode_seconds");
    let pool = sink.recycle_pool();
    let mut ingest = IngestQueue::new(sink.clone(), opts.insert_batch);
    // Ingest faults use site 0 (infer connections use actor_id + 1) so
    // every connection's schedule depends only on (seed, site).
    let mut faults = opts.faults.as_ref().map(|p| p.conn(0));
    let mut scratch: Vec<u8> = Vec::new();
    let sd = shutdown.clone();
    let stop = move || sd.is_signalled();
    let mut clean = false;
    loop {
        match reader.read_frame(&stop) {
            Ok(ReadOutcome::Frame) => {}
            Ok(ReadOutcome::Stopped) => {
                clean = true;
                break;
            }
            Ok(ReadOutcome::TimedOut) => unreachable!("no wake deadline on ingest"),
            Ok(ReadOutcome::Eof) => {
                note_first(errors, || {
                    format!("conn {conn_id} ({peer}, ingest): unexpected eof")
                });
                break;
            }
            Err(e) => {
                note_first(errors, || format!("conn {conn_id} ({peer}, ingest): {e}"));
                break;
            }
        }
        rx_frames.inc();
        rx_bytes.add((reader.frame().len() + 4) as u64);
        let mut mutated = false;
        if let Some(cf) = faults.as_mut() {
            match cf.sample() {
                FrameFault::Deliver => {}
                FrameFault::Kill => {
                    note_first(errors, || {
                        format!("conn {conn_id} ({peer}, ingest): injected kill")
                    });
                    reader.shutdown_both();
                    break;
                }
                FrameFault::Drop => continue,
                FrameFault::Delay(dur) => std::thread::sleep(dur),
                f @ (FrameFault::Truncate | FrameFault::Corrupt) => {
                    scratch.clear();
                    scratch.extend_from_slice(reader.frame());
                    cf.mutate(&mut scratch, f);
                    mutated = true;
                }
            }
        }
        let fr: &[u8] = if mutated { &scratch } else { reader.frame() };
        let hd = match frame::parse_header(fr) {
            Ok(hd) => hd,
            Err(e) => {
                bad_frames.inc();
                note_first(errors, || {
                    format!("conn {conn_id} ({peer}, ingest): bad frame: {e}")
                });
                break;
            }
        };
        match hd.kind {
            FrameKind::Goodbye => {
                clean = true;
                break;
            }
            // A ping on the one-way ingest path has no reply channel;
            // receiving it was already the proof of life.
            FrameKind::Ping => continue,
            FrameKind::Sequence => {}
            _ => {
                note_first(errors, || {
                    format!(
                        "conn {conn_id} ({peer}, ingest): protocol violation: \
                         unexpected {:?}",
                        hd.kind
                    )
                });
                break;
            }
        }
        let mut seq = match &pool {
            Some(p) => p.acquire(d.seq_len, d.obs_len, d.hidden, 0),
            None => Default::default(),
        };
        let decoded = decode_time.time(|| {
            frame::decode_sequence(frame::payload(fr), d.obs_len, d.hidden, &mut seq)
        });
        match decoded {
            Ok(()) => {
                rx_seqs.inc();
                ingest.push(seq);
            }
            Err(e) => {
                if let Some(p) = &pool {
                    p.put(seq);
                }
                bad_frames.inc();
                note_first(errors, || {
                    format!("conn {conn_id} ({peer}, ingest): bad sequence: {e}")
                });
                break;
            }
        }
    }
    ingest.flush();
    clean
}
