//! The coordinator side of the fleet: [`FleetServer`] multiplexes many
//! remote actor connections into the existing pooled batcher and the
//! central replay (DESIGN.md §14).
//!
//! Topology: one non-blocking accept loop; per connection a reader
//! thread (the connection's own thread) and, for infer connections, one
//! writer thread. The reader decodes `Submit` frames straight into
//! recycled [`InferSlab`]s and submits them to the batcher exactly like
//! a local policy client — same `InferItem`, same validation, same
//! reply mailbox pattern (a counted channel whose senders ride inside
//! the queued items, so the writer's drain ends precisely when every
//! outstanding reply has been routed). The writer serializes
//! [`ReplyChunk`]s back onto the wire borrowing rows directly from the
//! batch's shared output slab — the socket path adds zero copies over
//! the in-process scatter.
//!
//! Backpressure: each connection carries a bounded in-flight row budget
//! (`fleet.max_inflight_rows`). A submission that would exceed it is
//! *shed* — answered immediately with a `shed:`-prefixed error reply
//! the client retries after a pause — and counted in `fleet.shed_rows`:
//! a slow consumer costs itself a counter and a delay, never a stall of
//! the batcher or of other connections.
//!
//! Lifecycle: a connection ends cleanly on a `Goodbye` frame; a bare
//! EOF or read error is an unexpected death (`fleet.disconnects`) whose
//! in-flight replies are drained to a dead socket and counted as
//! `fleet.shed_inflight_rows`. An accept arriving after any death
//! increments `fleet.reconnects`. On server shutdown the readers stop
//! accepting new work, the writers drain every outstanding reply, send
//! `Goodbye`, and close — the clean-drain handshake the workers' clients
//! turn into their own shutdown.

use super::frame::{self, FrameKind, Role};
use super::{Addr, FrameReader, Listener, ReadOutcome, Stream};
use crate::coordinator::batcher::{BatcherHandle, InferItem, ReplyChunk};
use crate::exec::channel::channel;
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::replay::{IngestQueue, SequenceSink};
use crate::transport::client::SHED_PREFIX;
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How long a blocked connection read may hold the socket before the
/// reader polls the shutdown token.
const READ_SLICE: Duration = Duration::from_millis(50);

/// Server-side fleet knobs (mirrors the `[fleet]` config section).
#[derive(Clone, Copy, Debug)]
pub struct FleetServerOpts {
    /// Per-connection in-flight row budget; submissions beyond it are
    /// shed (error reply + counter), not queued.
    pub max_inflight_rows: usize,
    /// Ingest batching into the replay (one `add_batch` per this many
    /// received sequences; same knob as `replay.insert_batch`).
    pub insert_batch: usize,
}

impl Default for FleetServerOpts {
    fn default() -> Self {
        Self {
            max_inflight_rows: 4096,
            insert_batch: 1,
        }
    }
}

/// The fleet-aware server. `spawn` starts the accept loop; `join`
/// (after the shared token is signalled) waits for the drain.
pub struct FleetServer {
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    uds_path: Option<std::path::PathBuf>,
}

impl FleetServer {
    pub fn spawn(
        listener: Listener,
        handle: BatcherHandle,
        sink: Arc<dyn SequenceSink>,
        opts: FleetServerOpts,
        metrics: Registry,
        shutdown: ShutdownToken,
    ) -> FleetServer {
        let uds_path = match listener.local_addr() {
            Ok(Addr::Unix(p)) => Some(p),
            _ => None,
        };
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let conns2 = conns.clone();
        let accept = std::thread::Builder::new()
            .name("rlarch-fleet-accept".into())
            .spawn(move || {
                accept_loop(listener, handle, sink, opts, metrics, shutdown, conns2)
            })
            .expect("spawn fleet accept loop");
        FleetServer {
            accept: Some(accept),
            conns,
            uds_path,
        }
    }

    /// Wait for the accept loop and every connection thread to finish
    /// (signal the shared shutdown token first).
    pub fn join(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        if let Some(p) = self.uds_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: Listener,
    handle: BatcherHandle,
    sink: Arc<dyn SequenceSink>,
    opts: FleetServerOpts,
    metrics: Registry,
    shutdown: ShutdownToken,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let accepts = metrics.counter("fleet.accepts");
    let disconnects = metrics.counter("fleet.disconnects");
    let reconnects = metrics.counter("fleet.reconnects");
    let connections = metrics.gauge("fleet.connections");
    connections.set(0.0);
    let mut reconnects_counted = 0u64;
    while !shutdown.is_signalled() {
        match listener.poll_accept() {
            Ok(Some(stream)) => {
                accepts.inc();
                // An accept arriving after an unexpected death is a
                // worker coming back: the kill-and-reconnect signal.
                if disconnects.get() > reconnects_counted {
                    reconnects.inc();
                    reconnects_counted += 1;
                }
                let handle = handle.clone();
                let sink = sink.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let h = std::thread::Builder::new()
                    .name("rlarch-fleet-conn".into())
                    .spawn(move || serve_conn(stream, handle, sink, opts, metrics, shutdown))
                    .expect("spawn fleet connection");
                conns.lock().unwrap().push(h);
            }
            Ok(None) | Err(_) => {
                if shutdown.sleep_interruptible(Duration::from_millis(5)) {
                    break;
                }
            }
        }
    }
}

/// Handshake, then dispatch on the connection's declared role.
fn serve_conn(
    stream: Stream,
    handle: BatcherHandle,
    sink: Arc<dyn SequenceSink>,
    opts: FleetServerOpts,
    metrics: Registry,
    shutdown: ShutdownToken,
) {
    let connections = metrics.gauge("fleet.connections");
    connections.add(1.0);
    let clean = serve_conn_inner(stream, handle, sink, opts, &metrics, shutdown);
    connections.add(-1.0);
    if !clean {
        metrics.counter("fleet.disconnects").inc();
    }
}

/// Returns whether the connection ended cleanly (goodbye or refused
/// handshake, as opposed to dying mid-stream).
fn serve_conn_inner(
    stream: Stream,
    handle: BatcherHandle,
    sink: Arc<dyn SequenceSink>,
    opts: FleetServerOpts,
    metrics: &Registry,
    shutdown: ShutdownToken,
) -> bool {
    if stream.set_read_timeout(Some(READ_SLICE)).is_err()
        || stream.set_write_timeout(Some(Duration::from_secs(5))).is_err()
    {
        return false;
    }
    stream.set_nodelay();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return false,
    };
    let mut reader = FrameReader::new(stream);
    let sd = shutdown.clone();
    let stop = move || sd.is_signalled();
    match reader.read_frame(&stop) {
        Ok(ReadOutcome::Frame) => {}
        _ => return true, // never got a hello: nothing was in flight
    }
    let hello = match frame::parse_header(reader.frame()).and_then(|hd| {
        anyhow::ensure!(hd.kind == FrameKind::Hello, "expected hello, got {:?}", hd.kind);
        frame::decode_hello(frame::payload(reader.frame()))
    }) {
        Ok(h) => h,
        Err(_) => return false,
    };
    let d = handle.dims();
    let mut buf = Vec::new();
    let dims_ok = hello.obs_len as usize == d.obs_len
        && hello.hidden as usize == d.hidden
        && hello.num_actions as usize == d.num_actions
        && hello.seq_len as usize == d.seq_len;
    if !dims_ok {
        frame::encode_reply_err(
            &mut buf,
            0,
            0,
            0,
            &format!(
                "model dims mismatch: server {d:?}, worker hello {hello:?}"
            ),
        );
        let _ = writer.write_all(&buf);
        return true; // refused up front: clean
    }
    // Ack with the server's dims (echoing the worker's actor id).
    let ack = frame::Hello {
        role: hello.role,
        actor_id: hello.actor_id,
        obs_len: d.obs_len as u32,
        hidden: d.hidden as u32,
        num_actions: d.num_actions as u32,
        seq_len: d.seq_len as u32,
    };
    frame::encode_hello(&mut buf, &ack);
    if writer.write_all(&buf).is_err() {
        return false;
    }
    match hello.role {
        Role::Infer => serve_infer(
            reader,
            writer,
            hello.actor_id as usize,
            handle,
            opts,
            metrics,
            shutdown,
        ),
        Role::Ingest => serve_ingest(reader, sink, d, opts, metrics, shutdown),
    }
}

/// One remote actor's inference connection: reader decodes submissions
/// into the batcher; a writer thread routes reply chunks back.
fn serve_infer(
    mut reader: FrameReader,
    mut writer: Stream,
    actor: usize,
    handle: BatcherHandle,
    opts: FleetServerOpts,
    metrics: &Registry,
    shutdown: ShutdownToken,
) -> bool {
    let d = handle.dims();
    let pool = handle.slab_pool();
    let rx_frames = metrics.counter("fleet.rx_frames");
    let rx_bytes = metrics.counter("fleet.rx_bytes");
    let shed_rows = metrics.counter("fleet.shed_rows");
    let decode_time = metrics.timer("fleet.decode_seconds");
    // The reply route: the reader holds the root sender and clones it
    // into every queued item; the writer drains the receiver until all
    // senders are gone — i.e. the reader exited AND every outstanding
    // submission was answered. That disconnect IS the drain barrier.
    let (tx, rx) = channel::<ReplyChunk>(64);
    let rows_inflight = Arc::new(AtomicUsize::new(0));

    let writer_rows_inflight = rows_inflight.clone();
    let tx_frames = metrics.counter("fleet.tx_frames");
    let tx_bytes = metrics.counter("fleet.tx_bytes");
    let shed_inflight = metrics.counter("fleet.shed_inflight_rows");
    let encode_time = metrics.timer("fleet.encode_seconds");
    let writer_thread = std::thread::Builder::new()
        .name("rlarch-fleet-writer".into())
        .spawn(move || {
            let (na, hid) = (d.num_actions, d.hidden);
            let mut wbuf = Vec::new();
            let mut broken = false;
            while let Some(chunk) = rx.recv() {
                match &chunk.result {
                    Ok(range) => {
                        let (k, r0) = (chunk.rows, range.row0);
                        encode_time.time(|| {
                            frame::encode_reply_ok(
                                &mut wbuf,
                                chunk.ticket as u64,
                                chunk.slot0 as u32,
                                k,
                                &range.slab.q[r0 * na..(r0 + k) * na],
                                &range.slab.h[r0 * hid..(r0 + k) * hid],
                                &range.slab.c[r0 * hid..(r0 + k) * hid],
                            )
                        });
                    }
                    Err(msg) => frame::encode_reply_err(
                        &mut wbuf,
                        chunk.ticket as u64,
                        chunk.slot0 as u32,
                        chunk.rows,
                        msg,
                    ),
                }
                if broken || writer.write_all(&wbuf).is_err() {
                    // Dead socket: keep draining so in-flight rows keep
                    // releasing, but count what the peer never saw.
                    broken = true;
                    shed_inflight.add(chunk.rows as u64);
                } else {
                    tx_frames.inc();
                    tx_bytes.add(wbuf.len() as u64);
                }
                writer_rows_inflight.fetch_sub(chunk.rows, Ordering::AcqRel);
            }
            // Drain complete. Best-effort goodbye: on server shutdown
            // this is the clean-drain marker the worker turns into its
            // own exit; on a dead socket the write just fails.
            if !broken {
                frame::encode_goodbye(&mut wbuf);
                let _ = writer.write_all(&wbuf);
            }
            writer.shutdown_write();
        })
        .expect("spawn fleet reply writer");

    let sd = shutdown.clone();
    let stop = move || sd.is_signalled();
    let mut clean = false;
    loop {
        match reader.read_frame(&stop) {
            Ok(ReadOutcome::Frame) => {}
            Ok(ReadOutcome::Stopped) => {
                // Server drain: stop accepting submissions; the writer
                // flushes what's in flight and says goodbye.
                clean = true;
                break;
            }
            Ok(ReadOutcome::Eof) | Err(_) => break,
        }
        rx_frames.inc();
        rx_bytes.add((reader.frame().len() + 4) as u64);
        let hd = match frame::parse_header(reader.frame()) {
            Ok(hd) => hd,
            Err(_) => break,
        };
        match hd.kind {
            FrameKind::Goodbye => {
                clean = true;
                break;
            }
            FrameKind::Submit => {}
            _ => break, // protocol violation
        }
        let rows = hd.rows as usize;
        let mut slab = pool.acquire();
        let decoded = decode_time.time(|| {
            frame::decode_submit(
                frame::payload(reader.frame()),
                rows,
                d.obs_len,
                d.hidden,
                &mut slab.obs,
                &mut slab.h,
                &mut slab.c,
            )
        });
        if decoded.is_err() {
            pool.release(slab);
            break; // garbage payload: kill the connection
        }
        // Budget check. The count is incremented for shed submissions
        // too — their synthetic error chunk flows through the writer,
        // which decrements uniformly per chunk.
        let before = rows_inflight.fetch_add(rows, Ordering::AcqRel);
        if before + rows > opts.max_inflight_rows {
            shed_rows.add(rows as u64);
            pool.release(slab);
            let _ = tx.send(ReplyChunk {
                ticket: hd.ticket as usize,
                slot0: 0,
                rows,
                result: Err(format!(
                    "{SHED_PREFIX} connection over its {} in-flight row budget",
                    opts.max_inflight_rows
                )),
            });
            continue;
        }
        if let Err(e) = handle.submit(InferItem {
            actor,
            ticket: hd.ticket as usize,
            rows,
            slab,
            reply: tx.clone(),
        }) {
            // Batcher gone (or refused the item — it released the slab
            // either way): answer with the error instead of stalling.
            let _ = tx.send(ReplyChunk {
                ticket: hd.ticket as usize,
                slot0: 0,
                rows,
                result: Err(e.to_string()),
            });
        }
    }
    drop(tx);
    let _ = writer_thread.join();
    clean
}

/// One worker process's sequence-ingest connection: decode `Sequence`
/// frames into recycled slabs and batch them into the central replay.
fn serve_ingest(
    mut reader: FrameReader,
    sink: Arc<dyn SequenceSink>,
    d: crate::runtime::ModelDims,
    opts: FleetServerOpts,
    metrics: &Registry,
    shutdown: ShutdownToken,
) -> bool {
    let rx_frames = metrics.counter("fleet.rx_frames");
    let rx_bytes = metrics.counter("fleet.rx_bytes");
    let rx_seqs = metrics.counter("fleet.rx_sequences");
    let decode_time = metrics.timer("fleet.decode_seconds");
    let pool = sink.recycle_pool();
    let mut ingest = IngestQueue::new(sink.clone(), opts.insert_batch);
    let sd = shutdown.clone();
    let stop = move || sd.is_signalled();
    let mut clean = false;
    loop {
        match reader.read_frame(&stop) {
            Ok(ReadOutcome::Frame) => {}
            Ok(ReadOutcome::Stopped) => {
                clean = true;
                break;
            }
            Ok(ReadOutcome::Eof) | Err(_) => break,
        }
        rx_frames.inc();
        rx_bytes.add((reader.frame().len() + 4) as u64);
        let hd = match frame::parse_header(reader.frame()) {
            Ok(hd) => hd,
            Err(_) => break,
        };
        match hd.kind {
            FrameKind::Goodbye => {
                clean = true;
                break;
            }
            FrameKind::Sequence => {}
            _ => break,
        }
        let mut seq = match &pool {
            Some(p) => p.acquire(d.seq_len, d.obs_len, d.hidden, 0),
            None => Default::default(),
        };
        let decoded = decode_time.time(|| {
            frame::decode_sequence(frame::payload(reader.frame()), d.obs_len, d.hidden, &mut seq)
        });
        match decoded {
            Ok(()) => {
                rx_seqs.inc();
                ingest.push(seq);
            }
            Err(_) => {
                if let Some(p) = &pool {
                    p.put(seq);
                }
                break;
            }
        }
    }
    ingest.flush();
    clean
}
