//! Length-prefixed slab frames: the wire image of the pooled slab
//! protocol (DESIGN.md §5, §14).
//!
//! Every frame is `[len: u32 LE][header: 20 bytes][payload]` where `len`
//! counts everything after itself. The header is `[magic: u16][kind:
//! u8][reserved: u8][ticket: u64][slot0: u32][rows: u32]`, all
//! little-endian — `ticket`/`slot0`/`rows` mirror the in-process
//! [`ReplyChunk`](crate::coordinator::ReplyChunk) addressing exactly, so
//! a decoded reply frame scatters with the same arithmetic as a local
//! chunk. Payloads are raw little-endian `f32`/`i32` rows serialized
//! straight from (and into) recycled buffers: encoders write into a
//! reusable `Vec<u8>` whose capacity settles, decoders fill
//! caller-provided `Vec<f32>`s — steady state touches the allocator
//! zero times (hard-asserted by `micro_transport --quick`).
//!
//! Decoding is defensive at every boundary the bytes cross: bad magic,
//! unknown kind, truncated headers, and payload lengths that disagree
//! with `rows * dims` are all hard errors (never a panic, never a
//! silent mis-scatter) — property-tested in `tests/transport_fleet.rs`
//! against random rows/dims/tickets and corrupted byte streams.

use crate::rl::Sequence;

/// Header magic: a corrupt or desynchronized stream fails loudly.
pub const MAGIC: u16 = 0xAF7E;
/// Header bytes after the 4-byte length prefix.
pub const HEADER_LEN: usize = 20;
/// Upper bound on `len` (1 GiB): a corrupt length prefix must not turn
/// into an attempted giant allocation.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// What a frame carries. `Submit`/`ReplyOk`/`ReplyErr` are the wire
/// image of the in-process batcher protocol; `Sequence` ships completed
/// training sequences to the central replay; `Hello`/`Goodbye` bracket
/// a connection's life.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Connection handshake: role + model dims (both directions).
    Hello,
    /// Obs submission: `rows` of obs + h + c, ticket-tagged.
    Submit,
    /// Reply rows `slot0 .. slot0 + rows` of submission `ticket`.
    ReplyOk,
    /// Inference error for rows `slot0 .. slot0 + rows` of `ticket`.
    ReplyErr,
    /// One completed training sequence for the central replay.
    Sequence,
    /// Clean-drain marker: the sender will transmit nothing further.
    Goodbye,
    /// Heartbeat probe (client → server), nonce in `ticket`. Header
    /// only: the liveness path moves 24 bytes and allocates nothing.
    Ping,
    /// Heartbeat echo (server → client), same nonce in `ticket`.
    Pong,
}

impl FrameKind {
    fn to_u8(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::Submit => 2,
            FrameKind::ReplyOk => 3,
            FrameKind::ReplyErr => 4,
            FrameKind::Sequence => 5,
            FrameKind::Goodbye => 6,
            FrameKind::Ping => 7,
            FrameKind::Pong => 8,
        }
    }

    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => FrameKind::Hello,
            2 => FrameKind::Submit,
            3 => FrameKind::ReplyOk,
            4 => FrameKind::ReplyErr,
            5 => FrameKind::Sequence,
            6 => FrameKind::Goodbye,
            7 => FrameKind::Ping,
            8 => FrameKind::Pong,
            _ => return None,
        })
    }
}

/// Decoded frame header (the 20 bytes after the length prefix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    /// The submission's demux tag (the client's wire tag), echoed on
    /// reply frames. Unused (0) for hello/sequence/goodbye.
    pub ticket: u64,
    /// First submission row a reply frame covers (0 otherwise).
    pub slot0: u32,
    /// Row count: submission/reply rows, or `valid_len` for sequences.
    pub rows: u32,
}

/// What end of the fleet a connection serves, declared in its hello.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Split-phase inference round-trips (one per remote actor thread).
    Infer,
    /// Sequence ingest into the central replay (one per worker process).
    Ingest,
}

/// Handshake payload: both sides exchange it and refuse mismatched
/// model shapes up front instead of mis-scattering rows later.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub role: Role,
    /// Fleet-global actor id (0 for ingest connections / server acks).
    pub actor_id: u32,
    pub obs_len: u32,
    pub hidden: u32,
    pub num_actions: u32,
    pub seq_len: u32,
    /// Server incarnation tag. Workers send 0 (fresh — always
    /// accepted) or the generation they last synced with; a restarted
    /// server (generation bumped by checkpoint resume) refuses a
    /// non-zero mismatch with a `stale generation` error until the
    /// worker resyncs by re-handshaking at 0. Server acks always carry
    /// the current generation.
    pub generation: u32,
    /// Priority class of an infer connection (a
    /// `serve::PriorityClass` wire byte: 0 = actor, 1 = eval, 2 =
    /// bulk; the server refuses anything else at the handshake). Rides
    /// what was a zero pad byte of the PR 8 format, so generation-0
    /// streams are byte-identical and old workers are `actor` class.
    pub class: u8,
}

// ---------------------------------------------------------------------
// Encoding: every encoder clears `buf` and leaves one complete frame
// (length prefix included) in it, reusing the buffer's capacity.
// ---------------------------------------------------------------------

fn begin_frame(buf: &mut Vec<u8>, kind: FrameKind, ticket: u64, slot0: u32, rows: u32) {
    buf.clear();
    buf.extend_from_slice(&0u32.to_le_bytes()); // length, patched below
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.push(kind.to_u8());
    buf.push(0); // reserved
    buf.extend_from_slice(&ticket.to_le_bytes());
    buf.extend_from_slice(&slot0.to_le_bytes());
    buf.extend_from_slice(&rows.to_le_bytes());
}

fn finish_frame(buf: &mut Vec<u8>) {
    let len = (buf.len() - 4) as u32;
    buf[0..4].copy_from_slice(&len.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(buf: &mut Vec<u8>, xs: &[i32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn encode_hello(buf: &mut Vec<u8>, hello: &Hello) {
    begin_frame(buf, FrameKind::Hello, 0, 0, 0);
    buf.push(match hello.role {
        Role::Infer => 1,
        Role::Ingest => 2,
    });
    buf.push(hello.class);
    buf.extend_from_slice(&[0u8; 2]); // padding
    for v in [
        hello.actor_id,
        hello.obs_len,
        hello.hidden,
        hello.num_actions,
        hello.seq_len,
        hello.generation,
    ] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    finish_frame(buf);
}

/// Heartbeat probe: header-only, `nonce` rides in the ticket field.
pub fn encode_ping(buf: &mut Vec<u8>, nonce: u64) {
    begin_frame(buf, FrameKind::Ping, nonce, 0, 0);
    finish_frame(buf);
}

/// Heartbeat echo: header-only, echoing the probe's nonce.
pub fn encode_pong(buf: &mut Vec<u8>, nonce: u64) {
    begin_frame(buf, FrameKind::Pong, nonce, 0, 0);
    finish_frame(buf);
}

/// Serialize one obs submission straight from the caller's borrowed
/// rows (the same slices a [`crate::coordinator::InferSlab`] is filled
/// from — the wire path makes exactly the one copy the in-process path
/// makes).
pub fn encode_submit(
    buf: &mut Vec<u8>,
    ticket: u64,
    rows: usize,
    obs: &[f32],
    h: &[f32],
    c: &[f32],
) {
    begin_frame(buf, FrameKind::Submit, ticket, 0, rows as u32);
    put_f32s(buf, obs);
    put_f32s(buf, h);
    put_f32s(buf, c);
    finish_frame(buf);
}

/// Serialize one reply chunk's rows straight from the batcher's shared
/// output slab (the borrowed slices are `[row0 .. row0 + rows]` of a
/// [`crate::coordinator::ReplyRange`]).
pub fn encode_reply_ok(
    buf: &mut Vec<u8>,
    ticket: u64,
    slot0: u32,
    rows: usize,
    q: &[f32],
    h: &[f32],
    c: &[f32],
) {
    begin_frame(buf, FrameKind::ReplyOk, ticket, slot0, rows as u32);
    put_f32s(buf, q);
    put_f32s(buf, h);
    put_f32s(buf, c);
    finish_frame(buf);
}

pub fn encode_reply_err(buf: &mut Vec<u8>, ticket: u64, slot0: u32, rows: usize, msg: &str) {
    begin_frame(buf, FrameKind::ReplyErr, ticket, slot0, rows as u32);
    buf.extend_from_slice(msg.as_bytes());
    finish_frame(buf);
}

/// Serialize one completed training sequence (worker → central replay).
/// The payload leads with its own shape header so the receiver can
/// validate against its model dims before trusting any row arithmetic.
pub fn encode_sequence(buf: &mut Vec<u8>, seq: &Sequence) {
    let t = seq.seq_len();
    let obs_len = if t == 0 { 0 } else { seq.obs.len() / t };
    let hidden = seq.h0.len();
    begin_frame(buf, FrameKind::Sequence, 0, 0, seq.valid_len as u32);
    for v in [
        t as u32,
        obs_len as u32,
        hidden as u32,
        seq.actor_id as u32,
        seq.valid_len as u32,
    ] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    put_f32s(buf, &seq.obs);
    put_i32s(buf, &seq.actions);
    put_f32s(buf, &seq.rewards);
    put_f32s(buf, &seq.discounts);
    put_f32s(buf, &seq.h0);
    put_f32s(buf, &seq.c0);
    finish_frame(buf);
}

pub fn encode_goodbye(buf: &mut Vec<u8>) {
    begin_frame(buf, FrameKind::Goodbye, 0, 0, 0);
    finish_frame(buf);
}

// ---------------------------------------------------------------------
// Decoding: `frame` is the `len` bytes after the length prefix.
// ---------------------------------------------------------------------

/// Parse and validate the 20-byte header at the front of `frame`.
pub fn parse_header(frame: &[u8]) -> anyhow::Result<FrameHeader> {
    anyhow::ensure!(
        frame.len() >= HEADER_LEN,
        "truncated frame header: {} bytes",
        frame.len()
    );
    let magic = u16::from_le_bytes([frame[0], frame[1]]);
    anyhow::ensure!(magic == MAGIC, "bad frame magic {magic:#06x}");
    let kind = FrameKind::from_u8(frame[2])
        .ok_or_else(|| anyhow::anyhow!("unknown frame kind {}", frame[2]))?;
    let ticket = u64::from_le_bytes(frame[4..12].try_into().unwrap());
    let slot0 = u32::from_le_bytes(frame[12..16].try_into().unwrap());
    let rows = u32::from_le_bytes(frame[16..20].try_into().unwrap());
    Ok(FrameHeader {
        kind,
        ticket,
        slot0,
        rows,
    })
}

/// The payload bytes of a parsed frame.
pub fn payload(frame: &[u8]) -> &[u8] {
    &frame[HEADER_LEN..]
}

fn get_f32s(src: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(src.len() / 4);
    for w in src.chunks_exact(4) {
        out.push(f32::from_le_bytes([w[0], w[1], w[2], w[3]]));
    }
}

fn get_i32s(src: &[u8], out: &mut Vec<i32>) {
    out.clear();
    out.reserve(src.len() / 4);
    for w in src.chunks_exact(4) {
        out.push(i32::from_le_bytes([w[0], w[1], w[2], w[3]]));
    }
}

pub fn decode_hello(pl: &[u8]) -> anyhow::Result<Hello> {
    anyhow::ensure!(pl.len() == 28, "hello payload length {}", pl.len());
    let role = match pl[0] {
        1 => Role::Infer,
        2 => Role::Ingest,
        r => anyhow::bail!("unknown hello role {r}"),
    };
    let u = |i: usize| u32::from_le_bytes(pl[i..i + 4].try_into().unwrap());
    Ok(Hello {
        role,
        actor_id: u(4),
        obs_len: u(8),
        hidden: u(12),
        num_actions: u(16),
        seq_len: u(20),
        generation: u(24),
        class: pl[1],
    })
}

/// Decode a submit payload into recycled slab buffers, validating the
/// payload length against `rows * dims` exactly.
pub fn decode_submit(
    pl: &[u8],
    rows: usize,
    obs_len: usize,
    hidden: usize,
    obs: &mut Vec<f32>,
    h: &mut Vec<f32>,
    c: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let want = rows * (obs_len + 2 * hidden) * 4;
    anyhow::ensure!(
        rows > 0 && pl.len() == want,
        "submit payload {} bytes, want {want} ({rows} rows)",
        pl.len()
    );
    let ob = rows * obs_len * 4;
    let hb = rows * hidden * 4;
    get_f32s(&pl[..ob], obs);
    get_f32s(&pl[ob..ob + hb], h);
    get_f32s(&pl[ob + hb..], c);
    Ok(())
}

/// Decode a reply-ok payload (`rows` of q + h + c) into recycled
/// buffers, validating the payload length against `rows * dims`.
pub fn decode_reply_ok(
    pl: &[u8],
    rows: usize,
    num_actions: usize,
    hidden: usize,
    q: &mut Vec<f32>,
    h: &mut Vec<f32>,
    c: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let want = rows * (num_actions + 2 * hidden) * 4;
    anyhow::ensure!(
        rows > 0 && pl.len() == want,
        "reply payload {} bytes, want {want} ({rows} rows)",
        pl.len()
    );
    let qb = rows * num_actions * 4;
    let hb = rows * hidden * 4;
    get_f32s(&pl[..qb], q);
    get_f32s(&pl[qb..qb + hb], h);
    get_f32s(&pl[qb + hb..], c);
    Ok(())
}

pub fn decode_reply_err(pl: &[u8]) -> anyhow::Result<&str> {
    std::str::from_utf8(pl).map_err(|e| anyhow::anyhow!("reply error not utf-8: {e}"))
}

/// Decode a sequence payload into a recycled [`Sequence`], validating
/// its self-described shape against the payload length and the
/// receiver's expected dims.
pub fn decode_sequence(
    pl: &[u8],
    want_obs_len: usize,
    want_hidden: usize,
    out: &mut Sequence,
) -> anyhow::Result<()> {
    anyhow::ensure!(pl.len() >= 20, "sequence payload too short: {}", pl.len());
    let u = |i: usize| u32::from_le_bytes(pl[i..i + 4].try_into().unwrap()) as usize;
    let (t, obs_len, hidden) = (u(0), u(4), u(8));
    let (actor_id, valid_len) = (u(12), u(16));
    anyhow::ensure!(
        obs_len == want_obs_len && hidden == want_hidden,
        "sequence dims obs_len {obs_len}/hidden {hidden}, want {want_obs_len}/{want_hidden}"
    );
    anyhow::ensure!(valid_len <= t, "sequence valid_len {valid_len} > seq_len {t}");
    let want = 20 + (t * obs_len + 3 * t + 2 * hidden) * 4;
    anyhow::ensure!(
        pl.len() == want,
        "sequence payload {} bytes, want {want}",
        pl.len()
    );
    let mut at = 20usize;
    let mut take = |n: usize| {
        let s = &pl[at..at + n * 4];
        at += n * 4;
        s
    };
    get_f32s(take(t * obs_len), &mut out.obs);
    get_i32s(take(t), &mut out.actions);
    get_f32s(take(t), &mut out.rewards);
    get_f32s(take(t), &mut out.discounts);
    get_f32s(take(hidden), &mut out.h0);
    get_f32s(take(hidden), &mut out.c0);
    out.actor_id = actor_id;
    out.valid_len = valid_len;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_len(buf: &[u8]) -> &[u8] {
        let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4, "length prefix covers the frame");
        &buf[4..]
    }

    #[test]
    fn submit_roundtrip() {
        let (rows, obs_len, hidden) = (3usize, 5usize, 2usize);
        let obs: Vec<f32> = (0..rows * obs_len).map(|i| i as f32).collect();
        let h: Vec<f32> = (0..rows * hidden).map(|i| -(i as f32)).collect();
        let c: Vec<f32> = (0..rows * hidden).map(|i| 0.5 * i as f32).collect();
        let mut buf = Vec::new();
        encode_submit(&mut buf, 42, rows, &obs, &h, &c);
        let frame = strip_len(&buf);
        let hd = parse_header(frame).unwrap();
        assert_eq!(hd.kind, FrameKind::Submit);
        assert_eq!(hd.ticket, 42);
        assert_eq!(hd.rows, rows as u32);
        let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        decode_submit(payload(frame), rows, obs_len, hidden, &mut o2, &mut h2, &mut c2)
            .unwrap();
        assert_eq!(o2, obs);
        assert_eq!(h2, h);
        assert_eq!(c2, c);
    }

    #[test]
    fn reply_roundtrip_and_err() {
        let (rows, na, hidden) = (2usize, 4usize, 3usize);
        let q: Vec<f32> = (0..rows * na).map(|i| i as f32 * 0.1).collect();
        let h = vec![1.0f32; rows * hidden];
        let c = vec![2.0f32; rows * hidden];
        let mut buf = Vec::new();
        encode_reply_ok(&mut buf, 7, 5, rows, &q, &h, &c);
        let frame = strip_len(&buf);
        let hd = parse_header(frame).unwrap();
        assert_eq!((hd.kind, hd.ticket, hd.slot0), (FrameKind::ReplyOk, 7, 5));
        let (mut q2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        decode_reply_ok(payload(frame), rows, na, hidden, &mut q2, &mut h2, &mut c2)
            .unwrap();
        assert_eq!(q2, q);

        encode_reply_err(&mut buf, 9, 0, 3, "backend exploded");
        let frame = strip_len(&buf);
        assert_eq!(parse_header(frame).unwrap().kind, FrameKind::ReplyErr);
        assert_eq!(decode_reply_err(payload(frame)).unwrap(), "backend exploded");
    }

    #[test]
    fn hello_and_goodbye_roundtrip() {
        let hello = Hello {
            role: Role::Ingest,
            actor_id: 3,
            obs_len: 400,
            hidden: 16,
            num_actions: 4,
            seq_len: 30,
            generation: 2,
            class: 1,
        };
        let mut buf = Vec::new();
        encode_hello(&mut buf, &hello);
        let frame = strip_len(&buf);
        assert_eq!(parse_header(frame).unwrap().kind, FrameKind::Hello);
        assert_eq!(decode_hello(payload(frame)).unwrap(), hello);

        encode_goodbye(&mut buf);
        let frame = strip_len(&buf);
        assert_eq!(parse_header(frame).unwrap().kind, FrameKind::Goodbye);
        assert!(payload(frame).is_empty());
    }

    #[test]
    fn ping_pong_roundtrip_header_only() {
        let mut buf = Vec::new();
        encode_ping(&mut buf, 0xDEAD_BEEF_0042);
        let frame = strip_len(&buf);
        let hd = parse_header(frame).unwrap();
        assert_eq!((hd.kind, hd.ticket), (FrameKind::Ping, 0xDEAD_BEEF_0042));
        assert!(payload(frame).is_empty());

        encode_pong(&mut buf, 7);
        let frame = strip_len(&buf);
        let hd = parse_header(frame).unwrap();
        assert_eq!((hd.kind, hd.ticket), (FrameKind::Pong, 7));
        assert!(payload(frame).is_empty());
    }

    #[test]
    fn sequence_roundtrip() {
        let seq = Sequence {
            obs: (0..12).map(|i| i as f32).collect(),
            actions: vec![1, 2, 3],
            rewards: vec![0.5, -1.0, 0.0],
            discounts: vec![0.99, 0.99, 0.0],
            h0: vec![0.1, 0.2],
            c0: vec![-0.1, -0.2],
            actor_id: 7,
            valid_len: 3,
        };
        let mut buf = Vec::new();
        encode_sequence(&mut buf, &seq);
        let frame = strip_len(&buf);
        assert_eq!(parse_header(frame).unwrap().kind, FrameKind::Sequence);
        let mut out = Sequence::default();
        decode_sequence(payload(frame), 4, 2, &mut out).unwrap();
        assert_eq!(out, seq);
        // Dim mismatch is refused before any row arithmetic.
        assert!(decode_sequence(payload(frame), 5, 2, &mut out).is_err());
    }

    #[test]
    fn corrupt_headers_are_rejected() {
        let mut buf = Vec::new();
        encode_goodbye(&mut buf);
        let mut frame = strip_len(&buf).to_vec();
        assert!(parse_header(&frame).is_ok());
        // Bad magic.
        frame[0] ^= 0xFF;
        assert!(parse_header(&frame).is_err());
        frame[0] ^= 0xFF;
        // Unknown kind.
        frame[2] = 99;
        assert!(parse_header(&frame).is_err());
        // Truncated header.
        assert!(parse_header(&frame[..HEADER_LEN - 1]).is_err());
    }

    #[test]
    fn payload_length_mismatches_are_rejected() {
        let (mut o, mut h, mut c) = (Vec::new(), Vec::new(), Vec::new());
        // One byte short of 1 row x (2 + 2*1) f32s.
        let pl = vec![0u8; 4 * 4 - 1];
        assert!(decode_submit(&pl, 1, 2, 1, &mut o, &mut h, &mut c).is_err());
        // Zero rows is never valid.
        assert!(decode_submit(&[], 0, 2, 1, &mut o, &mut h, &mut c).is_err());
        assert!(decode_reply_ok(&pl, 1, 2, 1, &mut o, &mut h, &mut c).is_err());
    }
}
