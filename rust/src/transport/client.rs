//! The worker-process side of the fleet: [`RemoteClient`] (split-phase
//! inference over a socket) and [`RemoteIngest`] (sequence shipping to
//! the central replay).
//!
//! `RemoteClient` is the wire twin of
//! [`CentralClient`](crate::policy::CentralClient): same persistent-
//! mailbox demultiplexing (here the socket is the mailbox), same
//! monotone wire tags distinct from caller tickets, same stash for
//! out-of-tag reply chunks. The differences are the failure modes a
//! socket adds, all absorbed below the [`PolicyClient`] trait so
//! `coordinator::actor` runs unmodified:
//!
//! * **Reconnect-with-backoff** — a broken connection re-dials,
//!   re-handshakes, and re-sends every retained in-flight submission
//!   frame in tag order. Inference is deterministic and scattering is
//!   idempotent, so at-least-once resubmission is safe; replies from
//!   the dead connection are discarded wholesale.
//! * **Shed retry** — the server bounds in-flight rows per connection;
//!   an over-budget submission comes back as a `shed:` error reply and
//!   is simply re-sent after an interruptible pause (backpressure as a
//!   counter and a delay, never a stall or a crash).
//! * **Goodbye** — the server's clean-drain marker signals this
//!   worker's shutdown token so every local actor thread winds down.

use super::frame::{self, FrameKind, Role};
use super::{dial, Addr, DeadlineEwma, FrameReader, Heartbeat, ReadOutcome, Stream};
use crate::exec::ShutdownToken;
use crate::metrics::{Counter, Gauge, Registry, Timer};
use crate::policy::PolicyClient;
use crate::replay::SequenceSink;
use crate::rl::{Sequence, SequencePool};
use crate::runtime::ModelDims;
use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Prefix of the reply-error message the server uses to shed an
/// over-budget submission. Clients treat it as "try again", not as an
/// inference failure.
pub const SHED_PREFIX: &str = "shed:";

/// Prefix of the handshake-refusal message a restarted server sends a
/// worker synced to a previous incarnation. Clients resync by
/// re-handshaking at generation 0.
pub const STALE_GEN_PREFIX: &str = "stale generation";

/// How long a blocked read may hold the socket before the reader polls
/// the shutdown token (partial frames resume across these slices).
const READ_SLICE: Duration = Duration::from_millis(50);

/// Ticket deadlines arm at this multiple of the smoothed round-trip
/// time (floored by `fleet.liveness_timeout_ms`): late enough that
/// batching jitter never fires it, early enough to notice a dead
/// server inside a few RTTs.
const DEADLINE_RTT_MULT: f64 = 4.0;

/// Connection knobs shared by both worker-side endpoints (mirrors the
/// `[fleet]` config section).
#[derive(Clone, Copy, Debug)]
pub struct RemoteClientOpts {
    /// Re-dial attempts beyond the first, per (re)connect.
    pub connect_retries: usize,
    /// Initial re-dial backoff; doubles per attempt, capped at 2 s.
    pub backoff_ms: u64,
    /// Send a `Ping` after this much quiet on the infer connection
    /// (0 = never; pair with the server's liveness window).
    pub heartbeat_ms: u64,
    /// Per-ticket reply deadline floor; 0 disables deadlines. The
    /// armed deadline is `max(this, DEADLINE_RTT_MULT * ewma-rtt)` —
    /// a lapsed deadline reconnects and resubmits rather than erroring
    /// (at-least-once, same as any broken-socket recovery).
    pub liveness_ms: u64,
    /// Priority class declared in the hello (a `serve::PriorityClass`
    /// wire byte: 0 = actor, 1 = eval, 2 = bulk). Training workers are
    /// 0; the serving admission ladder sheds higher bytes first.
    pub class: u8,
}

impl Default for RemoteClientOpts {
    fn default() -> Self {
        Self {
            connect_retries: 40,
            backoff_ms: 50,
            heartbeat_ms: 0,
            liveness_ms: 0,
            class: 0,
        }
    }
}

fn hello_for(role: Role, actor_id: usize, d: &ModelDims, class: u8) -> frame::Hello {
    frame::Hello {
        role,
        actor_id: actor_id as u32,
        obs_len: d.obs_len as u32,
        hidden: d.hidden as u32,
        num_actions: d.num_actions as u32,
        seq_len: d.seq_len as u32,
        // Fresh connections always sync from scratch; `establish`
        // adopts the server's generation from the ack for reconnects.
        generation: 0,
        class,
    }
}

/// Dial + handshake: send our hello, require a dims-matching hello ack.
/// Returns the write half and a frame reader over the read half. The
/// hello is mutable for the generation fence: the ack's generation is
/// adopted (so reconnects prove they were synced to this incarnation),
/// and a `stale generation` refusal resyncs by re-handshaking at 0.
fn establish(
    addr: &Addr,
    hello: &mut frame::Hello,
    opts: &RemoteClientOpts,
    shutdown: &ShutdownToken,
) -> anyhow::Result<(Stream, FrameReader)> {
    loop {
        let stream = dial(addr, opts.connect_retries, opts.backoff_ms, Some(shutdown))?;
        stream.set_read_timeout(Some(READ_SLICE))?;
        stream.set_write_timeout(Some(Duration::from_secs(5)))?;
        let mut writer = stream.try_clone()?;
        let mut reader = FrameReader::new(stream);
        let mut buf = Vec::new();
        frame::encode_hello(&mut buf, hello);
        writer.write_all(&buf)?;
        match reader.read_frame(&|| shutdown.is_signalled())? {
            ReadOutcome::Frame => {}
            ReadOutcome::Eof => anyhow::bail!("server closed the connection during handshake"),
            ReadOutcome::Stopped => anyhow::bail!("shutdown during handshake"),
            ReadOutcome::TimedOut => anyhow::bail!("handshake timed out"),
        }
        let hd = frame::parse_header(reader.frame())?;
        if hd.kind == FrameKind::ReplyErr {
            let msg = frame::decode_reply_err(frame::payload(reader.frame()))?;
            if msg.starts_with(STALE_GEN_PREFIX) && hello.generation != 0 {
                // The server restarted since we last synced: resync
                // fresh. In-flight work is resent by the caller, so
                // nothing is lost crossing the generation fence.
                hello.generation = 0;
                continue;
            }
            anyhow::bail!("server refused connection: {msg}");
        }
        anyhow::ensure!(
            hd.kind == FrameKind::Hello,
            "expected hello ack, got {:?}",
            hd.kind
        );
        let ack = frame::decode_hello(frame::payload(reader.frame()))?;
        anyhow::ensure!(
            ack.obs_len == hello.obs_len
                && ack.hidden == hello.hidden
                && ack.num_actions == hello.num_actions
                && ack.seq_len == hello.seq_len,
            "model dims mismatch: server acked {ack:?}, worker sent {hello:?}"
        );
        hello.generation = ack.generation;
        return Ok((writer, reader));
    }
}

/// One in-flight submission: the retained encoded frame is what makes
/// reconnect resend (and shed retry) possible without the caller's
/// involvement.
struct Pending {
    rows: usize,
    tag: u64,
    buf: Vec<u8>,
    t0: Instant,
}

/// Split-phase [`PolicyClient`] over a fleet connection (see the module
/// docs). One per remote actor thread; each owns its socket and its tag
/// space.
pub struct RemoteClient {
    addr: Addr,
    hello: frame::Hello,
    opts: RemoteClientOpts,
    shutdown: ShutdownToken,
    writer: Stream,
    reader: FrameReader,
    dims: ModelDims,
    inflight: Vec<Option<Pending>>,
    /// Recycled submission-frame buffers (capacity settles after
    /// warmup: submit encodes into one of these, zero-alloc).
    buf_free: Vec<Vec<u8>>,
    /// Raw reply frames for other in-flight tags, parked for their own
    /// `wait`; recycled through `stash_free`.
    stash: Vec<Vec<u8>>,
    stash_free: Vec<Vec<u8>>,
    /// Decode scratch rows (reply payload lands here, then scatters
    /// into the caller's slabs).
    sq: Vec<f32>,
    sh: Vec<f32>,
    sc: Vec<f32>,
    next_tag: u64,
    /// Ping scheduler (`heartbeat_ms > 0`): any write is proof of
    /// life, so only idle connections actually ping.
    heartbeat: Option<Heartbeat>,
    /// Ticket-deadline estimator (`liveness_ms > 0`).
    deadline: Option<DeadlineEwma>,
    ping_buf: Vec<u8>,
    ping_nonce: u64,
    tx_frames: Counter,
    tx_bytes: Counter,
    rx_frames: Counter,
    rx_bytes: Counter,
    reconnects: Counter,
    resubmits: Counter,
    timeouts: Counter,
    rtt: Timer,
    inflight_gauge: Gauge,
}

impl RemoteClient {
    /// Dial `addr` (with backoff) and handshake as an infer connection
    /// for fleet-global actor `actor`.
    pub fn connect(
        addr: &Addr,
        actor: usize,
        dims: ModelDims,
        opts: RemoteClientOpts,
        metrics: &Registry,
        shutdown: ShutdownToken,
    ) -> anyhow::Result<Self> {
        let mut hello = hello_for(Role::Infer, actor, &dims, opts.class);
        let (writer, reader) = establish(addr, &mut hello, &opts, &shutdown)?;
        Ok(Self {
            addr: addr.clone(),
            hello,
            shutdown,
            writer,
            reader,
            dims,
            inflight: Vec::new(),
            buf_free: Vec::new(),
            stash: Vec::new(),
            stash_free: Vec::new(),
            sq: Vec::new(),
            sh: Vec::new(),
            sc: Vec::new(),
            next_tag: 0,
            heartbeat: (opts.heartbeat_ms > 0).then(|| {
                Heartbeat::new(Duration::from_millis(opts.heartbeat_ms), Instant::now())
            }),
            deadline: (opts.liveness_ms > 0).then(|| {
                DeadlineEwma::new(
                    Duration::from_millis(opts.liveness_ms),
                    DEADLINE_RTT_MULT,
                )
            }),
            ping_buf: Vec::new(),
            ping_nonce: 0,
            opts,
            tx_frames: metrics.counter("fleet.tx_frames"),
            tx_bytes: metrics.counter("fleet.tx_bytes"),
            rx_frames: metrics.counter("fleet.rx_frames"),
            rx_bytes: metrics.counter("fleet.rx_bytes"),
            reconnects: metrics.counter("fleet.client_reconnects"),
            resubmits: metrics.counter("fleet.resubmits"),
            timeouts: metrics.counter("fleet.timeouts"),
            rtt: metrics.timer("fleet.rtt_seconds"),
            inflight_gauge: metrics.gauge("policy.inflight"),
        })
    }

    fn tag_live(&self, tag: u64) -> bool {
        self.inflight.iter().flatten().any(|p| p.tag == tag)
    }

    /// Re-dial, re-handshake, and re-send every retained in-flight
    /// frame in tag order. Replies stashed from the dead connection are
    /// dropped wholesale — the resent submissions regenerate them.
    fn recover(&mut self, why: &str) -> anyhow::Result<()> {
        'attempt: for _ in 0..=self.opts.connect_retries {
            if self.shutdown.is_signalled() {
                anyhow::bail!("shutdown during reconnect ({why})");
            }
            let (w, r) =
                match establish(&self.addr, &mut self.hello, &self.opts, &self.shutdown) {
                    Ok(pair) => pair,
                    Err(_) => continue 'attempt,
                };
            self.writer = w;
            self.reader = r;
            self.reconnects.inc();
            if let Some(hb) = &mut self.heartbeat {
                hb.sent(Instant::now());
            }
            while let Some(b) = self.stash.pop() {
                self.stash_free.push(b);
            }
            let mut order: Vec<usize> = (0..self.inflight.len())
                .filter(|&i| self.inflight[i].is_some())
                .collect();
            order.sort_by_key(|&i| self.inflight[i].as_ref().expect("filtered").tag);
            for i in order {
                if self.resend(i).is_err() {
                    continue 'attempt;
                }
            }
            return Ok(());
        }
        anyhow::bail!(
            "reconnect to {} failed after {} attempts ({why})",
            self.addr,
            self.opts.connect_retries + 1
        )
    }

    /// Re-send the retained frame of in-flight slot `i`.
    fn resend(&mut self, i: usize) -> std::io::Result<()> {
        let buf = std::mem::take(&mut self.inflight[i].as_mut().expect("in flight").buf);
        let res = self.writer.write_all(&buf);
        self.tx_frames.inc();
        self.tx_bytes.add(buf.len() as u64);
        self.inflight[i].as_mut().expect("in flight").buf = buf;
        res
    }

    /// Shed retry: pause briefly (interruptibly), then re-send the shed
    /// submission.
    fn retry_shed(&mut self, i: usize) -> anyhow::Result<()> {
        self.resubmits.inc();
        if self
            .shutdown
            .sleep_interruptible(Duration::from_millis(self.opts.backoff_ms.max(1)))
        {
            anyhow::bail!("shutdown while backing off a shed submission");
        }
        if self.resend(i).is_err() {
            self.recover("resending a shed submission")?;
        }
        Ok(())
    }
}

/// Decode one reply-ok frame into the scratch rows and scatter them
/// into the caller's `[n, ·]` output slabs. Free function so the caller
/// can hold disjoint borrows of the reader's frame and the scratch.
#[allow(clippy::too_many_arguments)]
fn scatter_reply(
    fr: &[u8],
    hd: frame::FrameHeader,
    d: &ModelDims,
    n: usize,
    sq: &mut Vec<f32>,
    sh: &mut Vec<f32>,
    sc: &mut Vec<f32>,
    q: &mut [f32],
    h: &mut [f32],
    c: &mut [f32],
) -> anyhow::Result<usize> {
    let (s, k) = (hd.slot0 as usize, hd.rows as usize);
    anyhow::ensure!(s + k <= n, "reply chunk rows out of range");
    frame::decode_reply_ok(frame::payload(fr), k, d.num_actions, d.hidden, sq, sh, sc)?;
    let (na, hid) = (d.num_actions, d.hidden);
    q[s * na..(s + k) * na].copy_from_slice(sq);
    h[s * hid..(s + k) * hid].copy_from_slice(sh);
    c[s * hid..(s + k) * hid].copy_from_slice(sc);
    Ok(k)
}

impl Drop for RemoteClient {
    fn drop(&mut self) {
        // Same contract as CentralClient: give abandoned submissions'
        // gauge increments back. A best-effort goodbye tells the server
        // this is a clean departure, not a death.
        let abandoned = self.inflight.iter().filter(|p| p.is_some()).count();
        if abandoned > 0 {
            self.inflight_gauge.add(-(abandoned as f64));
        }
        let mut buf = self.buf_free.pop().unwrap_or_default();
        frame::encode_goodbye(&mut buf);
        let _ = self.writer.write_all(&buf);
        self.writer.shutdown_write();
    }
}

impl PolicyClient for RemoteClient {
    fn submit(
        &mut self,
        ticket: usize,
        rows: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<()> {
        if self.inflight.len() <= ticket {
            self.inflight.resize_with(ticket + 1, || None);
        }
        anyhow::ensure!(
            self.inflight[ticket].is_none(),
            "ticket {ticket} already in flight"
        );
        let d = &self.dims;
        anyhow::ensure!(
            rows > 0
                && obs.len() == rows * d.obs_len
                && h.len() == rows * d.hidden
                && c.len() == rows * d.hidden,
            "malformed submission: {rows} rows, obs {}, h {}, c {}",
            obs.len(),
            h.len(),
            c.len()
        );
        let mut buf = self.buf_free.pop().unwrap_or_default();
        let tag = self.next_tag;
        self.next_tag = self.next_tag.wrapping_add(1);
        frame::encode_submit(&mut buf, tag, rows, obs, h, c);
        let wrote = self.writer.write_all(&buf);
        self.tx_frames.inc();
        self.tx_bytes.add(buf.len() as u64);
        if let Some(hb) = &mut self.heartbeat {
            // Any frame is proof of life: submissions defer the ping.
            hb.sent(Instant::now());
        }
        self.inflight[ticket] = Some(Pending {
            rows,
            tag,
            buf,
            t0: Instant::now(),
        });
        self.inflight_gauge.add(1.0);
        if wrote.is_err() {
            self.recover("submit write failed")?;
        }
        Ok(())
    }

    fn wait(
        &mut self,
        ticket: usize,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<()> {
        let d = self.dims;
        let (n, tag) = {
            let p = self
                .inflight
                .get(ticket)
                .and_then(Option::as_ref)
                .ok_or_else(|| anyhow::anyhow!("wait on idle ticket {ticket}"))?;
            (p.rows, p.tag)
        };
        anyhow::ensure!(q.len() == n * d.num_actions, "q slab length");
        anyhow::ensure!(
            h.len() == n * d.hidden && c.len() == n * d.hidden,
            "recurrent slab length"
        );
        // Unlike CentralClient, the pending entry stays live until the
        // last row lands: its retained frame is the reconnect/shed
        // resend source. Terminal exits below clear it explicitly.
        let mut done = 0usize;
        // Redeem parked frames first (stale tags recycle silently).
        let mut i = 0;
        while i < self.stash.len() {
            let fhd = frame::parse_header(&self.stash[i])?;
            if fhd.ticket == tag {
                let fr = self.stash.swap_remove(i);
                if fhd.kind == FrameKind::ReplyOk {
                    done += scatter_reply(
                        &fr, fhd, &d, n, &mut self.sq, &mut self.sh, &mut self.sc, q, h, c,
                    )?;
                    self.stash_free.push(fr);
                } else {
                    let msg = frame::decode_reply_err(frame::payload(&fr))?.to_string();
                    self.stash_free.push(fr);
                    if msg.starts_with(SHED_PREFIX) {
                        let idx = ticket; // shed covers this whole submission
                        self.retry_shed(idx)?;
                        done = 0;
                    } else {
                        let p = self.inflight[ticket].take().expect("in flight");
                        self.buf_free.push(p.buf);
                        self.inflight_gauge.add(-1.0);
                        anyhow::bail!("remote inference failed: {msg}");
                    }
                }
            } else if !self.tag_live(fhd.ticket) {
                let fr = self.stash.swap_remove(i);
                self.stash_free.push(fr);
            } else {
                i += 1;
            }
        }
        let sd = self.shutdown.clone();
        let stop = move || sd.is_signalled();
        while done < n {
            // The wake-up is the earlier of this ticket's deadline and
            // the next owed heartbeat; both paths reuse buffers and
            // counters only (zero-alloc, `micro_transport` gate).
            let deadline_at = self.deadline.as_ref().map(|dl| {
                self.inflight[ticket].as_ref().expect("in flight").t0 + dl.deadline()
            });
            let ping_at = self.heartbeat.as_ref().map(|hb| hb.next_due());
            let wake = match (deadline_at, ping_at) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            match self.reader.read_frame_until(&stop, wake) {
                Ok(ReadOutcome::Frame) => {}
                Ok(ReadOutcome::Stopped) => {
                    anyhow::bail!("shutdown while waiting for inference replies")
                }
                Ok(ReadOutcome::TimedOut) => {
                    let now = Instant::now();
                    if deadline_at.is_some_and(|at| now >= at) {
                        // The reply is overdue far past the smoothed
                        // RTT: assume the connection (or our ticket) is
                        // lost and take the proven broken-socket path —
                        // reconnect, resend, re-arm.
                        self.timeouts.inc();
                        self.recover("ticket deadline exceeded")?;
                        if let Some(p) = self.inflight[ticket].as_mut() {
                            p.t0 = Instant::now();
                        }
                        done = 0;
                        continue;
                    }
                    if self.heartbeat.as_ref().is_some_and(|hb| hb.due(now)) {
                        self.ping_nonce = self.ping_nonce.wrapping_add(1);
                        frame::encode_ping(&mut self.ping_buf, self.ping_nonce);
                        if self.writer.write_all(&self.ping_buf).is_err() {
                            self.recover("ping write failed")?;
                            done = 0;
                        } else {
                            self.tx_frames.inc();
                            self.tx_bytes.add(self.ping_buf.len() as u64);
                        }
                        if let Some(hb) = &mut self.heartbeat {
                            hb.sent(Instant::now());
                        }
                    }
                    continue;
                }
                Ok(ReadOutcome::Eof) => {
                    self.recover("server closed the connection")?;
                    done = 0;
                    continue;
                }
                Err(e) => {
                    if self.shutdown.is_signalled() {
                        anyhow::bail!("shutdown while waiting for inference replies");
                    }
                    self.recover(&format!("read failed: {e}"))?;
                    done = 0;
                    continue;
                }
            }
            self.rx_frames.inc();
            self.rx_bytes.add((self.reader.frame().len() + 4) as u64);
            let hd = frame::parse_header(self.reader.frame())?;
            match hd.kind {
                FrameKind::Goodbye => {
                    // Server drain: wind the whole worker down.
                    self.shutdown.signal();
                    anyhow::bail!("server sent goodbye (drain)");
                }
                // Heartbeat echo: receiving it was the point.
                FrameKind::Pong => continue,
                FrameKind::ReplyOk | FrameKind::ReplyErr => {}
                k => anyhow::bail!("unexpected {k:?} frame on infer connection"),
            }
            if hd.ticket == tag {
                if hd.kind == FrameKind::ReplyOk {
                    done += scatter_reply(
                        self.reader.frame(),
                        hd,
                        &d,
                        n,
                        &mut self.sq,
                        &mut self.sh,
                        &mut self.sc,
                        q,
                        h,
                        c,
                    )?;
                } else {
                    let shed = {
                        let msg = frame::decode_reply_err(frame::payload(self.reader.frame()))?;
                        msg.starts_with(SHED_PREFIX).then_some(())
                            .ok_or_else(|| anyhow::anyhow!("remote inference failed: {msg}"))
                    };
                    match shed {
                        Ok(()) => {
                            self.retry_shed(ticket)?;
                            done = 0;
                        }
                        Err(e) => {
                            let p = self.inflight[ticket].take().expect("in flight");
                            self.buf_free.push(p.buf);
                            self.inflight_gauge.add(-1.0);
                            return Err(e);
                        }
                    }
                }
            } else if let Some(idx) = self
                .inflight
                .iter()
                .position(|p| p.as_ref().is_some_and(|p| p.tag == hd.ticket))
            {
                let shed = hd.kind == FrameKind::ReplyErr
                    && frame::decode_reply_err(frame::payload(self.reader.frame()))
                        .map(|m| m.starts_with(SHED_PREFIX))
                        .unwrap_or(false);
                if shed {
                    self.retry_shed(idx)?;
                } else {
                    // Another live submission's reply: park the raw
                    // frame for its own wait.
                    let mut b = self.stash_free.pop().unwrap_or_default();
                    b.clear();
                    b.extend_from_slice(self.reader.frame());
                    self.stash.push(b);
                }
            }
            // else: stale tag (an errored-out generation) — discard.
        }
        let p = self.inflight[ticket].take().expect("in flight");
        let rtt = p.t0.elapsed();
        self.rtt.record(rtt.as_secs_f64());
        if let Some(dl) = &mut self.deadline {
            dl.observe(rtt);
        }
        self.buf_free.push(p.buf);
        self.inflight_gauge.add(-1.0);
        Ok(())
    }
}

/// Shared [`SequenceSink`] shipping completed sequences to the central
/// replay over one per-process ingest connection. Worker-local slabs
/// recycle through the attached [`SequencePool`] the moment their bytes
/// are on the wire, so the worker's sequence path stays allocation-free
/// exactly like the in-process one.
///
/// A broken link re-dials and re-handshakes once per failed frame
/// (`fleet.ingest_errors` + `fleet.client_reconnects`): sequences that
/// were in flight on the dead socket are dropped — the replay is a
/// distribution, not a ledger — and only an unrecoverable link signals
/// worker shutdown. Every sequence dropped this way is counted in
/// `fleet.ingest_lost_sequences`, so a run can attribute exactly how
/// much experience a dead link cost.
pub struct RemoteIngest {
    state: Mutex<IngestState>,
    pool: Arc<SequencePool>,
    shutdown: ShutdownToken,
    errors: Counter,
    lost: Counter,
}

struct IngestState {
    writer: Stream,
    buf: Vec<u8>,
    failed: bool,
    addr: Addr,
    hello: frame::Hello,
    opts: RemoteClientOpts,
    tx_frames: Counter,
    tx_bytes: Counter,
    reconnects: Counter,
}

impl RemoteIngest {
    pub fn connect(
        addr: &Addr,
        dims: ModelDims,
        opts: &RemoteClientOpts,
        metrics: &Registry,
        shutdown: ShutdownToken,
    ) -> anyhow::Result<Self> {
        let mut hello = hello_for(Role::Ingest, 0, &dims, 0);
        let (writer, _reader) = establish(addr, &mut hello, opts, &shutdown)?;
        Ok(Self {
            state: Mutex::new(IngestState {
                writer,
                buf: Vec::new(),
                failed: false,
                addr: addr.clone(),
                hello,
                opts: *opts,
                tx_frames: metrics.counter("fleet.tx_frames"),
                tx_bytes: metrics.counter("fleet.tx_bytes"),
                reconnects: metrics.counter("fleet.client_reconnects"),
            }),
            pool: Arc::new(SequencePool::new()),
            shutdown,
            errors: metrics.counter("fleet.ingest_errors"),
            lost: metrics.counter("fleet.ingest_lost_sequences"),
        })
    }

    /// Clean-drain marker: goodbye + half-close, so the server commits
    /// everything received and logs a clean departure.
    pub fn goodbye(&self) {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        if !st.failed {
            frame::encode_goodbye(&mut st.buf);
            let _ = st.writer.write_all(&st.buf);
        }
        st.writer.shutdown_write();
    }
}

impl SequenceSink for RemoteIngest {
    fn add_batch(&self, batch: &mut Vec<Sequence>) {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        for seq in batch.drain(..) {
            if !st.failed {
                frame::encode_sequence(&mut st.buf, &seq);
                let mut sent = st.writer.write_all(&st.buf).is_ok();
                if !sent && !self.shutdown.is_signalled() {
                    // The link died. Sequences already on the dead
                    // socket are lost — the replay is a distribution,
                    // losing a few is safe — but this frame is intact:
                    // reconnect (the handshake resyncs the generation
                    // fence) and resend it.
                    self.errors.inc();
                    if let Ok((w, _)) =
                        establish(&st.addr, &mut st.hello, &st.opts, &self.shutdown)
                    {
                        st.writer = w;
                        st.reconnects.inc();
                        sent = st.writer.write_all(&st.buf).is_ok();
                    }
                }
                if sent {
                    st.tx_frames.inc();
                    st.tx_bytes.add(st.buf.len() as u64);
                } else {
                    // A dead, unrecoverable ingest link makes further
                    // training pointless for this worker: flag it, stop
                    // writing, and wind the process down. The drain
                    // below still recycles every slab.
                    st.failed = true;
                    self.errors.inc();
                    self.lost.inc();
                    self.shutdown.signal();
                }
            } else {
                // Link already declared dead: the sequence is dropped
                // by design, but the loss is ledgered.
                self.lost.inc();
            }
            self.pool.put(seq);
        }
    }

    fn recycle_pool(&self) -> Option<Arc<SequencePool>> {
        Some(self.pool.clone())
    }
}
