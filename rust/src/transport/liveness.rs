//! Heartbeat and deadline state machines for the fleet (DESIGN.md §15).
//!
//! Three tiny pure structs — no I/O, no clocks of their own — so the
//! transport loops stay testable and the hot paths stay allocation-free
//! (gated in `micro_transport --quick` next to the codec gates):
//!
//! * [`Heartbeat`] decides when the client owes the server a `Ping`.
//! * [`Liveness`] is the server's per-connection staleness window: any
//!   completed frame (including `Ping`) refreshes it; when it lapses
//!   the connection is reaped and its in-flight tickets failed with
//!   attribution.
//! * [`DeadlineEwma`] seeds the client's per-ticket deadline from a
//!   smoothed round-trip estimate (`fleet.rtt_seconds`), floored by the
//!   configured liveness window so a cold estimate never fires early.
//!
//! Every method takes `now: Instant` explicitly; the unit tests drive
//! them with synthetic clocks.

use std::time::{Duration, Instant};

/// Exponentially-weighted RTT estimate that turns into a per-ticket
/// deadline: `max(floor, mult * ewma)`. Starts at the floor until the
/// first observation lands.
#[derive(Clone, Copy, Debug)]
pub struct DeadlineEwma {
    ewma_s: f64,
    floor_s: f64,
    mult: f64,
}

impl DeadlineEwma {
    /// `floor` is the configured liveness window (the deadline never
    /// undercuts it); `mult` scales the smoothed RTT into a deadline.
    pub fn new(floor: Duration, mult: f64) -> Self {
        DeadlineEwma {
            ewma_s: 0.0,
            floor_s: floor.as_secs_f64(),
            mult,
        }
    }

    /// Fold one completed round-trip into the estimate (0.9/0.1 blend,
    /// first sample adopted outright).
    pub fn observe(&mut self, rtt: Duration) {
        let s = rtt.as_secs_f64();
        self.ewma_s = if self.ewma_s == 0.0 {
            s
        } else {
            0.9 * self.ewma_s + 0.1 * s
        };
    }

    /// The deadline to arm for the next ticket.
    pub fn deadline(&self) -> Duration {
        Duration::from_secs_f64((self.mult * self.ewma_s).max(self.floor_s))
    }
}

/// Client-side ping scheduler: one `Ping` per quiet interval.
#[derive(Clone, Copy, Debug)]
pub struct Heartbeat {
    every: Duration,
    last_tx: Instant,
}

impl Heartbeat {
    pub fn new(every: Duration, now: Instant) -> Self {
        Heartbeat { every, last_tx: now }
    }

    /// When the next ping is owed (send at or after this instant).
    pub fn next_due(&self) -> Instant {
        self.last_tx + self.every
    }

    /// True when a ping is owed now; callers send and then [`Self::sent`].
    pub fn due(&self, now: Instant) -> bool {
        now >= self.next_due()
    }

    /// Record a transmitted ping (or any frame — traffic is proof of
    /// life, so a busy connection pings less).
    pub fn sent(&mut self, now: Instant) {
        self.last_tx = now;
    }
}

/// Server-side staleness window: reap the connection when no complete
/// frame has arrived for `window`.
#[derive(Clone, Copy, Debug)]
pub struct Liveness {
    window: Duration,
    last_rx: Instant,
}

impl Liveness {
    pub fn new(window: Duration, now: Instant) -> Self {
        Liveness { window, last_rx: now }
    }

    /// Record a completed inbound frame.
    pub fn touch(&mut self, now: Instant) {
        self.last_rx = now;
    }

    /// The instant at which the connection becomes reapable — feed this
    /// to `FrameReader::read_frame_until` as the wake deadline.
    pub fn deadline(&self) -> Instant {
        self.last_rx + self.window
    }

    /// True once the window has lapsed with no inbound frame.
    pub fn stale(&self, now: Instant) -> bool {
        now >= self.deadline()
    }

    /// How long the connection had been silent at `now` (for the
    /// attributed reap error).
    pub fn silent_for(&self, now: Instant) -> Duration {
        now.saturating_duration_since(self.last_rx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_starts_at_floor_and_tracks_rtt() {
        let mut d = DeadlineEwma::new(Duration::from_millis(100), 4.0);
        assert_eq!(d.deadline(), Duration::from_millis(100));
        d.observe(Duration::from_millis(50));
        // 4 * 50ms = 200ms beats the floor.
        assert_eq!(d.deadline(), Duration::from_millis(200));
        // A fast outlier can't drag the deadline under the floor.
        for _ in 0..200 {
            d.observe(Duration::from_millis(1));
        }
        assert_eq!(d.deadline(), Duration::from_millis(100));
    }

    #[test]
    fn ewma_blends_toward_new_observations() {
        let mut d = DeadlineEwma::new(Duration::ZERO, 1.0);
        d.observe(Duration::from_secs(1));
        d.observe(Duration::from_secs(2));
        let s = d.deadline().as_secs_f64();
        assert!((s - 1.1).abs() < 1e-9, "0.9*1 + 0.1*2 = 1.1, got {s}");
    }

    #[test]
    fn heartbeat_fires_once_per_quiet_interval() {
        let t0 = Instant::now();
        let mut hb = Heartbeat::new(Duration::from_millis(10), t0);
        assert!(!hb.due(t0));
        assert!(hb.due(t0 + Duration::from_millis(10)));
        hb.sent(t0 + Duration::from_millis(10));
        assert!(!hb.due(t0 + Duration::from_millis(15)));
        assert!(hb.due(t0 + Duration::from_millis(20)));
        assert_eq!(hb.next_due(), t0 + Duration::from_millis(20));
    }

    #[test]
    fn liveness_reaps_only_after_a_silent_window() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(Duration::from_millis(30), t0);
        assert!(!lv.stale(t0 + Duration::from_millis(29)));
        assert!(lv.stale(t0 + Duration::from_millis(30)));
        lv.touch(t0 + Duration::from_millis(25));
        assert!(!lv.stale(t0 + Duration::from_millis(54)));
        assert!(lv.stale(t0 + Duration::from_millis(55)));
        assert_eq!(
            lv.silent_for(t0 + Duration::from_millis(40)),
            Duration::from_millis(15)
        );
    }
}
