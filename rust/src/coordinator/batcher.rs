//! The central-inference batcher — the core of the SEED-RL dataflow.
//!
//! Actors submit single observations (+ their recurrent state) through a
//! channel; the batcher thread greedily coalesces them into batches of up
//! to `max_batch`, flushing a partial batch after `timeout_us` so tail
//! latency stays bounded when few actors are running. Each flushed batch
//! becomes one `Backend::infer` call (one padded AOT executable launch),
//! and the replies are routed back to the submitting actors.
//!
//! Policy trade-off (paper Fig. 3 territory): a larger max_batch raises
//! GPU efficiency; a longer timeout raises occupancy at low actor counts
//! but adds latency to every actor's step. `micro_batcher` benches the
//! policy surface.

use crate::config::BatcherConfig;
use crate::metrics::Registry;
use crate::runtime::{Backend, InferRequest};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One actor's inference submission.
pub struct InferItem {
    pub actor: usize,
    pub obs: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    pub reply: mpsc::Sender<ActorReply>,
}

/// Per-actor inference result.
#[derive(Clone, Debug)]
pub struct ActorReply {
    pub q: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// Handle used by actors to submit observations.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<InferItem>,
}

impl BatcherHandle {
    /// Blocking round-trip: submit and wait for the routed reply.
    pub fn infer(
        &self,
        actor: usize,
        obs: Vec<f32>,
        h: Vec<f32>,
        c: Vec<f32>,
    ) -> anyhow::Result<ActorReply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(InferItem {
                actor,
                obs,
                h,
                c,
                reply: rtx,
            })
            .map_err(|_| anyhow::anyhow!("batcher gone"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("batcher dropped reply"))
    }

    /// Submit `n` observation rows at once (a vecenv actor's whole slot
    /// batch), then block until all `n` routed replies arrive; replies
    /// come back in slot order. All rows enter the batcher back-to-back,
    /// so one multi-env actor fills a GPU batch the way `n` single-env
    /// actors would — without the n threads.
    ///
    /// `obs`, `h`, and `c` are `[n, obs_len]`, `[n, hidden]`,
    /// `[n, hidden]` row-major slabs.
    pub fn infer_many(
        &self,
        actor: usize,
        n: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<Vec<ActorReply>> {
        anyhow::ensure!(n > 0, "infer_many with no rows");
        anyhow::ensure!(
            obs.len() % n == 0 && h.len() % n == 0 && c.len() % n == 0,
            "row slabs must be divisible by n"
        );
        let obs_len = obs.len() / n;
        let hidden = h.len() / n;
        // Submit all rows before waiting on any reply: the rows must be
        // in the batcher's queue together to coalesce into one batch.
        let mut pending = Vec::with_capacity(n);
        for i in 0..n {
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .send(InferItem {
                    actor,
                    obs: obs[i * obs_len..(i + 1) * obs_len].to_vec(),
                    h: h[i * hidden..(i + 1) * hidden].to_vec(),
                    c: c[i * hidden..(i + 1) * hidden].to_vec(),
                    reply: rtx,
                })
                .map_err(|_| anyhow::anyhow!("batcher gone"))?;
            pending.push(rrx);
        }
        pending
            .into_iter()
            .map(|rrx| {
                rrx.recv()
                    .map_err(|_| anyhow::anyhow!("batcher dropped reply"))
            })
            .collect()
    }
}

/// The batcher thread. Exits when every `BatcherHandle` is dropped.
pub struct Batcher {
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn spawn(
        cfg: BatcherConfig,
        backend: Backend,
        metrics: Registry,
    ) -> (Batcher, BatcherHandle) {
        let (tx, rx) = mpsc::channel::<InferItem>();
        let join = std::thread::Builder::new()
            .name("rlarch-batcher".into())
            .spawn(move || run_batcher(cfg, backend, metrics, rx))
            .expect("spawn batcher");
        (Batcher { join: Some(join) }, BatcherHandle { tx })
    }

    /// Wait for the batcher thread to exit (after all handles drop).
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn run_batcher(
    cfg: BatcherConfig,
    backend: Backend,
    metrics: Registry,
    rx: mpsc::Receiver<InferItem>,
) {
    let dims = backend.dims();
    let timeout = Duration::from_micros(cfg.timeout_us);
    let batches = metrics.counter("batcher.batches");
    let items = metrics.counter("batcher.items");
    let flush_timeout = metrics.counter("batcher.flush_timeout");
    let flush_full = metrics.counter("batcher.flush_full");
    let occupancy = metrics.gauge("batcher.last_batch_size");
    let infer_time = metrics.timer("batcher.infer_seconds");
    let wait_time = metrics.timer("batcher.collect_seconds");

    loop {
        // Block for the first item of the next batch.
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => return, // all handles dropped
        };
        let t_collect = Instant::now();
        let mut pending = vec![first];
        let deadline = t_collect + timeout;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                flush_timeout.inc();
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => pending.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    flush_timeout.inc();
                    break;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if pending.len() == cfg.max_batch {
            flush_full.inc();
        }
        wait_time.record(t_collect.elapsed().as_secs_f64());

        // Assemble the batched request.
        let n = pending.len();
        let mut req = InferRequest {
            n,
            h: Vec::with_capacity(n * dims.hidden),
            c: Vec::with_capacity(n * dims.hidden),
            obs: Vec::with_capacity(n * dims.obs_len),
        };
        for item in &pending {
            req.h.extend_from_slice(&item.h);
            req.c.extend_from_slice(&item.c);
            req.obs.extend_from_slice(&item.obs);
        }

        let reply = infer_time.time(|| backend.infer(req));
        batches.inc();
        items.add(n as u64);
        occupancy.set(n as f64);

        match reply {
            Ok(out) => {
                for (i, item) in pending.into_iter().enumerate() {
                    let a = dims.num_actions;
                    let h = dims.hidden;
                    let _ = item.reply.send(ActorReply {
                        q: out.q[i * a..(i + 1) * a].to_vec(),
                        h: out.h[i * h..(i + 1) * h].to_vec(),
                        c: out.c[i * h..(i + 1) * h].to_vec(),
                    });
                }
            }
            Err(e) => {
                // Inference failure: drop the replies; actors see a closed
                // channel and shut down. Report once per batch.
                eprintln!("batcher inference failed: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockModel, ModelDims};
    use std::sync::Arc;

    fn mock_backend() -> (Backend, ModelDims) {
        let dims = ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        };
        (Backend::Mock(Arc::new(MockModel::new(dims, 1))), dims)
    }

    fn cfg(max_batch: usize, timeout_us: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            timeout_us,
            batch_sizes: vec![max_batch],
        }
    }

    #[test]
    fn single_item_flushes_on_timeout() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(8, 500), backend, m.clone());
        let out = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap();
        assert_eq!(out.q.len(), 3);
        drop(handle);
        batcher.join();
        assert_eq!(m.counter("batcher.batches").get(), 1);
        assert_eq!(m.counter("batcher.items").get(), 1);
        assert!(m.counter("batcher.flush_timeout").get() >= 1);
    }

    #[test]
    fn concurrent_actors_get_their_own_rows() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(16, 2_000), backend.clone(), m.clone());
        let results: Vec<(usize, ActorReply)> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for a in 0..12usize {
                let h = handle.clone();
                handles.push(s.spawn(move || {
                    let fill = a as f32 / 12.0;
                    let out = h
                        .infer(a, vec![fill; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                    (a, out)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Each actor's reply must equal a direct single-row mock call.
        for (a, out) in results {
            let fill = a as f32 / 12.0;
            let direct = backend
                .infer(crate::runtime::InferRequest {
                    n: 1,
                    h: vec![0.0; 4],
                    c: vec![0.0; 4],
                    obs: vec![fill; dims.obs_len],
                })
                .unwrap();
            assert_eq!(out.q, direct.q, "actor {a} got someone else's row");
        }
        drop(handle);
        batcher.join();
        // Batching really happened (fewer batches than items).
        assert!(m.counter("batcher.batches").get() < 12);
        assert_eq!(m.counter("batcher.items").get(), 12);
    }

    #[test]
    fn infer_many_routes_rows_in_slot_order_and_coalesces() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) =
            Batcher::spawn(cfg(8, 2_000), backend.clone(), m.clone());
        let n = 5;
        let mut obs = vec![0.0f32; n * dims.obs_len];
        for i in 0..n {
            obs[i * dims.obs_len..(i + 1) * dims.obs_len]
                .fill(i as f32 / n as f32);
        }
        let h = vec![0.0f32; n * dims.hidden];
        let c = vec![0.0f32; n * dims.hidden];
        let replies = handle.infer_many(0, n, &obs, &h, &c).unwrap();
        assert_eq!(replies.len(), n);
        for (i, r) in replies.iter().enumerate() {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; dims.hidden],
                    c: vec![0.0; dims.hidden],
                    obs: vec![i as f32 / n as f32; dims.obs_len],
                })
                .unwrap();
            assert_eq!(r.q, direct.q, "row {i} misrouted");
        }
        drop(handle);
        batcher.join();
        // All 5 rows entered together: they coalesce into 1-2 batches
        // instead of 5 singleton calls.
        assert_eq!(m.counter("batcher.items").get(), 5);
        assert!(m.counter("batcher.batches").get() <= 2);
    }

    #[test]
    fn never_exceeds_max_batch() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 50_000), backend, m.clone());
        std::thread::scope(|s| {
            for a in 0..16usize {
                let h = handle.clone();
                s.spawn(move || {
                    h.infer(a, vec![0.1; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                });
            }
        });
        drop(handle);
        batcher.join();
        // 16 items / cap 4 => at least 4 batches, all full-or-smaller.
        assert!(m.counter("batcher.batches").get() >= 4);
        assert_eq!(m.counter("batcher.items").get(), 16);
        assert!(m.gauge("batcher.last_batch_size").get() <= 4.0);
    }
}
