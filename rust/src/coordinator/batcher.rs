//! The central-inference batcher — the core of the SEED-RL dataflow.
//!
//! Actors submit observation slabs (+ their recurrent state) through a
//! channel; the batcher thread greedily coalesces pending rows into
//! batches of up to `max_batch`, flushing a partial batch after
//! `timeout_us` so tail latency stays bounded when few actors are
//! running. Each flushed batch becomes one `Backend::infer` call (one
//! padded AOT executable launch), and the reply rows are routed back to
//! the submitting actors.
//!
//! Protocol (since the policy layer, DESIGN.md §5): a vecenv actor's E
//! rows travel as **one multi-row [`InferItem`] carrying contiguous
//! slabs**, with a single reply channel per submission. The batcher may
//! split a submission across several flushed batches (it never exceeds
//! `max_batch` rows per GPU call); each batch sends one [`ReplyChunk`]
//! back with `slot0`-addressed rows, and the submitter's `wait` scatters
//! them into its `[E, hidden]` slabs. Inference failures are surfaced as
//! error chunks plus a `batcher.errors` counter — never a silent drop.
//!
//! Policy trade-off (paper Fig. 3 territory): a larger max_batch raises
//! GPU efficiency; a longer timeout raises occupancy at low actor counts
//! but adds latency to every actor's step. `micro_batcher` benches the
//! policy surface.

use crate::config::BatcherConfig;
use crate::metrics::Registry;
use crate::runtime::{Backend, InferRequest};
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One actor submission: `rows` observation/recurrent-state rows
/// travelling together as contiguous row-major slabs. Replies arrive on
/// `reply` as one or more [`ReplyChunk`]s (several when the rows span
/// more than one flushed batch).
pub struct InferItem {
    pub actor: usize,
    pub rows: usize,
    /// `[rows * obs_len]` row-major observation slab.
    pub obs: Vec<f32>,
    /// `[rows * hidden]` recurrent-state slabs.
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    pub reply: mpsc::Sender<ReplyChunk>,
}

/// A contiguous run of reply rows routed back to one submission.
pub struct ReplyChunk {
    /// First row (slot) of the submission this chunk covers.
    pub slot0: usize,
    pub rows: usize,
    /// Row-major `[rows * A]` / `[rows * H]` slabs, or the inference
    /// error message.
    pub result: Result<ChunkData, String>,
}

/// Payload of a successful reply chunk.
pub struct ChunkData {
    pub q: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// Per-actor single-row inference result (convenience API / tests).
#[derive(Clone, Debug)]
pub struct ActorReply {
    pub q: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// Handle used by actors to submit observation slabs.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<InferItem>,
    first_error: Arc<Mutex<Option<String>>>,
}

impl BatcherHandle {
    /// Queue a multi-row submission. Replies arrive on `item.reply`.
    pub fn submit(&self, item: InferItem) -> anyhow::Result<()> {
        anyhow::ensure!(item.rows > 0, "submission with no rows");
        anyhow::ensure!(
            item.obs.len() % item.rows == 0
                && item.h.len() % item.rows == 0
                && item.c.len() % item.rows == 0,
            "submission slabs must be divisible by rows"
        );
        self.tx
            .send(item)
            .map_err(|_| anyhow::anyhow!("{}", self.gone_message()))
    }

    /// First inference failure the batcher recorded, if any.
    pub fn first_error(&self) -> Option<String> {
        self.first_error.lock().unwrap().clone()
    }

    /// Descriptive shutdown message: names the inference failure when
    /// the batcher died of one, instead of a bare "batcher gone".
    pub fn gone_message(&self) -> String {
        match self.first_error() {
            Some(e) => format!("batcher gone after inference failure: {e}"),
            None => "batcher gone".into(),
        }
    }

    /// Blocking single-row round-trip: submit and wait for the routed
    /// reply (tests / micro-benches; actors use the policy layer).
    pub fn infer(
        &self,
        actor: usize,
        obs: Vec<f32>,
        h: Vec<f32>,
        c: Vec<f32>,
    ) -> anyhow::Result<ActorReply> {
        let (rtx, rrx) = mpsc::channel();
        self.submit(InferItem {
            actor,
            rows: 1,
            obs,
            h,
            c,
            reply: rtx,
        })?;
        let chunk = rrx
            .recv()
            .map_err(|_| anyhow::anyhow!("{}", self.gone_message()))?;
        match chunk.result {
            Ok(d) => Ok(ActorReply {
                q: d.q,
                h: d.h,
                c: d.c,
            }),
            Err(e) => Err(anyhow::anyhow!("batcher inference failed: {e}")),
        }
    }
}

/// The batcher thread. Exits when every `BatcherHandle` is dropped, or
/// after a backend inference failure (recorded in `first_error`).
pub struct Batcher {
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn spawn(
        cfg: BatcherConfig,
        backend: Backend,
        metrics: Registry,
    ) -> (Batcher, BatcherHandle) {
        let (tx, rx) = mpsc::channel::<InferItem>();
        let first_error = Arc::new(Mutex::new(None));
        let cell = first_error.clone();
        let join = std::thread::Builder::new()
            .name("rlarch-batcher".into())
            .spawn(move || run_batcher(cfg, backend, metrics, rx, cell))
            .expect("spawn batcher");
        (
            Batcher { join: Some(join) },
            BatcherHandle { tx, first_error },
        )
    }

    /// Wait for the batcher thread to exit (after all handles drop).
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// A queued submission with a cursor over its already-batched rows.
struct Open {
    item: InferItem,
    consumed: usize,
}

fn run_batcher(
    cfg: BatcherConfig,
    backend: Backend,
    metrics: Registry,
    rx: mpsc::Receiver<InferItem>,
    first_error: Arc<Mutex<Option<String>>>,
) {
    let dims = backend.dims();
    let timeout = Duration::from_micros(cfg.timeout_us);
    let batches = metrics.counter("batcher.batches");
    let items = metrics.counter("batcher.items");
    let errors = metrics.counter("batcher.errors");
    let flush_timeout = metrics.counter("batcher.flush_timeout");
    let flush_full = metrics.counter("batcher.flush_full");
    let occupancy = metrics.gauge("batcher.last_batch_size");
    let infer_time = metrics.timer("batcher.infer_seconds");
    let wait_time = metrics.timer("batcher.collect_seconds");

    let mut queue: VecDeque<Open> = VecDeque::new();
    let mut rows_avail = 0usize;

    // Accept a submission into the queue; malformed slabs are refused
    // with an error chunk instead of poisoning the batch assembly.
    let push = |queue: &mut VecDeque<Open>, rows_avail: &mut usize, item: InferItem| {
        let ok = item.rows > 0
            && item.obs.len() == item.rows * dims.obs_len
            && item.h.len() == item.rows * dims.hidden
            && item.c.len() == item.rows * dims.hidden;
        if !ok {
            let _ = item.reply.send(ReplyChunk {
                slot0: 0,
                rows: item.rows,
                result: Err(format!(
                    "malformed submission from actor {}: {} rows, obs {}, h {}, c {}",
                    item.actor,
                    item.rows,
                    item.obs.len(),
                    item.h.len(),
                    item.c.len()
                )),
            });
            return;
        }
        *rows_avail += item.rows;
        queue.push_back(Open { item, consumed: 0 });
    };

    loop {
        // Block for the first rows of the next batch (leftover rows of
        // an oversized submission flow straight into the next one).
        if rows_avail == 0 {
            match rx.recv() {
                Ok(item) => push(&mut queue, &mut rows_avail, item),
                Err(_) => return, // all handles dropped
            }
            if rows_avail == 0 {
                continue; // the submission was malformed
            }
        }
        let t_collect = Instant::now();
        let deadline = t_collect + timeout;
        while rows_avail < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                flush_timeout.inc();
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => push(&mut queue, &mut rows_avail, item),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    flush_timeout.inc();
                    break;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if rows_avail >= cfg.max_batch {
            flush_full.inc();
        }
        wait_time.record(t_collect.elapsed().as_secs_f64());

        // Assemble up to max_batch rows off the queue front, consuming
        // submissions partially where needed (rows > max_batch split
        // across consecutive full batches, in slot order).
        let n = rows_avail.min(cfg.max_batch);
        let mut req = InferRequest {
            n,
            h: Vec::with_capacity(n * dims.hidden),
            c: Vec::with_capacity(n * dims.hidden),
            obs: Vec::with_capacity(n * dims.obs_len),
        };
        // (reply sender, slot0 within the submission, rows in this batch)
        let mut routes: Vec<(mpsc::Sender<ReplyChunk>, usize, usize)> = Vec::new();
        let mut taken = 0usize;
        while taken < n {
            let open = queue.front_mut().expect("rows_avail tracks queue rows");
            let k = (open.item.rows - open.consumed).min(n - taken);
            let (a, b) = (open.consumed, open.consumed + k);
            req.h.extend_from_slice(&open.item.h[a * dims.hidden..b * dims.hidden]);
            req.c.extend_from_slice(&open.item.c[a * dims.hidden..b * dims.hidden]);
            req.obs
                .extend_from_slice(&open.item.obs[a * dims.obs_len..b * dims.obs_len]);
            routes.push((open.item.reply.clone(), open.consumed, k));
            open.consumed += k;
            taken += k;
            if open.consumed == open.item.rows {
                queue.pop_front();
            }
        }
        rows_avail -= n;

        let reply = infer_time.time(|| backend.infer(req));
        batches.inc();
        items.add(n as u64);
        occupancy.set(n as f64);

        match reply {
            Ok(out) => {
                let a = dims.num_actions;
                let hd = dims.hidden;
                let mut off = 0usize;
                for (tx, slot0, k) in routes {
                    let _ = tx.send(ReplyChunk {
                        slot0,
                        rows: k,
                        result: Ok(ChunkData {
                            q: out.q[off * a..(off + k) * a].to_vec(),
                            h: out.h[off * hd..(off + k) * hd].to_vec(),
                            c: out.c[off * hd..(off + k) * hd].to_vec(),
                        }),
                    });
                    off += k;
                }
            }
            Err(e) => {
                // Inference failure: fail this batch's submissions and
                // everything still queued with the message, record it,
                // and exit — waiters see the error, later submitters see
                // a descriptive `gone_message`.
                errors.inc();
                let msg = e.to_string();
                let mut cell = first_error.lock().unwrap();
                if cell.is_none() {
                    *cell = Some(msg.clone());
                }
                drop(cell);
                for (tx, slot0, k) in routes {
                    let _ = tx.send(ReplyChunk {
                        slot0,
                        rows: k,
                        result: Err(msg.clone()),
                    });
                }
                for open in queue.drain(..) {
                    let _ = open.item.reply.send(ReplyChunk {
                        slot0: open.consumed,
                        rows: open.item.rows - open.consumed,
                        result: Err(msg.clone()),
                    });
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockModel, ModelDims};
    use std::sync::Arc;

    fn mock_backend() -> (Backend, ModelDims) {
        let dims = ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        };
        (Backend::Mock(Arc::new(MockModel::new(dims, 1))), dims)
    }

    fn cfg(max_batch: usize, timeout_us: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            timeout_us,
            batch_sizes: vec![max_batch],
        }
    }

    /// Submit a multi-row slab and gather all reply chunks into
    /// slot-ordered row slabs.
    fn submit_and_gather(
        handle: &BatcherHandle,
        dims: &ModelDims,
        rows: usize,
        obs: Vec<f32>,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, usize) {
        let (rtx, rrx) = mpsc::channel();
        handle
            .submit(InferItem {
                actor: 0,
                rows,
                obs,
                h: vec![0.0; rows * dims.hidden],
                c: vec![0.0; rows * dims.hidden],
                reply: rtx,
            })
            .unwrap();
        let mut q = vec![0.0f32; rows * dims.num_actions];
        let mut h = vec![0.0f32; rows * dims.hidden];
        let mut c = vec![0.0f32; rows * dims.hidden];
        let mut done = 0usize;
        let mut chunks = 0usize;
        while done < rows {
            let chunk = rrx.recv().expect("reply chunk");
            let d = chunk.result.expect("inference ok");
            let (s, k) = (chunk.slot0, chunk.rows);
            q[s * dims.num_actions..(s + k) * dims.num_actions].copy_from_slice(&d.q);
            h[s * dims.hidden..(s + k) * dims.hidden].copy_from_slice(&d.h);
            c[s * dims.hidden..(s + k) * dims.hidden].copy_from_slice(&d.c);
            done += k;
            chunks += 1;
        }
        (q, h, c, chunks)
    }

    #[test]
    fn single_item_flushes_on_timeout() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(8, 500), backend, m.clone());
        let out = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap();
        assert_eq!(out.q.len(), 3);
        drop(handle);
        batcher.join();
        assert_eq!(m.counter("batcher.batches").get(), 1);
        assert_eq!(m.counter("batcher.items").get(), 1);
        assert!(m.counter("batcher.flush_timeout").get() >= 1);
    }

    #[test]
    fn concurrent_actors_get_their_own_rows() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(16, 2_000), backend.clone(), m.clone());
        let results: Vec<(usize, ActorReply)> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for a in 0..12usize {
                let h = handle.clone();
                handles.push(s.spawn(move || {
                    let fill = a as f32 / 12.0;
                    let out = h
                        .infer(a, vec![fill; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                    (a, out)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Each actor's reply must equal a direct single-row mock call.
        for (a, out) in results {
            let fill = a as f32 / 12.0;
            let direct = backend
                .infer(crate::runtime::InferRequest {
                    n: 1,
                    h: vec![0.0; 4],
                    c: vec![0.0; 4],
                    obs: vec![fill; dims.obs_len],
                })
                .unwrap();
            assert_eq!(out.q, direct.q, "actor {a} got someone else's row");
        }
        drop(handle);
        batcher.join();
        // Batching really happened (fewer batches than rows).
        assert!(m.counter("batcher.batches").get() < 12);
        assert_eq!(m.counter("batcher.items").get(), 12);
    }

    #[test]
    fn multi_row_submission_routes_rows_in_slot_order_as_one_batch() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) =
            Batcher::spawn(cfg(8, 2_000), backend.clone(), m.clone());
        let n = 5;
        let mut obs = vec![0.0f32; n * dims.obs_len];
        for i in 0..n {
            obs[i * dims.obs_len..(i + 1) * dims.obs_len].fill(i as f32 / n as f32);
        }
        let (q, _, _, chunks) = submit_and_gather(&handle, &dims, n, obs);
        for i in 0..n {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; dims.hidden],
                    c: vec![0.0; dims.hidden],
                    obs: vec![i as f32 / n as f32; dims.obs_len],
                })
                .unwrap();
            assert_eq!(
                q[i * dims.num_actions..(i + 1) * dims.num_actions],
                direct.q[..],
                "row {i} misrouted"
            );
        }
        drop(handle);
        batcher.join();
        // All 5 rows entered together: one multi-row item, one batch,
        // one reply chunk — not 5 singleton calls.
        assert_eq!(chunks, 1);
        assert_eq!(m.counter("batcher.items").get(), 5);
        assert_eq!(m.counter("batcher.batches").get(), 1);
    }

    #[test]
    fn oversized_submission_splits_across_full_batches_in_slot_order() {
        // rows = 10 > max_batch = 4: must be served as 4 + 4 + 2, never
        // exceeding the cap, with every row routed back in slot order.
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 500), backend.clone(), m.clone());
        let n = 10;
        let mut obs = vec![0.0f32; n * dims.obs_len];
        for i in 0..n {
            obs[i * dims.obs_len..(i + 1) * dims.obs_len].fill(i as f32 / n as f32);
        }
        let (q, _, _, chunks) = submit_and_gather(&handle, &dims, n, obs);
        for i in 0..n {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; dims.hidden],
                    c: vec![0.0; dims.hidden],
                    obs: vec![i as f32 / n as f32; dims.obs_len],
                })
                .unwrap();
            assert_eq!(
                q[i * dims.num_actions..(i + 1) * dims.num_actions],
                direct.q[..],
                "row {i} misrouted"
            );
        }
        drop(handle);
        batcher.join();
        assert_eq!(chunks, 3, "10 rows at cap 4 => 3 chunks");
        assert_eq!(m.counter("batcher.items").get(), 10);
        assert_eq!(m.counter("batcher.batches").get(), 3);
        assert_eq!(m.counter("batcher.flush_full").get(), 2);
        assert!(m.gauge("batcher.last_batch_size").get() <= 4.0);
    }

    #[test]
    fn never_exceeds_max_batch() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 50_000), backend, m.clone());
        std::thread::scope(|s| {
            for a in 0..16usize {
                let h = handle.clone();
                s.spawn(move || {
                    h.infer(a, vec![0.1; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                });
            }
        });
        drop(handle);
        batcher.join();
        // 16 rows / cap 4 => at least 4 batches, all full-or-smaller.
        assert!(m.counter("batcher.batches").get() >= 4);
        assert_eq!(m.counter("batcher.items").get(), 16);
        assert!(m.gauge("batcher.last_batch_size").get() <= 4.0);
    }

    #[test]
    fn inference_failure_surfaces_as_error_chunks_and_counter() {
        let dims = ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        };
        let backend = Backend::Mock(Arc::new(
            MockModel::new(dims, 1).with_infer_error("injected GPU fault"),
        ));
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(8, 200), backend, m.clone());
        let err = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("injected GPU fault"), "got: {err}");
        assert_eq!(m.counter("batcher.errors").get(), 1);
        assert_eq!(
            handle.first_error().as_deref(),
            Some("injected GPU fault")
        );
        // The batcher thread exited; later submissions fail with a
        // descriptive message, not a bare "batcher gone".
        batcher.join();
        let err = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("injected GPU fault"), "got: {err}");
    }
}
