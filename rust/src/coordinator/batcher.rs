//! The central-inference batcher — the core of the SEED-RL dataflow.
//!
//! Actors submit observation slabs (+ their recurrent state) through a
//! channel; the batcher thread greedily coalesces pending rows into
//! batches of up to `max_batch`, flushing a partial batch after
//! `timeout_us` so tail latency stays bounded when few actors are
//! running. Each flushed batch becomes one backend launch, and the
//! reply rows are routed back to the submitting actors.
//!
//! Protocol (the pooled slab protocol, DESIGN.md §5): a vecenv actor's
//! E rows travel as one multi-row [`InferItem`] whose payload is a
//! recycled [`InferSlab`] drawn from the handle's shared [`SlabPool`]
//! (fed back by the batcher once the rows are copied into the assembly
//! request). Replies ride a **persistent per-client mailbox** — no
//! fresh channel per step — as [`ReplyChunk`]s that address a row range
//! ([`ReplyRange`]) inside an `Arc`-shared output slab the batcher
//! recycles once every chunk holder has scattered and dropped it. The
//! batcher may split a submission across several flushed batches (it
//! never exceeds `max_batch` rows per launch); chunks are `slot0`-
//! addressed and `ticket`-tagged so one mailbox serves several
//! in-flight submissions. In steady state the whole round-trip touches
//! the allocator zero times (hard-asserted by `micro_batcher --quick`'s
//! counting global allocator). Inference failures are surfaced as error
//! chunks plus a `batcher.errors` counter — never a silent drop.
//!
//! Launch shapes model fixed-shape AOT executables: a flush of `n` rows
//! is zero-padded up to the smallest configured `batcher.batch_sizes`
//! bucket `>= n` (the padded rows are computed and discarded, so the
//! reply stream is byte-identical to exact-shape launches — pinned by
//! `tests/batcher_equivalence.rs`). `batch_sizes = [max_batch]` pads
//! every partial flush to the cap; a denser ladder trades more compiled
//! executables for less padding waste. `batcher.padded_rows` counts the
//! waste; `batcher.last_launch_size` is the padded shape.
//!
//! Policy trade-off (paper Fig. 3 territory): a larger max_batch raises
//! GPU efficiency; a longer timeout raises occupancy at low actor counts
//! but adds latency to every actor's step; a denser bucket ladder cuts
//! padding waste. `micro_batcher` benches the policy surface.

use crate::config::BatcherConfig;
use crate::exec::channel::{channel_counted, mailbox, Receiver, RecvTimeoutError, Sender};
use crate::metrics::Registry;
use crate::runtime::{Backend, InferReply, InferRequest, InferSlices, ModelDims};
use crate::telemetry::SpanKind;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Contiguous row-major input slabs for one submission, recycled
/// through the [`SlabPool`]: `[rows * obs_len]` observations plus
/// `[rows * hidden]` recurrent state.
#[derive(Default)]
pub struct InferSlab {
    pub obs: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

impl InferSlab {
    fn clear(&mut self) {
        self.obs.clear();
        self.h.clear();
        self.c.clear();
    }

    /// Refill from borrowed rows, reusing the slab's capacity (the
    /// policy client's copy into the submission — the only copy the
    /// input side of the round-trip makes).
    pub fn fill_from(&mut self, obs: &[f32], h: &[f32], c: &[f32]) {
        self.clear();
        self.obs.extend_from_slice(obs);
        self.h.extend_from_slice(h);
        self.c.extend_from_slice(c);
    }
}

/// Free list of recycled input slabs, shared between every policy
/// client and the batcher thread (which feeds slabs back once their
/// rows are copied into the assembly request). Capacities settle at
/// the largest submission each slab has carried, after which the
/// acquire/release cycle never allocates.
#[derive(Default)]
pub struct SlabPool {
    free: Mutex<Vec<InferSlab>>,
}

impl SlabPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a recycled slab (or a fresh empty one while warming up).
    pub fn acquire(&self) -> InferSlab {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Feed a slab back for reuse.
    pub fn release(&self, mut slab: InferSlab) {
        slab.clear();
        self.free.lock().unwrap().push(slab);
    }

    /// Slabs currently parked in the free list (tests/observability).
    pub fn free_count(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// One actor submission: `rows` rows travelling as one recycled
/// [`InferSlab`]. Replies arrive on the submitter's mailbox as one or
/// more [`ReplyChunk`]s (several when the rows span more than one
/// flushed batch), each echoing `ticket`.
pub struct InferItem {
    pub actor: usize,
    /// Caller-chosen demux tag echoed on every reply chunk, letting one
    /// persistent mailbox serve several in-flight submissions. The
    /// policy client uses a monotone per-submission counter so chunks
    /// from a returned (e.g. errored-out) generation can never be
    /// mistaken for a live one.
    pub ticket: usize,
    pub rows: usize,
    pub slab: InferSlab,
    /// Minted from the submitter's persistent mailbox
    /// ([`crate::exec::channel::Receiver::sender`]); the mailbox reads
    /// as disconnected only when no submission holds a route to it.
    pub reply: Sender<ReplyChunk>,
}

/// A contiguous run of reply rows routed back to one submission.
pub struct ReplyChunk {
    /// The submission's demux tag, echoed back.
    pub ticket: usize,
    /// First row (slot) of the submission this chunk covers.
    pub slot0: usize,
    pub rows: usize,
    /// A row range in the batch's shared output slab, or the inference
    /// error message.
    pub result: Result<ReplyRange, String>,
}

/// `rows` reply rows starting at row `row0` of a shared output slab.
/// Holding the `Arc` keeps the slab pinned; the batcher reuses it once
/// every chunk holder has scattered and dropped its clone.
pub struct ReplyRange {
    pub slab: Arc<InferReply>,
    pub row0: usize,
}

/// Per-actor single-row inference result (convenience API / tests).
#[derive(Clone, Debug)]
pub struct ActorReply {
    pub q: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// Handle used by actors to submit observation slabs.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<InferItem>,
    dims: ModelDims,
    pool: Arc<SlabPool>,
    first_error: Arc<Mutex<Option<String>>>,
}

impl BatcherHandle {
    /// Model dimensions submissions are validated against.
    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    /// The shared input-slab pool (policy clients draw submission slabs
    /// from it; the batcher feeds them back).
    pub fn slab_pool(&self) -> Arc<SlabPool> {
        self.pool.clone()
    }

    /// Queue a multi-row submission. Replies arrive on the mailbox
    /// `item.reply` was minted from.
    ///
    /// Exact-dims validation happens here — once, at the call site, so
    /// a malformed slab fails the submitting actor immediately with its
    /// id in the message (the batcher loop itself trusts the queue).
    pub fn submit(&self, item: InferItem) -> anyhow::Result<()> {
        let d = &self.dims;
        let ok = item.rows > 0
            && item.slab.obs.len() == item.rows * d.obs_len
            && item.slab.h.len() == item.rows * d.hidden
            && item.slab.c.len() == item.rows * d.hidden;
        if !ok {
            let msg = format!(
                "malformed submission from actor {}: {} rows, obs {}, h {}, c {} \
                 (model wants obs {}/row, hidden {}/row)",
                item.actor,
                item.rows,
                item.slab.obs.len(),
                item.slab.h.len(),
                item.slab.c.len(),
                d.obs_len,
                d.hidden
            );
            self.pool.release(item.slab);
            anyhow::bail!(msg);
        }
        self.tx.send(item).map_err(|item| {
            // Recycle the slab even on a dead batcher so the pool's
            // steady state survives shutdown races.
            self.pool.release(item.slab);
            anyhow::anyhow!("{}", self.gone_message())
        })
    }

    /// First inference failure the batcher recorded, if any.
    pub fn first_error(&self) -> Option<String> {
        self.first_error.lock().unwrap().clone()
    }

    /// Descriptive shutdown message: names the inference failure when
    /// the batcher died of one, instead of a bare "batcher gone".
    pub fn gone_message(&self) -> String {
        match self.first_error() {
            Some(e) => format!("batcher gone after inference failure: {e}"),
            None => "batcher gone".into(),
        }
    }

    /// Blocking single-row round-trip: submit and wait for the routed
    /// reply (tests / micro-benches; actors use the policy layer, which
    /// holds a persistent mailbox — this convenience path allocates a
    /// fresh one per call).
    pub fn infer(
        &self,
        actor: usize,
        obs: Vec<f32>,
        h: Vec<f32>,
        c: Vec<f32>,
    ) -> anyhow::Result<ActorReply> {
        let mb = mailbox::<ReplyChunk>(2);
        let mut slab = self.pool.acquire();
        slab.fill_from(&obs, &h, &c);
        self.submit(InferItem {
            actor,
            ticket: 0,
            rows: 1,
            slab,
            reply: mb.sender(),
        })?;
        let chunk = mb
            .recv()
            .ok_or_else(|| anyhow::anyhow!("{}", self.gone_message()))?;
        match chunk.result {
            Ok(r) => {
                let d = &self.dims;
                let (a, hd, r0) = (d.num_actions, d.hidden, r.row0);
                Ok(ActorReply {
                    q: r.slab.q[r0 * a..(r0 + 1) * a].to_vec(),
                    h: r.slab.h[r0 * hd..(r0 + 1) * hd].to_vec(),
                    c: r.slab.c[r0 * hd..(r0 + 1) * hd].to_vec(),
                })
            }
            Err(e) => Err(anyhow::anyhow!("batcher inference failed: {e}")),
        }
    }
}

/// The batcher thread. Exits when every `BatcherHandle` is dropped, or
/// after a backend inference failure (recorded in `first_error`).
pub struct Batcher {
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn spawn(
        cfg: BatcherConfig,
        backend: Backend,
        metrics: Registry,
    ) -> (Batcher, BatcherHandle) {
        // The input queue carries the doorbell counter. With doorbell
        // batching in the channel (notify only when the batcher is
        // parked), `batcher.queue_wakeups` counts notifies actually
        // issued: a submission burst against a busy batcher is free,
        // where the PR 6 baseline paid one wakeup per submission.
        let (tx, rx) = channel_counted::<InferItem>(
            256,
            metrics.counter("batcher.queue_wakeups"),
        );
        let dims = backend.dims();
        let pool = Arc::new(SlabPool::new());
        let first_error = Arc::new(Mutex::new(None));
        let cell = first_error.clone();
        let loop_pool = pool.clone();
        let join = std::thread::Builder::new()
            .name("rlarch-batcher".into())
            .spawn(move || run_batcher(cfg, backend, metrics, rx, loop_pool, cell))
            .expect("spawn batcher");
        (
            Batcher { join: Some(join) },
            BatcherHandle {
                tx,
                dims,
                pool,
                first_error,
            },
        )
    }

    /// Wait for the batcher thread to exit (after all handles drop).
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// A queued submission with a cursor over its already-batched rows.
struct Open {
    item: InferItem,
    consumed: usize,
}

/// One reply route of the in-flight batch: `rows` rows going back to a
/// submission, starting at its slot `slot0`.
struct Route {
    reply: Sender<ReplyChunk>,
    ticket: usize,
    slot0: usize,
    rows: usize,
}

fn run_batcher(
    cfg: BatcherConfig,
    backend: Backend,
    metrics: Registry,
    rx: Receiver<InferItem>,
    pool: Arc<SlabPool>,
    first_error: Arc<Mutex<Option<String>>>,
) {
    let dims = backend.dims();
    let (ol, hd) = (dims.obs_len, dims.hidden);
    let timeout = Duration::from_micros(cfg.timeout_us);
    let batches = metrics.counter("batcher.batches");
    let items = metrics.counter("batcher.items");
    let errors = metrics.counter("batcher.errors");
    let flush_timeout = metrics.counter("batcher.flush_timeout");
    let flush_full = metrics.counter("batcher.flush_full");
    let padded_rows = metrics.counter("batcher.padded_rows");
    let occupancy = metrics.gauge("batcher.last_batch_size");
    let launch_size = metrics.gauge("batcher.last_launch_size");
    let infer_time = metrics.timer("batcher.infer_seconds");
    let wait_time = metrics.timer("batcher.collect_seconds");
    let trace = metrics.span_recorder(format_args!("batcher"));

    let mut queue: VecDeque<Open> = VecDeque::new();
    let mut rows_avail = 0usize;
    // Recycled assembly state: the request the batch is gathered into,
    // the reply routing table, and the shared output slabs (an output
    // slab is free again once its `Arc` is unique — every chunk holder
    // scattered and dropped it). All of it reaches a fixed capacity
    // after warmup; the steady-state loop never allocates.
    let mut req = InferRequest {
        n: 0,
        h: Vec::new(),
        c: Vec::new(),
        obs: Vec::new(),
    };
    let mut routes: Vec<Route> = Vec::new();
    let mut reply_slabs: Vec<Arc<InferReply>> = Vec::new();

    // Accept a submission into the queue. Exact dims were validated at
    // `BatcherHandle::submit` (the call site); the loop trusts them.
    let push = |queue: &mut VecDeque<Open>, rows_avail: &mut usize, item: InferItem| {
        debug_assert!(
            item.rows > 0
                && item.slab.obs.len() == item.rows * ol
                && item.slab.h.len() == item.rows * hd
                && item.slab.c.len() == item.rows * hd,
            "submission bypassed BatcherHandle::submit validation"
        );
        *rows_avail += item.rows;
        queue.push_back(Open { item, consumed: 0 });
    };

    loop {
        // Block for the first rows of the next batch (leftover rows of
        // an oversized submission flow straight into the next one).
        if rows_avail == 0 {
            match rx.recv() {
                Some(item) => push(&mut queue, &mut rows_avail, item),
                None => return, // all handles dropped
            }
        }
        let t_collect = Instant::now();
        let sp_collect = trace.span(SpanKind::BatcherCollect);
        let deadline = t_collect + timeout;
        while rows_avail < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                flush_timeout.inc();
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => push(&mut queue, &mut rows_avail, item),
                Err(RecvTimeoutError::Timeout) => {
                    flush_timeout.inc();
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if rows_avail >= cfg.max_batch {
            flush_full.inc();
        }
        wait_time.record(t_collect.elapsed().as_secs_f64());
        drop(sp_collect);

        // Assemble up to max_batch rows off the queue front into the
        // recycled request, consuming submissions partially where needed
        // (rows > max_batch split across consecutive full batches, in
        // slot order). Fully-consumed submissions feed their input slab
        // back to the pool — the request holds the copies.
        let n = rows_avail.min(cfg.max_batch);
        req.h.clear();
        req.c.clear();
        req.obs.clear();
        routes.clear();
        let mut taken = 0usize;
        while taken < n {
            let open = queue.front_mut().expect("rows_avail tracks queue rows");
            let k = (open.item.rows - open.consumed).min(n - taken);
            let (a, b) = (open.consumed, open.consumed + k);
            req.h.extend_from_slice(&open.item.slab.h[a * hd..b * hd]);
            req.c.extend_from_slice(&open.item.slab.c[a * hd..b * hd]);
            req.obs.extend_from_slice(&open.item.slab.obs[a * ol..b * ol]);
            routes.push(Route {
                reply: open.item.reply.clone(),
                ticket: open.item.ticket,
                slot0: open.consumed,
                rows: k,
            });
            open.consumed += k;
            taken += k;
            if open.consumed == open.item.rows {
                let done = queue.pop_front().expect("front exists");
                pool.release(done.item.slab);
            }
        }
        rows_avail -= n;

        // Padded-bucket launch: round the flush up to the smallest AOT
        // bucket that fits (`BatcherConfig::launch_size` — the one copy
        // of the rounding rule, mirrored by `SystemModel::launch_size`
        // on the simulator side), zero-filling the pad rows (computed
        // and discarded — the reply stream is invariant to the launch
        // shape).
        let launch = cfg.launch_size(n);
        if launch > n {
            req.h.resize(launch * hd, 0.0);
            req.c.resize(launch * hd, 0.0);
            req.obs.resize(launch * ol, 0.0);
            padded_rows.add((launch - n) as u64);
        }
        req.n = launch;

        // A free output slab: any whose Arc is unique again (all chunk
        // holders scattered and dropped). Growth beyond the warmed-up
        // set only happens while receivers still hold older replies.
        let mut free = None;
        for (i, slab) in reply_slabs.iter_mut().enumerate() {
            if Arc::get_mut(slab).is_some() {
                free = Some(i);
                break;
            }
        }
        let idx = free.unwrap_or_else(|| {
            reply_slabs.push(Arc::new(InferReply {
                q: Vec::new(),
                h: Vec::new(),
                c: Vec::new(),
            }));
            reply_slabs.len() - 1
        });
        let sp_launch = trace.span(SpanKind::BatcherLaunch);
        let result = infer_time.time(|| {
            let out = Arc::get_mut(&mut reply_slabs[idx])
                .expect("free output slab is uniquely held");
            backend.infer_into(
                InferSlices {
                    n: launch,
                    h: &req.h,
                    c: &req.c,
                    obs: &req.obs,
                },
                out,
            )
        });
        drop(sp_launch);
        batches.inc();
        items.add(n as u64);
        occupancy.set(n as f64);
        launch_size.set(launch as f64);

        match result {
            Ok(()) => {
                let slab = reply_slabs[idx].clone();
                let mut off = 0usize;
                for r in &routes {
                    let _ = r.reply.send(ReplyChunk {
                        ticket: r.ticket,
                        slot0: r.slot0,
                        rows: r.rows,
                        result: Ok(ReplyRange {
                            slab: slab.clone(),
                            row0: off,
                        }),
                    });
                    off += r.rows;
                }
            }
            Err(e) => {
                // Inference failure: fail this batch's submissions and
                // everything still queued with the message, record it,
                // and exit — waiters see the error, later submitters see
                // a descriptive `gone_message`. Items still in the input
                // channel are dropped when `rx` drops, which releases
                // their mailbox routes so those waiters see disconnect
                // (mapped to the same message).
                errors.inc();
                let msg = e.to_string();
                let mut cell = first_error.lock().unwrap();
                if cell.is_none() {
                    *cell = Some(msg.clone());
                }
                drop(cell);
                for r in &routes {
                    let _ = r.reply.send(ReplyChunk {
                        ticket: r.ticket,
                        slot0: r.slot0,
                        rows: r.rows,
                        result: Err(msg.clone()),
                    });
                }
                for open in queue.drain(..) {
                    let _ = open.item.reply.send(ReplyChunk {
                        ticket: open.item.ticket,
                        slot0: open.consumed,
                        rows: open.item.rows - open.consumed,
                        result: Err(msg.clone()),
                    });
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockModel, ModelDims};
    use std::sync::Arc;

    fn mock_backend() -> (Backend, ModelDims) {
        let dims = ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        };
        (Backend::Mock(Arc::new(MockModel::new(dims, 1))), dims)
    }

    fn cfg(max_batch: usize, timeout_us: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            timeout_us,
            batch_sizes: vec![max_batch],
        }
    }

    /// Submit a multi-row slab and gather all reply chunks into
    /// slot-ordered row slabs.
    fn submit_and_gather(
        handle: &BatcherHandle,
        dims: &ModelDims,
        rows: usize,
        obs: Vec<f32>,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, usize) {
        let mb = mailbox::<ReplyChunk>(4);
        let mut slab = handle.slab_pool().acquire();
        slab.fill_from(
            &obs,
            &vec![0.0; rows * dims.hidden],
            &vec![0.0; rows * dims.hidden],
        );
        handle
            .submit(InferItem {
                actor: 0,
                ticket: 0,
                rows,
                slab,
                reply: mb.sender(),
            })
            .unwrap();
        let mut q = vec![0.0f32; rows * dims.num_actions];
        let mut h = vec![0.0f32; rows * dims.hidden];
        let mut c = vec![0.0f32; rows * dims.hidden];
        let mut done = 0usize;
        let mut chunks = 0usize;
        let (na, hd) = (dims.num_actions, dims.hidden);
        while done < rows {
            let chunk = mb.recv().expect("reply chunk");
            let d = chunk.result.expect("inference ok");
            let (s, k, r0) = (chunk.slot0, chunk.rows, d.row0);
            q[s * na..(s + k) * na].copy_from_slice(&d.slab.q[r0 * na..(r0 + k) * na]);
            h[s * hd..(s + k) * hd].copy_from_slice(&d.slab.h[r0 * hd..(r0 + k) * hd]);
            c[s * hd..(s + k) * hd].copy_from_slice(&d.slab.c[r0 * hd..(r0 + k) * hd]);
            done += k;
            chunks += 1;
        }
        (q, h, c, chunks)
    }

    #[test]
    fn single_item_flushes_on_timeout() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(8, 500), backend, m.clone());
        let out = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap();
        assert_eq!(out.q.len(), 3);
        drop(handle);
        batcher.join();
        assert_eq!(m.counter("batcher.batches").get(), 1);
        assert_eq!(m.counter("batcher.items").get(), 1);
        assert!(m.counter("batcher.flush_timeout").get() >= 1);
        // One bucket [8]: the 1-row flush padded up to the cap.
        assert_eq!(m.counter("batcher.padded_rows").get(), 7);
        assert_eq!(m.gauge("batcher.last_launch_size").get(), 8.0);
        assert_eq!(m.gauge("batcher.last_batch_size").get(), 1.0);
    }

    #[test]
    fn concurrent_actors_get_their_own_rows() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(16, 2_000), backend.clone(), m.clone());
        let results: Vec<(usize, ActorReply)> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for a in 0..12usize {
                let h = handle.clone();
                handles.push(s.spawn(move || {
                    let fill = a as f32 / 12.0;
                    let out = h
                        .infer(a, vec![fill; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                    (a, out)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Each actor's reply must equal a direct single-row mock call.
        for (a, out) in results {
            let fill = a as f32 / 12.0;
            let direct = backend
                .infer(crate::runtime::InferRequest {
                    n: 1,
                    h: vec![0.0; 4],
                    c: vec![0.0; 4],
                    obs: vec![fill; dims.obs_len],
                })
                .unwrap();
            assert_eq!(out.q, direct.q, "actor {a} got someone else's row");
        }
        drop(handle);
        batcher.join();
        // Batching really happened (fewer batches than rows).
        assert!(m.counter("batcher.batches").get() < 12);
        assert_eq!(m.counter("batcher.items").get(), 12);
    }

    #[test]
    fn multi_row_submission_routes_rows_in_slot_order_as_one_batch() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) =
            Batcher::spawn(cfg(8, 2_000), backend.clone(), m.clone());
        let n = 5;
        let mut obs = vec![0.0f32; n * dims.obs_len];
        for i in 0..n {
            obs[i * dims.obs_len..(i + 1) * dims.obs_len].fill(i as f32 / n as f32);
        }
        let (q, _, _, chunks) = submit_and_gather(&handle, &dims, n, obs);
        for i in 0..n {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; dims.hidden],
                    c: vec![0.0; dims.hidden],
                    obs: vec![i as f32 / n as f32; dims.obs_len],
                })
                .unwrap();
            assert_eq!(
                q[i * dims.num_actions..(i + 1) * dims.num_actions],
                direct.q[..],
                "row {i} misrouted"
            );
        }
        drop(handle);
        batcher.join();
        // All 5 rows entered together: one multi-row item, one batch,
        // one reply chunk — not 5 singleton calls.
        assert_eq!(chunks, 1);
        assert_eq!(m.counter("batcher.items").get(), 5);
        assert_eq!(m.counter("batcher.batches").get(), 1);
        // Bucket [8]: the 5-row flush launched as 8 with 3 pad rows.
        assert_eq!(m.counter("batcher.padded_rows").get(), 3);
    }

    #[test]
    fn oversized_submission_splits_across_full_batches_in_slot_order() {
        // rows = 10 > max_batch = 4: must be served as 4 + 4 + 2, never
        // exceeding the cap, with every row routed back in slot order.
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 500), backend.clone(), m.clone());
        let n = 10;
        let mut obs = vec![0.0f32; n * dims.obs_len];
        for i in 0..n {
            obs[i * dims.obs_len..(i + 1) * dims.obs_len].fill(i as f32 / n as f32);
        }
        let (q, _, _, chunks) = submit_and_gather(&handle, &dims, n, obs);
        for i in 0..n {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; dims.hidden],
                    c: vec![0.0; dims.hidden],
                    obs: vec![i as f32 / n as f32; dims.obs_len],
                })
                .unwrap();
            assert_eq!(
                q[i * dims.num_actions..(i + 1) * dims.num_actions],
                direct.q[..],
                "row {i} misrouted"
            );
        }
        drop(handle);
        batcher.join();
        assert_eq!(chunks, 3, "10 rows at cap 4 => 3 chunks");
        assert_eq!(m.counter("batcher.items").get(), 10);
        assert_eq!(m.counter("batcher.batches").get(), 3);
        assert_eq!(m.counter("batcher.flush_full").get(), 2);
        assert!(m.gauge("batcher.last_batch_size").get() <= 4.0);
    }

    #[test]
    fn never_exceeds_max_batch() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 50_000), backend, m.clone());
        std::thread::scope(|s| {
            for a in 0..16usize {
                let h = handle.clone();
                s.spawn(move || {
                    h.infer(a, vec![0.1; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                });
            }
        });
        drop(handle);
        batcher.join();
        // 16 rows / cap 4 => at least 4 batches, all full-or-smaller.
        assert!(m.counter("batcher.batches").get() >= 4);
        assert_eq!(m.counter("batcher.items").get(), 16);
        assert!(m.gauge("batcher.last_batch_size").get() <= 4.0);
        assert!(m.gauge("batcher.last_launch_size").get() <= 4.0);
    }

    #[test]
    fn padded_bucket_launch_rounds_partial_flushes_up_the_ladder() {
        // Ladder [2, 4, 8]: a 3-row flush launches as 4 (1 pad row), a
        // 1-row flush as 2 — and the replies are byte-identical to
        // direct exact-shape calls either way.
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let bc = BatcherConfig {
            max_batch: 8,
            timeout_us: 300,
            batch_sizes: vec![2, 4, 8],
        };
        let (batcher, handle) = Batcher::spawn(bc, backend.clone(), m.clone());
        let mut obs = vec![0.0f32; 3 * dims.obs_len];
        for i in 0..3 {
            obs[i * dims.obs_len..(i + 1) * dims.obs_len].fill(0.1 + i as f32 * 0.2);
        }
        let (q, _, _, _) = submit_and_gather(&handle, &dims, 3, obs);
        for i in 0..3 {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; dims.hidden],
                    c: vec![0.0; dims.hidden],
                    obs: vec![0.1 + i as f32 * 0.2; dims.obs_len],
                })
                .unwrap();
            assert_eq!(
                q[i * dims.num_actions..(i + 1) * dims.num_actions],
                direct.q[..],
                "padding corrupted row {i}"
            );
        }
        assert_eq!(m.counter("batcher.padded_rows").get(), 1);
        assert_eq!(m.gauge("batcher.last_launch_size").get(), 4.0);
        assert_eq!(m.gauge("batcher.last_batch_size").get(), 3.0);
        let out = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap();
        assert_eq!(out.q.len(), 3);
        assert_eq!(m.gauge("batcher.last_launch_size").get(), 2.0);
        drop(handle);
        batcher.join();
    }

    #[test]
    fn submit_validates_exact_dims_at_the_call_site() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(8, 200), backend, m.clone());
        // Short obs row: must fail synchronously, naming the actor.
        let mb = mailbox::<ReplyChunk>(2);
        let mut slab = handle.slab_pool().acquire();
        slab.fill_from(
            &vec![0.5; dims.obs_len - 1],
            &vec![0.0; dims.hidden],
            &vec![0.0; dims.hidden],
        );
        let err = handle
            .submit(InferItem {
                actor: 7,
                ticket: 0,
                rows: 1,
                slab,
                reply: mb.sender(),
            })
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("malformed submission from actor 7"),
            "got: {err}"
        );
        // The rejected slab went back to the pool, not into the queue.
        assert!(handle.slab_pool().free_count() >= 1);
        // Zero rows are rejected the same way.
        let slab = handle.slab_pool().acquire();
        let err = handle
            .submit(InferItem {
                actor: 3,
                ticket: 0,
                rows: 0,
                slab,
                reply: mb.sender(),
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("actor 3"), "got: {err}");
        // The batcher never saw either submission.
        let out = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap();
        assert_eq!(out.q.len(), 3);
        assert_eq!(m.counter("batcher.items").get(), 1);
        drop(handle);
        batcher.join();
    }

    #[test]
    fn input_slabs_recycle_through_the_pool() {
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 200), backend, m.clone());
        for _ in 0..8 {
            handle
                .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                .unwrap();
        }
        // Sequential round-trips reuse one slab: after the last reply
        // the batcher has fed it back.
        assert_eq!(handle.slab_pool().free_count(), 1);
        drop(handle);
        batcher.join();
    }

    #[test]
    fn doorbell_wakeups_never_exceed_submissions_at_equal_replies() {
        // PR 6 measured `batcher.queue_wakeups` at exactly one condvar
        // notify per submission. The doorbell protocol rings only when
        // the batcher thread is parked, so at equal replies the count
        // can only drop: sends landing while the batcher assembles or
        // launches a batch are free. The invariant (and the equal-reply
        // half of the equivalence) is deterministic; how far below the
        // baseline it lands depends on scheduling.
        let (backend, dims) = mock_backend();
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(4, 20_000), backend, m.clone());
        std::thread::scope(|s| {
            for a in 0..16usize {
                let h = handle.clone();
                s.spawn(move || {
                    h.infer(a, vec![0.1; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
                        .unwrap();
                });
            }
        });
        drop(handle);
        batcher.join();
        let items = m.counter("batcher.items").get();
        let wakeups = m.counter("batcher.queue_wakeups").get();
        assert_eq!(items, 16, "every submission answered");
        assert!(
            wakeups <= items,
            "doorbell rang {wakeups} times for {items} submissions \
             (baseline was exactly one per submission)"
        );
    }

    #[test]
    fn inference_failure_surfaces_as_error_chunks_and_counter() {
        let dims = ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        };
        let backend = Backend::Mock(Arc::new(
            MockModel::new(dims, 1).with_infer_error("injected GPU fault"),
        ));
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(cfg(8, 200), backend, m.clone());
        let err = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("injected GPU fault"), "got: {err}");
        assert_eq!(m.counter("batcher.errors").get(), 1);
        assert_eq!(
            handle.first_error().as_deref(),
            Some("injected GPU fault")
        );
        // The batcher thread exited; later submissions fail with a
        // descriptive message, not a bare "batcher gone".
        batcher.join();
        let err = handle
            .infer(0, vec![0.5; dims.obs_len], vec![0.0; 4], vec![0.0; 4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("injected GPU fault"), "got: {err}");
    }
}
