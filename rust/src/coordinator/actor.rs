//! Actor threads: environment interaction (the CPU side of the paper).
//!
//! Each actor owns one wrapped environment and its recurrent state. In
//! central mode (SEED) the actor's policy step is a blocking round-trip
//! through the inference batcher; in local mode (IMPALA baseline) the
//! actor calls the backend directly with a batch of 1, modelling
//! actor-side inference. Completed sequences flow into the shared
//! prioritized replay.

use super::batcher::BatcherHandle;
use crate::config::SystemConfig;
use crate::env::wrappers::Wrapped;
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::replay::SequenceReplay;
use crate::rl::{actor_epsilon, epsilon_greedy, SequenceBuilder, Transition};
use crate::runtime::{Backend, InferRequest, ModelDims};
use crate::util::prng::Pcg32;
use std::sync::Arc;

/// How an actor obtains q-values for an observation.
pub enum PolicyPath {
    /// SEED: round-trip through the central inference batcher.
    Central(BatcherHandle),
    /// IMPALA baseline: direct per-actor inference (batch of 1).
    Local(Backend),
}

pub struct ActorArgs {
    pub id: usize,
    pub cfg: SystemConfig,
    pub dims: ModelDims,
    pub path: PolicyPath,
    pub replay: Arc<SequenceReplay>,
    pub metrics: Registry,
    pub shutdown: ShutdownToken,
}

/// Per-actor terminal statistics, returned at join time.
#[derive(Clone, Debug, Default)]
pub struct ActorStats {
    pub id: usize,
    pub env_steps: u64,
    pub episodes: u64,
    pub mean_return: f64,
    pub epsilon: f64,
}

/// The actor main loop. Runs until shutdown is signalled.
pub fn run_actor(args: ActorArgs) -> anyhow::Result<ActorStats> {
    let ActorArgs {
        id,
        cfg,
        dims,
        path,
        replay,
        metrics,
        shutdown,
    } = args;

    let mut env = Wrapped::from_config(&cfg.env, id as u64 + 1)?;
    anyhow::ensure!(
        env.obs_len() == dims.obs_len,
        "env obs_len {} != model obs_len {} (frame_stack vs obs_channels?)",
        env.obs_len(),
        dims.obs_len
    );
    let epsilon = actor_epsilon(
        id,
        cfg.actors.num_actors,
        cfg.actors.epsilon_base,
        cfg.actors.epsilon_alpha,
    );
    let mut rng = Pcg32::seeded(cfg.seed ^ (0xAC70 + id as u64));
    let mut builder = SequenceBuilder::new(
        cfg.learner.seq_len(),
        cfg.learner.seq_overlap,
        dims.obs_len,
        dims.hidden,
        id,
    );

    let steps = metrics.counter("actor.env_steps");
    let episodes_c = metrics.counter("actor.episodes");
    let seqs = metrics.counter("actor.sequences");
    let step_time = metrics.timer("actor.step_seconds");
    let return_gauge = metrics.gauge("actor.last_return");

    let mut obs = vec![0.0f32; dims.obs_len];
    let mut h = vec![0.0f32; dims.hidden];
    let mut c = vec![0.0f32; dims.hidden];
    env.reset(&mut obs);

    let mut return_sum = 0.0f64;
    let mut return_count = 0u64;

    while !shutdown.is_signalled() {
        let t0 = std::time::Instant::now();
        // Policy step: obtain q and next recurrent state.
        let (q, h2, c2) = match &path {
            PolicyPath::Central(handle) => {
                match handle.infer(id, obs.clone(), h.clone(), c.clone()) {
                    Ok(r) => (r.q, r.h, r.c),
                    Err(_) => break, // batcher shut down
                }
            }
            PolicyPath::Local(backend) => {
                let r = backend.infer(InferRequest {
                    n: 1,
                    h: h.clone(),
                    c: c.clone(),
                    obs: obs.clone(),
                })?;
                (r.q, r.h, r.c)
            }
        };
        let action = epsilon_greedy(&q, epsilon, &mut rng);

        // Environment step (the CPU-bound work the paper sweeps).
        let prev_obs = obs.clone();
        let step = env.step(action, &mut obs);
        let discount = if step.done && !step.truncated {
            0.0
        } else {
            cfg.learner.gamma as f32
        };

        if step.done {
            episodes_c.inc();
            return_gauge.set(env.last_return as f64);
            return_sum += env.last_return as f64;
            return_count += 1;
        }

        // Record the transition with the pre-step state.
        let done = step.done;
        if let Some(seq) = builder.push(Transition {
            obs: prev_obs,
            action: action as i32,
            reward: step.reward,
            discount,
            h: h.clone(),
            c: c.clone(),
        }) {
            replay.add(seq);
            seqs.inc();
        }

        // Advance recurrent state; reset it at episode boundaries.
        if done {
            h.fill(0.0);
            c.fill(0.0);
        } else {
            h = h2;
            c = c2;
        }

        steps.inc();
        step_time.record(t0.elapsed().as_secs_f64());
    }

    if let Some(seq) = builder.flush() {
        replay.add(seq);
        seqs.inc();
    }

    Ok(ActorStats {
        id,
        env_steps: env.total_steps,
        episodes: env.episodes_completed,
        mean_return: if return_count > 0 {
            return_sum / return_count as f64
        } else {
            0.0
        },
        epsilon,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayConfig, SequenceReplay};
    use crate::runtime::MockModel;

    fn test_cfg() -> (SystemConfig, ModelDims) {
        let mut cfg = SystemConfig::default();
        cfg.env.name = "catch".into();
        cfg.env.step_cost_us = 0;
        cfg.env.frame_stack = 4;
        cfg.learner.burn_in = 2;
        cfg.learner.unroll_len = 4;
        cfg.learner.seq_overlap = 2;
        cfg.actors.num_actors = 2;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 2,
        };
        (cfg, dims)
    }

    #[test]
    fn local_actor_fills_replay_and_stops_on_shutdown() {
        let (cfg, dims) = test_cfg();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 256,
            ..Default::default()
        }));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let shutdown = ShutdownToken::new();
        let metrics = Registry::new();
        let stats = std::thread::scope(|s| {
            let h = s.spawn({
                let replay = replay.clone();
                let shutdown = shutdown.clone();
                let metrics = metrics.clone();
                move || {
                    run_actor(ActorArgs {
                        id: 0,
                        cfg,
                        dims,
                        path: PolicyPath::Local(backend),
                        replay,
                        metrics,
                        shutdown,
                    })
                    .unwrap()
                }
            });
            std::thread::sleep(std::time::Duration::from_millis(150));
            shutdown.signal();
            h.join().unwrap()
        });
        assert!(stats.env_steps > 50, "steps {}", stats.env_steps);
        assert!(stats.episodes > 0);
        assert!(replay.len() > 0, "sequences should reach replay");
        assert!(metrics.counter("actor.sequences").get() > 0);
    }

    #[test]
    fn obs_len_mismatch_is_rejected() {
        let (mut cfg, dims) = test_cfg();
        cfg.env.frame_stack = 2; // obs_len becomes 200 != dims.obs_len 400
        let replay = Arc::new(SequenceReplay::new(ReplayConfig::default()));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let r = run_actor(ActorArgs {
            id: 0,
            cfg,
            dims,
            path: PolicyPath::Local(backend),
            replay,
            metrics: Registry::new(),
            shutdown: ShutdownToken::new(),
        });
        assert!(r.is_err());
    }
}
