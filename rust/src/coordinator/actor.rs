//! Actor threads: environment interaction (the CPU side of the paper).
//!
//! Each actor thread owns a [`VecEnv`] driving `envs_per_actor`
//! environment slots in lockstep, plus one recurrent state and one
//! trajectory builder per slot. Inference goes through the split-phase
//! [`PolicyClient`] (DESIGN.md §5): the slots are partitioned into
//! `pipeline_depth` contiguous groups, and the loop round-robins over
//! them — `wait` on group g's in-flight inference, act, step g's
//! environments, `submit` g's next observations — so with depth ≥ 2 the
//! CPU-bound env stepping of one group overlaps the GPU latency of the
//! others. Completed sequences flow into the shared prioritized replay.
//!
//! With `pipeline_depth = 1` (and any `envs_per_actor`) this is exactly
//! the seed's serialized loop: same seeds, same RNG streams, same
//! submission pattern, same replay contents — asserted bit-for-bit by
//! `tests/coordinator_e2e.rs`. Observations live in two full-size slabs
//! per actor (double buffer): the step writes the post-step frame into
//! the spare buffer while the pre-step frame stays addressable for
//! transition recording, so the loop itself allocates no observation
//! slabs per step (the seed's full-slab `obs.clone()` is gone).
//!
//! The transition path is allocation-free in steady state (DESIGN.md
//! §8): transitions enter the per-slot builders as borrowed rows
//! ([`SequenceBuilder::push_slices`] — the seed's three per-step
//! `to_vec()` copies are gone), emitted sequence slabs are drawn from
//! the replay's recycling [`crate::rl::SequencePool`] when one is
//! attached (hit/miss counters → the `actor.pool_hit_rate` gauge), and
//! completed sequences buffer in a per-actor
//! [`IngestQueue`](crate::replay::IngestQueue) that commits
//! `replay.insert_batch` of them per flush, taking each replay shard
//! lock at most once. `insert_batch = 1` (the default) flushes each
//! sequence immediately through the exact seed `add` path.

use crate::config::SystemConfig;
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::policy::PolicyClient;
use crate::replay::{IngestQueue, SequenceSink};
use crate::rl::{actor_epsilon, epsilon_greedy, SequenceBuilder};
use crate::runtime::ModelDims;
use crate::telemetry::SpanKind;
use crate::util::prng::Pcg32;
use crate::vecenv::VecEnv;
use std::sync::Arc;

pub struct ActorArgs {
    pub id: usize,
    pub cfg: SystemConfig,
    pub dims: ModelDims,
    /// Split-phase inference client (central batcher, local backend, or
    /// a fleet worker's remote connection).
    pub policy: Box<dyn PolicyClient>,
    /// Where completed sequences go: the in-process replay, or a
    /// [`crate::transport::RemoteIngest`] shipping them to the
    /// coordinator — the actor loop is identical either way.
    pub replay: Arc<dyn SequenceSink>,
    pub metrics: Registry,
    pub shutdown: ShutdownToken,
    /// Stop after this many rounds (a round steps every env slot once);
    /// `None` runs until shutdown. Tests/benches use this to make actor
    /// runs deterministic.
    pub max_rounds: Option<u64>,
}

/// Per-actor terminal statistics, returned at join time.
#[derive(Clone, Debug, Default)]
pub struct ActorStats {
    pub id: usize,
    /// Environment slots this actor drove.
    pub envs: usize,
    pub env_steps: u64,
    pub episodes: u64,
    pub mean_return: f64,
    /// Mean epsilon across this actor's slots.
    pub epsilon: f64,
}

/// Contiguous `(start, len)` slot groups: `e` slots split into `depth`
/// pipeline stages, earlier groups taking the remainder slots.
fn slot_groups(e: usize, depth: usize) -> Vec<(usize, usize)> {
    let base = e / depth;
    let extra = e % depth;
    let mut out = Vec::with_capacity(depth);
    let mut start = 0;
    for g in 0..depth {
        let len = base + usize::from(g < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// The actor main loop. Runs until shutdown is signalled (or
/// `max_rounds` elapse). A policy failure signals shutdown and returns
/// a descriptive error instead of dying silently.
pub fn run_actor(args: ActorArgs) -> anyhow::Result<ActorStats> {
    let ActorArgs {
        id,
        cfg,
        dims,
        mut policy,
        replay,
        metrics,
        shutdown,
        max_rounds,
    } = args;

    let e = cfg.actors.envs_per_actor.max(1);
    let total_slots = cfg.actors.num_actors * e;
    // More pipeline stages than slots cannot help: clamp to one slot
    // per group.
    let depth = cfg.actors.pipeline_depth.max(1).min(e);
    let groups = slot_groups(e, depth);
    // Slot seeds continue the seed layout of the single-env design:
    // actor `id` at E = 1 used instance seed `id + 1`; slot `s` of actor
    // `id` uses `id * E + s + 1`.
    let mut venv = VecEnv::from_config(&cfg.env, e, (id * e) as u64 + 1)?;
    anyhow::ensure!(
        venv.obs_len() == dims.obs_len,
        "env obs_len {} != model obs_len {} (frame_stack vs obs_channels?)",
        venv.obs_len(),
        dims.obs_len
    );
    let obs_len = dims.obs_len;
    let hidden = dims.hidden;

    // Per-slot exploration spectrum over ALL environment slots in the
    // pool, so E envs on one thread explore like E distinct actors.
    let epsilons: Vec<f64> = (0..e)
        .map(|s| {
            actor_epsilon(
                id * e + s,
                total_slots,
                cfg.actors.epsilon_base,
                cfg.actors.epsilon_alpha,
            )
        })
        .collect();
    let mut rngs: Vec<Pcg32> = (0..e)
        .map(|s| Pcg32::seeded(cfg.seed ^ (0xAC70 + (id * e + s) as u64)))
        .collect();
    // Builders draw emitted slabs from the sink's recycling pool when
    // one is attached; completed sequences buffer in the ingest queue
    // and commit `insert_batch` per flush (1 = the seed path).
    let pool = replay.recycle_pool();
    let mut builders: Vec<SequenceBuilder> = (0..e)
        .map(|s| {
            let b = SequenceBuilder::new(
                cfg.learner.seq_len(),
                cfg.learner.seq_overlap,
                obs_len,
                hidden,
                id * e + s,
            );
            match &pool {
                Some(p) => b.with_pool(p.clone()),
                None => b,
            }
        })
        .collect();
    let mut ingest = IngestQueue::new(replay.clone(), cfg.replay.insert_batch);

    let steps = metrics.counter("actor.env_steps");
    let episodes_c = metrics.counter("actor.episodes");
    let seqs = metrics.counter("actor.sequences");
    let step_time = metrics.timer("actor.step_seconds");
    let overlap_time = metrics.timer("actor.overlap_seconds");
    // Pure CPU phase of a group iteration (action selection + env step +
    // transition building + replay hand-off, no inference wait): the
    // `t_env` term of the live CPU/GPU-ratio proxy.
    let env_time = metrics.timer("actor.env_seconds");
    let return_gauge = metrics.gauge("actor.last_return");
    let trace = metrics.span_recorder(format_args!("actor-{id}"));

    // Double-buffered contiguous [E, S, S, K] observation slabs plus
    // [E, hidden] recurrent-state slabs (h/c inputs and h_next/c_next
    // scatter targets): slot rows map 1:1 onto inference-batch rows, and
    // the loop never clones a whole observation slab — stepping writes
    // the post-step frame into the spare buffer while the pre-step frame
    // is still recorded from the other.
    let mut obs_bufs = [venv.new_obs_batch(), venv.new_obs_batch()];
    // Which buffer holds each group's current (pre-step) observations.
    let mut cur = vec![0usize; depth];
    let mut h = vec![0.0f32; e * hidden];
    let mut c = vec![0.0f32; e * hidden];
    let mut h_next = vec![0.0f32; e * hidden];
    let mut c_next = vec![0.0f32; e * hidden];
    let mut q = vec![0.0f32; e * dims.num_actions];
    let mut actions = vec![0usize; e];
    let mut steps_buf: Vec<crate::env::Step> = Vec::with_capacity(e);
    venv.reset_all(&mut obs_bufs[0]);

    let mut return_sum = 0.0f64;
    let mut return_count = 0u64;
    let mut rounds = 0u64;
    let mut failure: Option<anyhow::Error> = None;

    // Prologue: put every group's initial observations in flight.
    for (g, &(start, len)) in groups.iter().enumerate() {
        let orow = start * obs_len..(start + len) * obs_len;
        let hrow = start * hidden..(start + len) * hidden;
        if let Err(err) = policy.submit(
            g,
            len,
            &obs_bufs[0][orow],
            &h[hrow.clone()],
            &c[hrow],
        ) {
            shutdown.signal();
            return Err(anyhow::anyhow!("actor {id}: inference submit failed: {err}"));
        }
    }

    'run: while !shutdown.is_signalled() {
        if let Some(max) = max_rounds {
            if rounds >= max {
                break;
            }
        }
        rounds += 1;
        for (g, &(start, len)) in groups.iter().enumerate() {
            let t0 = std::time::Instant::now();
            let orow = start * obs_len..(start + len) * obs_len;
            let hrow = start * hidden..(start + len) * hidden;
            let qrow = start * dims.num_actions..(start + len) * dims.num_actions;

            // Redeem group g's in-flight inference: q plus next
            // recurrent state scatter straight into the slot rows.
            let waited = {
                let _sp = trace.span(SpanKind::PolicyWait);
                policy.wait(
                    g,
                    &mut q[qrow],
                    &mut h_next[hrow.clone()],
                    &mut c_next[hrow.clone()],
                )
            };
            if let Err(err) = waited {
                if shutdown.is_signalled() {
                    break 'run; // teardown race, not a failure
                }
                shutdown.signal();
                failure =
                    Some(anyhow::anyhow!("actor {id}: inference failed: {err}"));
                break 'run;
            }
            let t_work = std::time::Instant::now();
            let sp_env = trace.span(SpanKind::EnvStep);

            for s in start..start + len {
                actions[s] = epsilon_greedy(
                    &q[s * dims.num_actions..(s + 1) * dims.num_actions],
                    epsilons[s],
                    &mut rngs[s],
                );
            }

            // Environment step (the CPU-bound work the paper sweeps) for
            // this group's slots, into the spare observation buffer; the
            // pre-step frames stay live in the current one.
            let (prev_buf, next_buf) = {
                let [a, b] = &mut obs_bufs;
                if cur[g] == 0 {
                    (&*a, b)
                } else {
                    (&*b, a)
                }
            };
            steps_buf.clear();
            steps_buf.extend_from_slice(venv.step_range(
                start,
                &actions[start..start + len],
                &mut next_buf[orow.clone()],
            ));

            for s in start..start + len {
                let step = &steps_buf[s - start];
                let discount = if step.done && !step.truncated {
                    0.0
                } else {
                    cfg.learner.gamma as f32
                };

                if step.done {
                    episodes_c.inc();
                    let last = venv.last_return(s) as f64;
                    return_gauge.set(last);
                    return_sum += last;
                    return_count += 1;
                }

                // Record the transition with the pre-step state: rows
                // borrowed straight from the slot slabs — nothing on
                // this path heap-allocates per step.
                let row = s * obs_len..(s + 1) * obs_len;
                let hr = s * hidden..(s + 1) * hidden;
                if let Some(seq) = builders[s].push_slices(
                    &prev_buf[row],
                    actions[s] as i32,
                    step.reward,
                    discount,
                    &h[hr.clone()],
                    &c[hr.clone()],
                ) {
                    let _sp = trace.span(SpanKind::ReplayInsert);
                    ingest.push(seq);
                    seqs.inc();
                }

                // Advance recurrent state; reset it at episode ends.
                if step.done {
                    h[hr.clone()].fill(0.0);
                    c[hr].fill(0.0);
                } else {
                    h[hr.clone()].copy_from_slice(&h_next[hr.clone()]);
                    c[hr.clone()].copy_from_slice(&c_next[hr]);
                }
            }

            drop(sp_env);
            env_time.record(t_work.elapsed().as_secs_f64());

            // Put group g's next round in flight before touching the
            // other groups: at depth ≥ 2 their env work now overlaps it.
            let submitted = {
                let _sp = trace.span(SpanKind::PolicySubmit);
                policy.submit(g, len, &next_buf[orow], &h[hrow.clone()], &c[hrow])
            };
            if let Err(err) = submitted {
                if shutdown.is_signalled() {
                    break 'run;
                }
                shutdown.signal();
                failure = Some(anyhow::anyhow!(
                    "actor {id}: inference submit failed: {err}"
                ));
                break 'run;
            }
            cur[g] ^= 1;

            steps.add(len as u64);
            if depth > 1 {
                // Env/bookkeeping time spent while the other groups'
                // inference was in flight — the pipeline's win.
                overlap_time.record(t_work.elapsed().as_secs_f64());
            }
            step_time.record(t0.elapsed().as_secs_f64());
        }
    }

    for b in &mut builders {
        if let Some(seq) = b.flush() {
            ingest.push(seq);
            seqs.inc();
        }
    }
    ingest.flush();
    if let Some(p) = &pool {
        metrics.gauge("actor.pool_hit_rate").set(p.hit_rate());
    }

    if let Some(err) = failure {
        return Err(err);
    }

    Ok(ActorStats {
        id,
        envs: e,
        env_steps: venv.total_steps(),
        episodes: venv.episodes_completed(),
        mean_return: if return_count > 0 {
            return_sum / return_count as f64
        } else {
            0.0
        },
        epsilon: epsilons.iter().sum::<f64>() / e as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::LocalClient;
    use crate::replay::{ReplayConfig, SequenceReplay};
    use crate::runtime::{Backend, MockModel};

    fn test_cfg() -> (SystemConfig, ModelDims) {
        let mut cfg = SystemConfig::default();
        cfg.env.name = "catch".into();
        cfg.env.step_cost_us = 0;
        cfg.env.frame_stack = 4;
        cfg.learner.burn_in = 2;
        cfg.learner.unroll_len = 4;
        cfg.learner.seq_overlap = 2;
        cfg.actors.num_actors = 2;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 2,
        };
        (cfg, dims)
    }

    fn run_local_for(
        cfg: SystemConfig,
        dims: ModelDims,
        ms: u64,
    ) -> (ActorStats, Arc<SequenceReplay>, Registry) {
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 256,
            ..Default::default()
        }));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let shutdown = ShutdownToken::new();
        let metrics = Registry::new();
        let stats = std::thread::scope(|s| {
            let h = s.spawn({
                let replay = replay.clone();
                let shutdown = shutdown.clone();
                let metrics = metrics.clone();
                let policy: Box<dyn PolicyClient> = Box::new(LocalClient::new(
                    backend,
                    cfg.batcher.max_batch,
                    dims,
                    &metrics,
                ));
                move || {
                    run_actor(ActorArgs {
                        id: 0,
                        cfg,
                        dims,
                        policy,
                        replay,
                        metrics,
                        shutdown,
                        max_rounds: None,
                    })
                    .unwrap()
                }
            });
            std::thread::sleep(std::time::Duration::from_millis(ms));
            shutdown.signal();
            h.join().unwrap()
        });
        (stats, replay, metrics)
    }

    #[test]
    fn local_actor_fills_replay_and_stops_on_shutdown() {
        let (cfg, dims) = test_cfg();
        let (stats, replay, metrics) = run_local_for(cfg, dims, 150);
        assert_eq!(stats.envs, 1);
        assert!(stats.env_steps > 50, "steps {}", stats.env_steps);
        assert!(stats.episodes > 0);
        assert!(replay.len() > 0, "sequences should reach replay");
        assert!(metrics.counter("actor.sequences").get() > 0);
    }

    #[test]
    fn multi_env_actor_steps_all_slots() {
        let (mut cfg, dims) = test_cfg();
        cfg.actors.envs_per_actor = 4;
        let (stats, replay, metrics) = run_local_for(cfg, dims, 150);
        assert_eq!(stats.envs, 4);
        // All slots advance together: the step total is a multiple of 4.
        assert_eq!(stats.env_steps % 4, 0);
        assert!(stats.env_steps >= 200, "steps {}", stats.env_steps);
        assert!(stats.episodes > 3, "episodes {}", stats.episodes);
        assert!(replay.len() > 0);
        assert_eq!(
            metrics.counter("actor.env_steps").get(),
            stats.env_steps
        );
    }

    #[test]
    fn pipelined_actor_steps_all_slots() {
        // depth 2 over 4 slots: two groups of 2 leapfrogging; every slot
        // still advances once per round.
        let (mut cfg, dims) = test_cfg();
        cfg.actors.envs_per_actor = 4;
        cfg.actors.pipeline_depth = 2;
        let (stats, replay, metrics) = run_local_for(cfg, dims, 150);
        assert_eq!(stats.envs, 4);
        assert!(stats.env_steps >= 200, "steps {}", stats.env_steps);
        assert!(replay.len() > 0);
        // Groups may be one apart at shutdown, never more.
        let per_group = 2u64;
        let diff = stats.env_steps % (2 * per_group);
        assert!(
            diff == 0 || diff == per_group,
            "groups drifted: {} steps",
            stats.env_steps
        );
        assert!(metrics.timer("actor.overlap_seconds").snapshot().count() > 0);
    }

    #[test]
    fn max_rounds_bounds_the_run_exactly() {
        let (mut cfg, dims) = test_cfg();
        cfg.actors.envs_per_actor = 3;
        let replay = Arc::new(SequenceReplay::new(ReplayConfig::default()));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let metrics = Registry::new();
        let policy: Box<dyn PolicyClient> = Box::new(LocalClient::new(
            backend,
            cfg.batcher.max_batch,
            dims,
            &metrics,
        ));
        let stats = run_actor(ActorArgs {
            id: 0,
            cfg,
            dims,
            policy,
            replay,
            metrics,
            shutdown: ShutdownToken::new(),
            max_rounds: Some(25),
        })
        .unwrap();
        assert_eq!(stats.env_steps, 25 * 3);
    }

    #[test]
    fn batch_native_actor_matches_per_slot_actor() {
        // A bounded deterministic run (mock backend, max_rounds) must
        // produce identical terminal stats on either env engine: the
        // SoA path changes cost, not behavior.
        let run = |batch_native: bool| {
            let (mut cfg, dims) = test_cfg();
            cfg.actors.envs_per_actor = 3;
            cfg.actors.pipeline_depth = 2;
            cfg.env.batch_native = batch_native;
            let replay = Arc::new(SequenceReplay::new(ReplayConfig::default()));
            let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
            let metrics = Registry::new();
            let policy: Box<dyn PolicyClient> = Box::new(LocalClient::new(
                backend,
                cfg.batcher.max_batch,
                dims,
                &metrics,
            ));
            let stats = run_actor(ActorArgs {
                id: 0,
                cfg,
                dims,
                policy,
                replay: replay.clone(),
                metrics,
                shutdown: ShutdownToken::new(),
                max_rounds: Some(40),
            })
            .unwrap();
            (
                stats.env_steps,
                stats.episodes,
                stats.mean_return,
                replay.len(),
            )
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn obs_len_mismatch_is_rejected() {
        let (mut cfg, dims) = test_cfg();
        cfg.env.frame_stack = 2; // obs_len becomes 200 != dims.obs_len 400
        let replay = Arc::new(SequenceReplay::new(ReplayConfig::default()));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let metrics = Registry::new();
        let policy: Box<dyn PolicyClient> = Box::new(LocalClient::new(
            backend,
            cfg.batcher.max_batch,
            dims,
            &metrics,
        ));
        let r = run_actor(ActorArgs {
            id: 0,
            cfg,
            dims,
            policy,
            replay,
            metrics,
            shutdown: ShutdownToken::new(),
            max_rounds: None,
        });
        assert!(r.is_err());
    }

    #[test]
    fn slot_groups_cover_contiguously() {
        assert_eq!(slot_groups(8, 2), vec![(0, 4), (4, 4)]);
        assert_eq!(slot_groups(5, 2), vec![(0, 3), (3, 2)]);
        assert_eq!(slot_groups(1, 1), vec![(0, 1)]);
        assert_eq!(slot_groups(6, 3), vec![(0, 2), (2, 2), (4, 2)]);
    }
}
