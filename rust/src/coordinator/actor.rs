//! Actor threads: environment interaction (the CPU side of the paper).
//!
//! Each actor thread owns a [`VecEnv`] driving `envs_per_actor`
//! environment slots in lockstep, plus one recurrent state and one
//! trajectory builder per slot. In central mode (SEED) the policy step
//! submits all E observations to the inference batcher in one shot and
//! waits for the routed replies; in local mode (IMPALA baseline) the
//! actor calls the backend directly with a batch of E. Completed
//! sequences flow into the shared prioritized replay.
//!
//! With `envs_per_actor = 1` this is exactly the seed's single-env actor
//! loop: same seeds, same RNG streams, same submission pattern.

use super::batcher::BatcherHandle;
use crate::config::SystemConfig;
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::replay::SequenceReplay;
use crate::rl::{actor_epsilon, epsilon_greedy, SequenceBuilder, Transition};
use crate::runtime::{Backend, InferRequest, ModelDims};
use crate::util::prng::Pcg32;
use crate::vecenv::VecEnv;
use std::sync::Arc;

/// How an actor obtains q-values for its observations.
pub enum PolicyPath {
    /// SEED: round-trip through the central inference batcher.
    Central(BatcherHandle),
    /// IMPALA baseline: direct per-actor inference (batch of E).
    Local(Backend),
}

pub struct ActorArgs {
    pub id: usize,
    pub cfg: SystemConfig,
    pub dims: ModelDims,
    pub path: PolicyPath,
    pub replay: Arc<SequenceReplay>,
    pub metrics: Registry,
    pub shutdown: ShutdownToken,
}

/// Per-actor terminal statistics, returned at join time.
#[derive(Clone, Debug, Default)]
pub struct ActorStats {
    pub id: usize,
    /// Environment slots this actor drove.
    pub envs: usize,
    pub env_steps: u64,
    pub episodes: u64,
    pub mean_return: f64,
    /// Mean epsilon across this actor's slots.
    pub epsilon: f64,
}

/// The actor main loop. Runs until shutdown is signalled.
pub fn run_actor(args: ActorArgs) -> anyhow::Result<ActorStats> {
    let ActorArgs {
        id,
        cfg,
        dims,
        path,
        replay,
        metrics,
        shutdown,
    } = args;

    let e = cfg.actors.envs_per_actor.max(1);
    let total_slots = cfg.actors.num_actors * e;
    // Slot seeds continue the seed layout of the single-env design:
    // actor `id` at E = 1 used instance seed `id + 1`; slot `s` of actor
    // `id` uses `id * E + s + 1`.
    let mut venv = VecEnv::from_config(&cfg.env, e, (id * e) as u64 + 1)?;
    anyhow::ensure!(
        venv.obs_len() == dims.obs_len,
        "env obs_len {} != model obs_len {} (frame_stack vs obs_channels?)",
        venv.obs_len(),
        dims.obs_len
    );

    // Per-slot exploration spectrum over ALL environment slots in the
    // pool, so E envs on one thread explore like E distinct actors.
    let epsilons: Vec<f64> = (0..e)
        .map(|s| {
            actor_epsilon(
                id * e + s,
                total_slots,
                cfg.actors.epsilon_base,
                cfg.actors.epsilon_alpha,
            )
        })
        .collect();
    let mut rngs: Vec<Pcg32> = (0..e)
        .map(|s| Pcg32::seeded(cfg.seed ^ (0xAC70 + (id * e + s) as u64)))
        .collect();
    let mut builders: Vec<SequenceBuilder> = (0..e)
        .map(|s| {
            SequenceBuilder::new(
                cfg.learner.seq_len(),
                cfg.learner.seq_overlap,
                dims.obs_len,
                dims.hidden,
                id * e + s,
            )
        })
        .collect();

    let steps = metrics.counter("actor.env_steps");
    let episodes_c = metrics.counter("actor.episodes");
    let seqs = metrics.counter("actor.sequences");
    let step_time = metrics.timer("actor.step_seconds");
    let return_gauge = metrics.gauge("actor.last_return");

    // Contiguous [E, S, S, K] observation slab and [E, hidden] recurrent
    // state slabs: slot rows map 1:1 onto inference-batch rows.
    let mut obs = venv.new_obs_batch();
    let mut h = vec![0.0f32; e * dims.hidden];
    let mut c = vec![0.0f32; e * dims.hidden];
    venv.reset_all(&mut obs);

    let mut actions = vec![0usize; e];
    let mut return_sum = 0.0f64;
    let mut return_count = 0u64;

    'run: while !shutdown.is_signalled() {
        let t0 = std::time::Instant::now();
        // Policy step: obtain q and next recurrent state for every slot.
        let replies = match &path {
            PolicyPath::Central(handle) => {
                match handle.infer_many(id, e, &obs, &h, &c) {
                    Ok(rs) => rs,
                    Err(_) => break 'run, // batcher shut down
                }
            }
            PolicyPath::Local(backend) => {
                // One backend call can carry at most max_batch rows (the
                // largest compiled AOT batch); E beyond that is served in
                // ceil(E / max_batch) chunked calls.
                let cap = cfg.batcher.max_batch.max(1);
                let mut replies = Vec::with_capacity(e);
                let mut start = 0usize;
                while start < e {
                    let n = cap.min(e - start);
                    let r = backend.infer(InferRequest {
                        n,
                        h: h[start * dims.hidden..(start + n) * dims.hidden]
                            .to_vec(),
                        c: c[start * dims.hidden..(start + n) * dims.hidden]
                            .to_vec(),
                        obs: obs[start * dims.obs_len..(start + n) * dims.obs_len]
                            .to_vec(),
                    })?;
                    for s in 0..n {
                        replies.push(super::batcher::ActorReply {
                            q: r.q[s * dims.num_actions..(s + 1) * dims.num_actions]
                                .to_vec(),
                            h: r.h[s * dims.hidden..(s + 1) * dims.hidden].to_vec(),
                            c: r.c[s * dims.hidden..(s + 1) * dims.hidden].to_vec(),
                        });
                    }
                    start += n;
                }
                replies
            }
        };
        for s in 0..e {
            actions[s] = epsilon_greedy(&replies[s].q, epsilons[s], &mut rngs[s]);
        }

        // Environment step (the CPU-bound work the paper sweeps): all E
        // slots advance before the next inference round-trip.
        let prev_obs = obs.clone();
        let step_results = venv.step_all(&actions, &mut obs).to_vec();

        for s in 0..e {
            let step = &step_results[s];
            let discount = if step.done && !step.truncated {
                0.0
            } else {
                cfg.learner.gamma as f32
            };

            if step.done {
                episodes_c.inc();
                let last = venv.slot(s).last_return as f64;
                return_gauge.set(last);
                return_sum += last;
                return_count += 1;
            }

            // Record the transition with the pre-step state.
            let row = s * dims.obs_len..(s + 1) * dims.obs_len;
            let hrow = s * dims.hidden..(s + 1) * dims.hidden;
            if let Some(seq) = builders[s].push(Transition {
                obs: prev_obs[row].to_vec(),
                action: actions[s] as i32,
                reward: step.reward,
                discount,
                h: h[hrow.clone()].to_vec(),
                c: c[hrow.clone()].to_vec(),
            }) {
                replay.add(seq);
                seqs.inc();
            }

            // Advance recurrent state; reset it at episode boundaries.
            if step.done {
                h[hrow.clone()].fill(0.0);
                c[hrow.clone()].fill(0.0);
            } else {
                h[hrow.clone()].copy_from_slice(&replies[s].h);
                c[hrow].copy_from_slice(&replies[s].c);
            }
        }

        steps.add(e as u64);
        step_time.record(t0.elapsed().as_secs_f64());
    }

    for b in &mut builders {
        if let Some(seq) = b.flush() {
            replay.add(seq);
            seqs.inc();
        }
    }

    Ok(ActorStats {
        id,
        envs: e,
        env_steps: venv.total_steps(),
        episodes: venv.episodes_completed(),
        mean_return: if return_count > 0 {
            return_sum / return_count as f64
        } else {
            0.0
        },
        epsilon: epsilons.iter().sum::<f64>() / e as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayConfig, SequenceReplay};
    use crate::runtime::MockModel;

    fn test_cfg() -> (SystemConfig, ModelDims) {
        let mut cfg = SystemConfig::default();
        cfg.env.name = "catch".into();
        cfg.env.step_cost_us = 0;
        cfg.env.frame_stack = 4;
        cfg.learner.burn_in = 2;
        cfg.learner.unroll_len = 4;
        cfg.learner.seq_overlap = 2;
        cfg.actors.num_actors = 2;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 2,
        };
        (cfg, dims)
    }

    fn run_local_for(cfg: SystemConfig, dims: ModelDims, ms: u64) -> (ActorStats, Arc<SequenceReplay>, Registry) {
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 256,
            ..Default::default()
        }));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let shutdown = ShutdownToken::new();
        let metrics = Registry::new();
        let stats = std::thread::scope(|s| {
            let h = s.spawn({
                let replay = replay.clone();
                let shutdown = shutdown.clone();
                let metrics = metrics.clone();
                move || {
                    run_actor(ActorArgs {
                        id: 0,
                        cfg,
                        dims,
                        path: PolicyPath::Local(backend),
                        replay,
                        metrics,
                        shutdown,
                    })
                    .unwrap()
                }
            });
            std::thread::sleep(std::time::Duration::from_millis(ms));
            shutdown.signal();
            h.join().unwrap()
        });
        (stats, replay, metrics)
    }

    #[test]
    fn local_actor_fills_replay_and_stops_on_shutdown() {
        let (cfg, dims) = test_cfg();
        let (stats, replay, metrics) = run_local_for(cfg, dims, 150);
        assert_eq!(stats.envs, 1);
        assert!(stats.env_steps > 50, "steps {}", stats.env_steps);
        assert!(stats.episodes > 0);
        assert!(replay.len() > 0, "sequences should reach replay");
        assert!(metrics.counter("actor.sequences").get() > 0);
    }

    #[test]
    fn multi_env_actor_steps_all_slots() {
        let (mut cfg, dims) = test_cfg();
        cfg.actors.envs_per_actor = 4;
        let (stats, replay, metrics) = run_local_for(cfg, dims, 150);
        assert_eq!(stats.envs, 4);
        // All slots advance together: the step total is a multiple of 4.
        assert_eq!(stats.env_steps % 4, 0);
        assert!(stats.env_steps >= 200, "steps {}", stats.env_steps);
        assert!(stats.episodes > 3, "episodes {}", stats.episodes);
        assert!(replay.len() > 0);
        assert_eq!(
            metrics.counter("actor.env_steps").get(),
            stats.env_steps
        );
    }

    #[test]
    fn obs_len_mismatch_is_rejected() {
        let (mut cfg, dims) = test_cfg();
        cfg.env.frame_stack = 2; // obs_len becomes 200 != dims.obs_len 400
        let replay = Arc::new(SequenceReplay::new(ReplayConfig::default()));
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 3)));
        let r = run_actor(ActorArgs {
            id: 0,
            cfg,
            dims,
            path: PolicyPath::Local(backend),
            replay,
            metrics: Registry::new(),
            shutdown: ShutdownToken::new(),
        });
        assert!(r.is_err());
    }
}
