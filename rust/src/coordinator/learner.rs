//! Learner loop: sample prioritized sequences, run the AOT train step,
//! refresh priorities, periodically sync the target network.
//!
//! The loop is split-phase, mirroring the `policy` layer's submit/wait
//! design on the trainer side (SRL's disaggregated trainer data path;
//! GA3C's trainer queue at single-node scale). At
//! `prefetch_depth >= 2` a prefetch thread samples and assembles batch
//! k+1 into pooled `TrainBatch` buffers while the backend trains batch
//! k, and the priority write-back for batch k−1 rides back to the
//! prefetch thread — off the train critical path — so the accelerator
//! no longer idles during the CPU-side sample/assemble/update phases.
//! `prefetch_depth = 1` is the seed's fully serialized
//! sample → assemble → train → write-back loop, reproduced bit-for-bit
//! (same RNG stream, same sampled slots, same loss curve; asserted
//! against a verbatim seed-learner replica in
//! `tests/coordinator_e2e.rs`).
//!
//! Pipelining trades priority freshness for overlap: batch k+1 is
//! sampled under priorities as of batch k−1 (one train step staler than
//! the serialized loop), the standard Ape-X/R2D2 relaxation.
//!
//! Both paths sample through the borrow-visiting
//! [`SequenceReplay::sample_into`]: rows copy into the (pooled) train
//! batch under the owning shard's lock, so the sample path takes no
//! `Arc` handles at all — no refcount churn per row, and an evicted
//! buffer recycles to the actors' sequence builders the moment the ring
//! overwrites it, since the replay's own reference is the only one
//! (DESIGN.md §8). The sample path is allocation-free at steady state,
//! hard-asserted by the counting-allocator gate in `micro_replay`.

use crate::config::LearnerConfig;
use crate::exec::ShutdownToken;
use crate::metrics::{Counter, Gauge, Registry, Timer};
use crate::replay::SequenceReplay;
use crate::runtime::{Backend, ModelDims, TrainBatch, TrainReply};
use crate::telemetry::{SpanKind, SpanRecorder};
use crate::util::prng::Pcg32;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Summary of a learner run.
#[derive(Clone, Debug, Default)]
pub struct LearnerStats {
    pub steps: u64,
    pub final_loss: f32,
    pub first_loss: f32,
    pub mean_loss: f64,
    pub target_syncs: u64,
    /// Loss curve sampled every `loss_every` steps.
    pub loss_curve: Vec<(u64, f32)>,
}

/// Test/diagnostic probe: called with the global replay slot ids of
/// every batch actually trained, in train order (the pipeline
/// equivalence tests compare these across prefetch depths).
pub type BatchProbe = Box<dyn FnMut(&[usize]) + Send>;

pub struct LearnerArgs {
    pub cfg: LearnerConfig,
    pub dims: ModelDims,
    pub backend: Backend,
    pub replay: Arc<SequenceReplay>,
    pub metrics: Registry,
    pub shutdown: ShutdownToken,
    /// Record a loss-curve point every N steps.
    pub loss_every: u64,
    pub seed: u64,
    /// Optional probe over each trained batch's sampled slots.
    pub on_batch: Option<BatchProbe>,
}

/// Reset a `TrainBatch` buffer for `b` sequences of length
/// `dims.seq_len`, keeping whatever capacity it already holds. Rows are
/// then appended one at a time with [`assemble_push`] — the shape the
/// borrow-sampling [`SequenceReplay::sample_into`] visit path needs.
pub fn assemble_begin(batch: &mut TrainBatch, b: usize, dims: &ModelDims) {
    let t = dims.seq_len;
    batch.batch = b;
    batch.obs.clear();
    batch.obs.reserve(b * t * dims.obs_len);
    batch.actions.clear();
    batch.actions.reserve(b * t);
    batch.rewards.clear();
    batch.rewards.reserve(b * t);
    batch.discounts.clear();
    batch.discounts.reserve(b * t);
    batch.h0.clear();
    batch.h0.reserve(b * dims.hidden);
    batch.c0.clear();
    batch.c0.reserve(b * dims.hidden);
}

/// Append one sequence's rows to a batch begun with [`assemble_begin`]
/// (batch-major layout, matching the AOT ABI). Allocation-free once the
/// buffer has reached shape.
pub fn assemble_push(batch: &mut TrainBatch, seq: &crate::rl::Sequence, dims: &ModelDims) {
    debug_assert_eq!(seq.seq_len(), dims.seq_len, "sequence length mismatch");
    batch.obs.extend_from_slice(&seq.obs);
    batch.actions.extend_from_slice(&seq.actions);
    batch.rewards.extend_from_slice(&seq.rewards);
    batch.discounts.extend_from_slice(&seq.discounts);
    batch.h0.extend_from_slice(&seq.h0);
    batch.c0.extend_from_slice(&seq.c0);
}

/// Assemble a `TrainBatch` from sampled sequences into a caller-owned
/// (pooled) buffer, reusing whatever capacity it already holds
/// (batch-major layout, matching the AOT ABI).
pub fn assemble_into<S: std::ops::Deref<Target = crate::rl::Sequence>>(
    batch: &mut TrainBatch,
    sequences: &[S],
    dims: &ModelDims,
) {
    assemble_begin(batch, sequences.len(), dims);
    for seq in sequences {
        assemble_push(batch, seq, dims);
    }
}

/// Assemble a `TrainBatch` from sampled sequences into a fresh buffer
/// (convenience wrapper over [`assemble_into`]).
pub fn assemble_batch<S: std::ops::Deref<Target = crate::rl::Sequence>>(
    sequences: &[S],
    dims: &ModelDims,
) -> TrainBatch {
    let mut batch = TrainBatch::empty();
    assemble_into(&mut batch, sequences, dims);
    batch
}

/// Loss/step bookkeeping shared by the serial and pipelined paths.
#[derive(Default)]
struct Book {
    stats: LearnerStats,
    loss_sum: f64,
    /// Whether any train step has completed — tracked explicitly so a
    /// genuine first loss of 0.0 is not silently overwritten (the old
    /// `first_loss == 0.0` sentinel bug).
    first_seen: bool,
}

impl Book {
    fn observe(&mut self, reply: &TrainReply, loss_every: u64) {
        self.stats.steps = reply.step;
        if !self.first_seen {
            self.first_seen = true;
            self.stats.first_loss = reply.loss;
        }
        self.stats.final_loss = reply.loss;
        self.loss_sum += reply.loss as f64;
        if loss_every > 0 && self.stats.steps % loss_every == 0 {
            self.stats.loss_curve.push((self.stats.steps, reply.loss));
        }
    }
}

/// A sampled + assembled batch waiting for the train step.
struct Prefetched {
    batch: TrainBatch,
    slots: Vec<usize>,
    generations: Vec<u64>,
}

/// A completed train step's priority refresh, riding back to the
/// prefetch thread (with the batch buffer, which returns to the pool).
struct WriteBack {
    slots: Vec<usize>,
    generations: Vec<u64>,
    priorities: Vec<f32>,
    pool: TrainBatch,
}

/// Everything both learner paths need; keeps the helpers below at sane
/// arities.
struct LearnerCtx {
    cfg: LearnerConfig,
    dims: ModelDims,
    backend: Backend,
    replay: Arc<SequenceReplay>,
    shutdown: ShutdownToken,
    loss_every: u64,
    seed: u64,
    steps_c: Counter,
    waits_c: Counter,
    train_time: Timer,
    sample_time: Timer,
    assemble_time: Timer,
    occupancy_g: Gauge,
    loss_gauge: Gauge,
    /// Registry handle kept so the prefetch thread can mint its own
    /// span recorder (recorders are single-writer, one per thread).
    metrics: Registry,
    trace: SpanRecorder,
}

impl LearnerCtx {
    fn record(&self, book: &mut Book, reply: &TrainReply) -> anyhow::Result<()> {
        book.observe(reply, self.loss_every);
        self.loss_gauge.set(reply.loss as f64);
        self.steps_c.inc();
        if book.stats.steps % self.cfg.target_update_interval as u64 == 0 {
            self.backend.sync_target()?;
            book.stats.target_syncs += 1;
        }
        Ok(())
    }

    /// The seed's serialized loop: sample → assemble → train →
    /// write-back, strictly in sequence (one reused batch buffer).
    ///
    /// Sampling and assembly are fused through
    /// [`SequenceReplay::sample_into`]: each drawn sequence is copied
    /// into the batch as a borrow pinned under its shard lock — no
    /// `Arc` clone/release churn per row, no handle vec, and (scratch +
    /// slot/generation vecs reused) no steady-state allocation on the
    /// sample path (hard-asserted in `micro_replay --quick`). The
    /// `learner.sample_seconds` / `learner.assemble_seconds` split is
    /// preserved by subtracting the measured in-visit assembly time.
    fn run_serial(
        &self,
        book: &mut Book,
        on_batch: &mut Option<BatchProbe>,
    ) -> anyhow::Result<()> {
        let mut rng = Pcg32::seeded(self.seed ^ 0x1EA8);
        let mut pool = TrainBatch::empty();
        let mut scratch = crate::replay::SampleScratch::new();
        let mut slots: Vec<usize> = Vec::new();
        let mut generations: Vec<u64> = Vec::new();
        while book.stats.steps < self.cfg.max_steps as u64
            && !self.shutdown.is_signalled()
        {
            let b = self.cfg.train_batch;
            let t0 = std::time::Instant::now();
            let mut t_asm = 0.0f64;
            let ok = {
                let _sp = self.trace.span(SpanKind::ReplaySample);
                let (pool, dims, t_asm) = (&mut pool, &self.dims, &mut t_asm);
                self.replay.sample_into(
                    b,
                    &mut rng,
                    &mut scratch,
                    &mut slots,
                    &mut generations,
                    |row, seq| {
                        let ta = std::time::Instant::now();
                        if row == 0 {
                            assemble_begin(pool, b, dims);
                        }
                        assemble_push(pool, seq, dims);
                        *t_asm += ta.elapsed().as_secs_f64();
                    },
                )
            };
            if !ok {
                self.sample_time.record(t0.elapsed().as_secs_f64());
                self.waits_c.inc();
                if self.shutdown.sleep_interruptible(Duration::from_millis(1)) {
                    break;
                }
                continue;
            }
            self.assemble_time.record(t_asm);
            self.sample_time
                .record((t0.elapsed().as_secs_f64() - t_asm).max(0.0));
            let reply = {
                let _sp = self.trace.span(SpanKind::LearnerTrain);
                self.train_time.time(|| self.backend.train_step(&mut pool))
            }?;
            self.replay
                .update_priorities(&slots, &generations, &reply.priorities);
            if let Some(probe) = on_batch.as_mut() {
                probe(&slots);
            }
            self.record(book, &reply)?;
        }
        Ok(())
    }

    /// The split-phase pipeline: a prefetch thread samples + assembles
    /// ahead (bounded at `prefetch_depth - 1` batches in flight beyond
    /// the one training) and applies priority write-backs between
    /// samples, while this thread runs back-to-back train steps.
    fn run_pipelined(
        &self,
        book: &mut Book,
        on_batch: &mut Option<BatchProbe>,
    ) -> anyhow::Result<()> {
        // Rendezvous at depth 2: the prefetcher finishes assembling
        // batch k+1 during train k and hands it over the moment train
        // k+1 is wanted. Deeper pipelines buffer depth-2 extra batches.
        let (ready_tx, ready_rx) =
            mpsc::sync_channel::<Prefetched>(self.cfg.prefetch_depth.saturating_sub(2));
        let (back_tx, back_rx) = mpsc::channel::<WriteBack>();
        let stop = AtomicBool::new(false);
        let stop_ref = &stop;
        std::thread::scope(|s| -> anyhow::Result<()> {
            let prefetcher = s.spawn({
                let replay = self.replay.clone();
                let shutdown = self.shutdown.clone();
                let sample_time = self.sample_time.clone();
                let assemble_time = self.assemble_time.clone();
                let waits_c = self.waits_c.clone();
                let train_batch = self.cfg.train_batch;
                let dims = self.dims;
                let seed = self.seed;
                let trace = self
                    .metrics
                    .span_recorder(format_args!("learner-prefetch"));
                move || -> mpsc::Receiver<WriteBack> {
                    let mut rng = Pcg32::seeded(seed ^ 0x1EA8);
                    // Recycled (batch, slots, generations) buffer sets:
                    // write-backs return them, hand-offs take them, so
                    // the steady-state prefetch loop allocates nothing.
                    let mut free: Vec<(TrainBatch, Vec<usize>, Vec<u64>)> =
                        Vec::new();
                    let mut scratch = crate::replay::SampleScratch::new();
                    while !stop_ref.load(Ordering::Relaxed)
                        && !shutdown.is_signalled()
                    {
                        // Apply completed write-backs off the train
                        // critical path, reclaiming their buffers.
                        while let Ok(wb) = back_rx.try_recv() {
                            replay.update_priorities(
                                &wb.slots,
                                &wb.generations,
                                &wb.priorities,
                            );
                            free.push((wb.pool, wb.slots, wb.generations));
                        }
                        let (mut batch, mut slots, mut generations) =
                            free.pop().unwrap_or_else(|| {
                                (TrainBatch::empty(), Vec::new(), Vec::new())
                            });
                        // Fused sample + assemble: rows copy into the
                        // batch as borrows under the shard lock (see
                        // run_serial; same timer attribution).
                        let t0 = std::time::Instant::now();
                        let mut t_asm = 0.0f64;
                        let ok = {
                            let _sp = trace.span(SpanKind::ReplaySample);
                            let (batch, t_asm) = (&mut batch, &mut t_asm);
                            replay.sample_into(
                                train_batch,
                                &mut rng,
                                &mut scratch,
                                &mut slots,
                                &mut generations,
                                |row, seq| {
                                    let ta = std::time::Instant::now();
                                    if row == 0 {
                                        assemble_begin(batch, train_batch, &dims);
                                    }
                                    assemble_push(batch, seq, &dims);
                                    *t_asm += ta.elapsed().as_secs_f64();
                                },
                            )
                        };
                        if !ok {
                            sample_time.record(t0.elapsed().as_secs_f64());
                            free.push((batch, slots, generations));
                            waits_c.inc();
                            if shutdown
                                .sleep_interruptible(Duration::from_millis(1))
                            {
                                break;
                            }
                            continue;
                        }
                        assemble_time.record(t_asm);
                        sample_time.record(
                            (t0.elapsed().as_secs_f64() - t_asm).max(0.0),
                        );
                        let handoff = Prefetched {
                            batch,
                            slots,
                            generations,
                        };
                        if ready_tx.send(handoff).is_err() {
                            break; // train side exited
                        }
                    }
                    back_rx
                }
            });

            let mut train_err: Option<anyhow::Error> = None;
            let (mut hits, mut total) = (0u64, 0u64);
            while book.stats.steps < self.cfg.max_steps as u64
                && !self.shutdown.is_signalled()
            {
                total += 1;
                let pf = match ready_rx.try_recv() {
                    Ok(pf) => {
                        // The next batch was already assembled when the
                        // backend wanted it: the pipeline kept up.
                        hits += 1;
                        Some(pf)
                    }
                    Err(mpsc::TryRecvError::Empty) => loop {
                        match ready_rx.recv_timeout(Duration::from_millis(20)) {
                            Ok(pf) => break Some(pf),
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                if self.shutdown.is_signalled() {
                                    break None;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                break None
                            }
                        }
                    },
                    Err(mpsc::TryRecvError::Disconnected) => None,
                };
                self.occupancy_g.set(hits as f64 / total as f64);
                let Some(mut pf) = pf else { break };
                let trained = {
                    let _sp = self.trace.span(SpanKind::LearnerTrain);
                    self.train_time.time(|| self.backend.train_step(&mut pf.batch))
                };
                match trained {
                    Ok(reply) => {
                        if let Some(probe) = on_batch.as_mut() {
                            probe(&pf.slots);
                        }
                        let recorded = self.record(book, &reply);
                        let _ = back_tx.send(WriteBack {
                            slots: pf.slots,
                            generations: pf.generations,
                            priorities: reply.priorities,
                            pool: pf.batch,
                        });
                        if let Err(e) = recorded {
                            train_err = Some(e);
                            break;
                        }
                    }
                    Err(e) => {
                        train_err = Some(e);
                        break;
                    }
                }
            }

            stop.store(true, Ordering::Relaxed);
            // Dropping the ready side releases a prefetcher blocked on
            // the bounded hand-off.
            drop(ready_rx);
            drop(back_tx);
            let back_rx = prefetcher.join().expect("prefetch thread panicked");
            // Write-backs still in flight apply now; anything racing a
            // slot overwrite is dropped by the generation tags.
            while let Ok(wb) = back_rx.try_recv() {
                self.replay.update_priorities(
                    &wb.slots,
                    &wb.generations,
                    &wb.priorities,
                );
            }
            match train_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })
    }
}

/// Run the learner until `cfg.max_steps` or shutdown. Returns stats and
/// signals `shutdown` on exit so actors stop with it.
pub fn run_learner(args: LearnerArgs) -> anyhow::Result<LearnerStats> {
    let LearnerArgs {
        cfg,
        dims,
        backend,
        replay,
        metrics,
        shutdown,
        loss_every,
        seed,
        mut on_batch,
    } = args;
    let ctx = LearnerCtx {
        steps_c: metrics.counter("learner.steps"),
        waits_c: metrics.counter("learner.replay_waits"),
        train_time: metrics.timer("learner.train_seconds"),
        sample_time: metrics.timer("learner.sample_seconds"),
        assemble_time: metrics.timer("learner.assemble_seconds"),
        occupancy_g: metrics.gauge("learner.prefetch_occupancy"),
        loss_gauge: metrics.gauge("learner.loss"),
        trace: metrics.span_recorder(format_args!("learner")),
        metrics,
        cfg,
        dims,
        backend,
        replay,
        shutdown,
        loss_every,
        seed,
    };
    let mut book = Book::default();

    // Wait for the minimum replay fill.
    while ctx.replay.len() < ctx.cfg.min_replay {
        ctx.waits_c.inc();
        if ctx.shutdown.sleep_interruptible(Duration::from_millis(2)) {
            return Ok(book.stats);
        }
    }

    if ctx.cfg.prefetch_depth <= 1 {
        ctx.run_serial(&mut book, &mut on_batch)?;
    } else {
        ctx.run_pipelined(&mut book, &mut on_batch)?;
    }

    if book.stats.steps > 0 {
        book.stats.mean_loss = book.loss_sum / book.stats.steps as f64;
    }
    ctx.shutdown.signal();
    Ok(book.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayConfig, SequenceReplay};
    use crate::rl::Sequence;
    use crate::runtime::MockModel;

    fn dims() -> ModelDims {
        ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 5,
            train_batch: 4,
        }
    }

    fn seq(d: &ModelDims, reward: f32) -> Sequence {
        Sequence {
            obs: vec![0.1; d.seq_len * d.obs_len],
            actions: vec![0; d.seq_len],
            rewards: vec![reward; d.seq_len],
            discounts: vec![0.9; d.seq_len],
            h0: vec![0.0; d.hidden],
            c0: vec![0.0; d.hidden],
            actor_id: 0,
            valid_len: d.seq_len,
        }
    }

    #[test]
    fn assemble_batch_layout() {
        let d = dims();
        let seqs = vec![Box::new(seq(&d, 1.0)), Box::new(seq(&d, 2.0))];
        let b = assemble_batch(&seqs, &d);
        assert_eq!(b.batch, 2);
        assert_eq!(b.obs.len(), 2 * 5 * 8);
        assert_eq!(b.rewards[0], 1.0);
        assert_eq!(b.rewards[5], 2.0); // second sequence starts at B-major offset
        b.validate(&ModelDims {
            train_batch: 2,
            ..d
        })
        .unwrap();
    }

    #[test]
    fn assemble_into_reuses_pooled_buffers() {
        let d = dims();
        let seqs = vec![Box::new(seq(&d, 1.0)), Box::new(seq(&d, 2.0))];
        let mut pool = assemble_batch(&seqs, &d);
        let obs_ptr = pool.obs.as_ptr();
        let obs_cap = pool.obs.capacity();
        // Re-assembling the same shape into the pooled buffer must not
        // reallocate the payload vectors.
        assemble_into(&mut pool, &seqs, &d);
        assert_eq!(pool.obs.as_ptr(), obs_ptr);
        assert_eq!(pool.obs.capacity(), obs_cap);
        assert_eq!(pool.batch, 2);
        assert_eq!(pool.rewards[5], 2.0);
    }

    #[test]
    fn first_loss_zero_is_not_overwritten() {
        // Regression: a genuine first loss of 0.0 used to be treated as
        // "not yet seen" and silently replaced by the second loss.
        let mut book = Book::default();
        let reply = |step: u64, loss: f32| TrainReply {
            loss,
            priorities: vec![],
            grad_norm: 1.0,
            step,
        };
        book.observe(&reply(1, 0.0), 0);
        book.observe(&reply(2, 0.5), 0);
        assert_eq!(book.stats.first_loss, 0.0);
        assert_eq!(book.stats.final_loss, 0.5);
    }

    #[test]
    fn learner_runs_to_max_steps_and_signals_shutdown() {
        let d = dims();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        for i in 0..16 {
            replay.add(seq(&d, i as f32));
        }
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 5)));
        let shutdown = ShutdownToken::new();
        let cfg = LearnerConfig {
            train_batch: 4,
            min_replay: 8,
            max_steps: 25,
            target_update_interval: 10,
            ..Default::default()
        };
        let stats = run_learner(LearnerArgs {
            cfg,
            dims: d,
            backend,
            replay,
            metrics: Registry::new(),
            shutdown: shutdown.clone(),
            loss_every: 5,
            seed: 0,
            on_batch: None,
        })
        .unwrap();
        assert_eq!(stats.steps, 25);
        assert_eq!(stats.target_syncs, 2);
        assert!(stats.final_loss < stats.first_loss);
        assert_eq!(stats.loss_curve.len(), 5);
        assert!(shutdown.is_signalled());
    }

    #[test]
    fn pipelined_learner_runs_to_max_steps() {
        for depth in [2usize, 3] {
            let d = dims();
            let replay = Arc::new(SequenceReplay::new(ReplayConfig {
                capacity: 64,
                shards: 2,
                ..Default::default()
            }));
            for i in 0..16 {
                replay.add(seq(&d, i as f32));
            }
            let backend = Backend::Mock(Arc::new(MockModel::new(d, 5)));
            let shutdown = ShutdownToken::new();
            let cfg = LearnerConfig {
                train_batch: 4,
                min_replay: 8,
                max_steps: 25,
                target_update_interval: 10,
                prefetch_depth: depth,
                ..Default::default()
            };
            let metrics = Registry::new();
            let stats = run_learner(LearnerArgs {
                cfg,
                dims: d,
                backend,
                replay,
                metrics: metrics.clone(),
                shutdown: shutdown.clone(),
                loss_every: 5,
                seed: 0,
                on_batch: None,
            })
            .unwrap();
            assert_eq!(stats.steps, 25, "depth={depth}");
            assert_eq!(stats.target_syncs, 2, "depth={depth}");
            assert_eq!(stats.loss_curve.len(), 5, "depth={depth}");
            assert!(shutdown.is_signalled());
            let occ = metrics.gauge("learner.prefetch_occupancy").get();
            assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
            assert!(
                metrics.timer("learner.assemble_seconds").snapshot().count()
                    >= 25
            );
        }
    }

    #[test]
    fn pipelined_learner_propagates_train_failure() {
        let d = dims();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        for i in 0..16 {
            replay.add(seq(&d, i as f32));
        }
        let backend = Backend::Mock(Arc::new(
            MockModel::new(d, 5).with_train_error("injected train fault"),
        ));
        let cfg = LearnerConfig {
            train_batch: 4,
            min_replay: 8,
            max_steps: 25,
            prefetch_depth: 2,
            ..Default::default()
        };
        let err = run_learner(LearnerArgs {
            cfg,
            dims: d,
            backend,
            replay,
            metrics: Registry::new(),
            shutdown: ShutdownToken::new(),
            loss_every: 0,
            seed: 0,
            on_batch: None,
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("injected train fault"), "got: {err}");
    }

    #[test]
    fn learner_waits_for_min_replay() {
        let d = dims();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 6)));
        let shutdown = ShutdownToken::new();
        let metrics = Registry::new();
        let cfg = LearnerConfig {
            train_batch: 4,
            min_replay: 8,
            max_steps: 5,
            ..Default::default()
        };
        let stats = std::thread::scope(|s| {
            let h = s.spawn({
                let replay = replay.clone();
                let shutdown = shutdown.clone();
                let metrics = metrics.clone();
                move || {
                    run_learner(LearnerArgs {
                        cfg,
                        dims: d,
                        backend,
                        replay,
                        metrics,
                        shutdown,
                        loss_every: 0,
                        seed: 1,
                        on_batch: None,
                    })
                    .unwrap()
                }
            });
            std::thread::sleep(Duration::from_millis(30));
            for i in 0..12 {
                replay.add(seq(&d, i as f32));
            }
            h.join().unwrap()
        });
        assert_eq!(stats.steps, 5);
        assert!(metrics.counter("learner.replay_waits").get() > 0);
    }
}
