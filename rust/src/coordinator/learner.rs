//! Learner loop: sample prioritized sequences, run the AOT train step,
//! refresh priorities, periodically sync the target network.

use crate::config::LearnerConfig;
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::replay::SequenceReplay;
use crate::runtime::{Backend, ModelDims, TrainBatch};
use crate::util::prng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

/// Summary of a learner run.
#[derive(Clone, Debug, Default)]
pub struct LearnerStats {
    pub steps: u64,
    pub final_loss: f32,
    pub first_loss: f32,
    pub mean_loss: f64,
    pub target_syncs: u64,
    /// Loss curve sampled every `loss_every` steps.
    pub loss_curve: Vec<(u64, f32)>,
}

pub struct LearnerArgs {
    pub cfg: LearnerConfig,
    pub dims: ModelDims,
    pub backend: Backend,
    pub replay: Arc<SequenceReplay>,
    pub metrics: Registry,
    pub shutdown: ShutdownToken,
    /// Record a loss-curve point every N steps.
    pub loss_every: u64,
    pub seed: u64,
}

/// Assemble a `TrainBatch` from sampled sequences (batch-major layout,
/// matching the AOT ABI).
pub fn assemble_batch<S: std::ops::Deref<Target = crate::rl::Sequence>>(
    sequences: &[S],
    dims: &ModelDims,
) -> TrainBatch {
    let b = sequences.len();
    let t = dims.seq_len;
    let mut batch = TrainBatch {
        batch: b,
        obs: Vec::with_capacity(b * t * dims.obs_len),
        actions: Vec::with_capacity(b * t),
        rewards: Vec::with_capacity(b * t),
        discounts: Vec::with_capacity(b * t),
        h0: Vec::with_capacity(b * dims.hidden),
        c0: Vec::with_capacity(b * dims.hidden),
    };
    for seq in sequences {
        let seq: &crate::rl::Sequence = seq;
        debug_assert_eq!(seq.seq_len(), t, "sequence length mismatch");
        batch.obs.extend_from_slice(&seq.obs);
        batch.actions.extend_from_slice(&seq.actions);
        batch.rewards.extend_from_slice(&seq.rewards);
        batch.discounts.extend_from_slice(&seq.discounts);
        batch.h0.extend_from_slice(&seq.h0);
        batch.c0.extend_from_slice(&seq.c0);
    }
    batch
}

/// Run the learner until `cfg.max_steps` or shutdown. Returns stats and
/// signals `shutdown` on exit so actors stop with it.
pub fn run_learner(args: LearnerArgs) -> anyhow::Result<LearnerStats> {
    let LearnerArgs {
        cfg,
        dims,
        backend,
        replay,
        metrics,
        shutdown,
        loss_every,
        seed,
    } = args;
    let mut rng = Pcg32::seeded(seed ^ 0x1EA8);
    let steps_c = metrics.counter("learner.steps");
    let waits_c = metrics.counter("learner.replay_waits");
    let train_time = metrics.timer("learner.train_seconds");
    let sample_time = metrics.timer("learner.sample_seconds");
    let loss_gauge = metrics.gauge("learner.loss");

    let mut stats = LearnerStats::default();
    let mut loss_sum = 0.0f64;

    // Wait for the minimum replay fill.
    while replay.len() < cfg.min_replay {
        waits_c.inc();
        if shutdown.sleep_interruptible(Duration::from_millis(2)) {
            return Ok(stats);
        }
    }

    while stats.steps < cfg.max_steps as u64 && !shutdown.is_signalled() {
        let sampled = sample_time.time(|| replay.sample(cfg.train_batch, &mut rng));
        let Some(sampled) = sampled else {
            waits_c.inc();
            if shutdown.sleep_interruptible(Duration::from_millis(1)) {
                break;
            }
            continue;
        };
        let batch = assemble_batch(&sampled.sequences, &dims);
        let reply = train_time.time(|| backend.train(batch))?;
        replay.update_priorities(&sampled.slots, &reply.priorities);

        stats.steps = reply.step;
        if stats.first_loss == 0.0 {
            stats.first_loss = reply.loss;
        }
        stats.final_loss = reply.loss;
        loss_sum += reply.loss as f64;
        loss_gauge.set(reply.loss as f64);
        steps_c.inc();
        if loss_every > 0 && stats.steps % loss_every == 0 {
            stats.loss_curve.push((stats.steps, reply.loss));
        }

        if stats.steps % cfg.target_update_interval as u64 == 0 {
            backend.sync_target()?;
            stats.target_syncs += 1;
        }
    }

    if stats.steps > 0 {
        stats.mean_loss = loss_sum / stats.steps as f64;
    }
    shutdown.signal();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayConfig, SequenceReplay};
    use crate::rl::Sequence;
    use crate::runtime::MockModel;

    fn dims() -> ModelDims {
        ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 5,
            train_batch: 4,
        }
    }

    fn seq(d: &ModelDims, reward: f32) -> Sequence {
        Sequence {
            obs: vec![0.1; d.seq_len * d.obs_len],
            actions: vec![0; d.seq_len],
            rewards: vec![reward; d.seq_len],
            discounts: vec![0.9; d.seq_len],
            h0: vec![0.0; d.hidden],
            c0: vec![0.0; d.hidden],
            actor_id: 0,
            valid_len: d.seq_len,
        }
    }

    #[test]
    fn assemble_batch_layout() {
        let d = dims();
        let seqs = vec![Box::new(seq(&d, 1.0)), Box::new(seq(&d, 2.0))];
        let b = assemble_batch(&seqs, &d);
        assert_eq!(b.batch, 2);
        assert_eq!(b.obs.len(), 2 * 5 * 8);
        assert_eq!(b.rewards[0], 1.0);
        assert_eq!(b.rewards[5], 2.0); // second sequence starts at B-major offset
        b.validate(&ModelDims {
            train_batch: 2,
            ..d
        })
        .unwrap();
    }

    #[test]
    fn learner_runs_to_max_steps_and_signals_shutdown() {
        let d = dims();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        for i in 0..16 {
            replay.add(seq(&d, i as f32));
        }
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 5)));
        let shutdown = ShutdownToken::new();
        let cfg = LearnerConfig {
            train_batch: 4,
            min_replay: 8,
            max_steps: 25,
            target_update_interval: 10,
            ..Default::default()
        };
        let stats = run_learner(LearnerArgs {
            cfg,
            dims: d,
            backend,
            replay,
            metrics: Registry::new(),
            shutdown: shutdown.clone(),
            loss_every: 5,
            seed: 0,
        })
        .unwrap();
        assert_eq!(stats.steps, 25);
        assert_eq!(stats.target_syncs, 2);
        assert!(stats.final_loss < stats.first_loss);
        assert_eq!(stats.loss_curve.len(), 5);
        assert!(shutdown.is_signalled());
    }

    #[test]
    fn learner_waits_for_min_replay() {
        let d = dims();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 6)));
        let shutdown = ShutdownToken::new();
        let metrics = Registry::new();
        let cfg = LearnerConfig {
            train_batch: 4,
            min_replay: 8,
            max_steps: 5,
            ..Default::default()
        };
        let stats = std::thread::scope(|s| {
            let h = s.spawn({
                let replay = replay.clone();
                let shutdown = shutdown.clone();
                let metrics = metrics.clone();
                move || {
                    run_learner(LearnerArgs {
                        cfg,
                        dims: d,
                        backend,
                        replay,
                        metrics,
                        shutdown,
                        loss_every: 0,
                        seed: 1,
                    })
                    .unwrap()
                }
            });
            std::thread::sleep(Duration::from_millis(30));
            for i in 0..12 {
                replay.add(seq(&d, i as f32));
            }
            h.join().unwrap()
        });
        assert_eq!(stats.steps, 5);
        assert!(metrics.counter("learner.replay_waits").get() > 0);
    }
}
