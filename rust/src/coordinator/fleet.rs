//! Fleet orchestration: the multi-process deployment of the same
//! SEED-style dataflow `run()` wires in one process (DESIGN.md §14).
//!
//! [`run_serve`] is the coordinator process (`rlarch serve`): the
//! backend, batcher, replay, and learner live here, exactly as in
//! [`super::run`], but instead of spawning actor threads it spawns a
//! [`FleetServer`] that multiplexes remote actor connections into the
//! batcher and remote sequence streams into the replay. [`run_worker`]
//! is an actor process (`rlarch actor --connect`): it runs the
//! unmodified [`actor::run_actor`] loop over a [`RemoteClient`] policy
//! and a [`RemoteIngest`] sink — the split-phase `PolicyClient` trait
//! and the `SequenceSink` seam are the only process boundary.
//!
//! ```text
//!  worker 0..W      (TCP / UDS)          coordinator
//!  actors ──submit──► RemoteClient ═══► FleetServer ──► batcher ──► Backend
//!     ▲                                      │                         │
//!     └──── wait ◄── reply chunks ◄══════════┴── slot-addressed ◄──────┘
//!  actors ──sequences──► RemoteIngest ═══► serve_ingest ──► SequenceReplay
//!                                                              ▲
//!                                                    learner ──┘ (train)
//! ```
//!
//! Determinism: a loopback fleet with the same seeds, the same
//! fleet-global actor-id layout (`id_base` partitioning
//! `cfg.actors.num_actors`), and the same backend produces the same
//! replay stream as the in-process central path — inference is
//! deterministic, the wire preserves f32 bits, and every actor derives
//! its RNG and epsilon from its fleet-global id
//! (`tests/transport_fleet.rs`).

use super::batcher::Batcher;
use super::{actor, learner, weighted_mean_return, ActorStats, LearnerStats};
use crate::config::{InferenceMode, SystemConfig};
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::policy::PolicyClient;
use crate::replay::{ReplayConfig, SequenceReplay, SequenceSink};
use crate::rl::SequencePool;
use crate::runtime::{Backend, ModelDims};
use crate::telemetry::Telemetry;
use crate::transport::{
    Addr, FleetServer, FleetServerOpts, Listener, RemoteClient, RemoteClientOpts,
    RemoteIngest,
};
use std::sync::Arc;
use std::time::Instant;

/// Outcome of a coordinator (`serve`) run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub learner: LearnerStats,
    pub elapsed_seconds: f64,
    /// Sequences committed to replay (all of them arrived by wire).
    pub sequences: u64,
    /// Connections accepted over the run (infer + ingest).
    pub accepts: u64,
    /// Connections that died mid-stream (no goodbye).
    pub disconnects: u64,
    /// Accepts that followed a death: workers coming back.
    pub reconnects: u64,
    /// Rows shed by per-connection backpressure.
    pub shed_rows: u64,
    pub inference_batches: u64,
    pub mean_batch_occupancy: f64,
    pub batcher_errors: u64,
}

/// Outcome of a worker (`actor --connect`) run.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub actors: Vec<ActorStats>,
    pub elapsed_seconds: f64,
    pub env_steps: u64,
    pub episodes: u64,
    pub mean_return: f64,
    /// First actor failure, if any. A worker whose server drained
    /// cleanly reports the goodbye here for actors that were mid-`wait`
    /// when it landed; callers treat it as informational when
    /// `env_steps > 0` and the shutdown was server-initiated.
    pub first_error: Option<String>,
}

/// Run the coordinator side of a fleet: backend + batcher + replay +
/// learner in this process, remote actors over `cfg.fleet.listen`.
///
/// Blocks until the learner completes `cfg.learner.max_steps` steps,
/// then drains: the fleet server flushes every outstanding reply, sends
/// `Goodbye` on each connection (the workers' shutdown signal), and
/// closes before the batcher is joined.
pub fn run_serve(
    cfg: &SystemConfig,
    backend: Backend,
    metrics: Registry,
) -> anyhow::Result<ServeReport> {
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("config: {e}"))?;
    anyhow::ensure!(
        !cfg.fleet.listen.is_empty(),
        "fleet.listen is empty: nothing to serve on (set [fleet] listen or --listen)"
    );
    anyhow::ensure!(
        cfg.mode == InferenceMode::Central,
        "fleet serving requires mode = \"central\" (remote actors share the batcher)"
    );
    let dims = backend.dims();
    anyhow::ensure!(
        dims.seq_len == cfg.learner.seq_len(),
        "learner seq_len {} != model seq_len {} (burn_in+unroll must match the AOT graph)",
        cfg.learner.seq_len(),
        dims.seq_len
    );
    anyhow::ensure!(
        dims.train_batch == cfg.learner.train_batch,
        "learner train_batch {} != model train_batch {}",
        cfg.learner.train_batch,
        dims.train_batch
    );
    let listener = Listener::bind(&Addr::parse(&cfg.fleet.listen)?)?;

    let pool = cfg.replay.pool.then(|| Arc::new(SequencePool::new()));
    let mut replay = SequenceReplay::new(ReplayConfig::from(&cfg.replay));
    if let Some(p) = &pool {
        replay = replay.with_pool(p.clone());
    }
    let replay = Arc::new(replay);
    let shutdown = ShutdownToken::new();

    let telemetry = Telemetry::from_config(&cfg.telemetry);
    telemetry.install(&metrics);
    let sampler = telemetry.start_sampler(&metrics)?;

    let t0 = Instant::now();
    let (batcher, handle) = Batcher::spawn(cfg.batcher.clone(), backend.clone(), metrics.clone());
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        replay.clone(),
        FleetServerOpts {
            max_inflight_rows: cfg.fleet.max_inflight_rows,
            insert_batch: cfg.replay.insert_batch,
        },
        metrics.clone(),
        shutdown.clone(),
    );

    // The learner runs on this thread; data arrives by wire.
    let learner_result = learner::run_learner(learner::LearnerArgs {
        cfg: cfg.learner.clone(),
        dims,
        backend: backend.clone(),
        replay: replay.clone(),
        metrics: metrics.clone(),
        shutdown: shutdown.clone(),
        loss_every: 10,
        seed: cfg.seed,
        on_batch: None,
    });
    // run_learner signals shutdown on its happy path; a train failure
    // must still drain the fleet before this function returns.
    shutdown.signal();

    // Drain order matters: the server's writers must flush outstanding
    // reply chunks (they hold ReplyRange borrows of batcher output
    // slabs) and say goodbye before the batcher can be joined.
    server.join();
    drop(handle);
    batcher.join();

    let elapsed = t0.elapsed().as_secs_f64();
    metrics
        .counter("replay.shard_contention")
        .add(replay.shard_contention());
    metrics
        .counter("replay.lock_acquisitions")
        .add(replay.lock_acquisitions());
    if let Some(p) = &pool {
        metrics.gauge("actor.pool_hit_rate").set(p.hit_rate());
    }
    if let Some(s) = sampler {
        s.stop()?;
    }
    telemetry.write_trace()?;

    let batches = metrics.counter("batcher.batches").get();
    let items = metrics.counter("batcher.items").get();
    Ok(ServeReport {
        learner: learner_result?,
        elapsed_seconds: elapsed,
        sequences: replay.inserts(),
        accepts: metrics.counter("fleet.accepts").get(),
        disconnects: metrics.counter("fleet.disconnects").get(),
        reconnects: metrics.counter("fleet.reconnects").get(),
        shed_rows: metrics.counter("fleet.shed_rows").get(),
        inference_batches: batches,
        mean_batch_occupancy: if batches > 0 {
            items as f64 / batches as f64
        } else {
            0.0
        },
        batcher_errors: metrics.counter("batcher.errors").get(),
    })
}

/// Run one worker process: `local_actors` actor threads over
/// `cfg.fleet.connect`, with fleet-global ids `id_base ..`.
///
/// `dims` must match the coordinator backend's (the handshake rejects a
/// mismatch); `cfg.actors.num_actors` stays the *fleet-wide* total so
/// every worker derives the same epsilon spectrum and env-seed layout
/// as the in-process run — `id_base` picks this worker's slice of it.
///
/// Actor failures do not abort the report: a server drain lands as a
/// goodbye mid-`wait` in whichever actors were blocked, and the rest
/// exit on the signalled token. The caller decides what a nonzero
/// `first_error` means from `env_steps`.
pub fn run_worker(
    cfg: &SystemConfig,
    dims: ModelDims,
    id_base: usize,
    local_actors: usize,
    max_rounds: Option<u64>,
    metrics: Registry,
) -> anyhow::Result<WorkerReport> {
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("config: {e}"))?;
    anyhow::ensure!(
        !cfg.fleet.connect.is_empty(),
        "fleet.connect is empty: nowhere to connect (set [fleet] connect or --connect)"
    );
    anyhow::ensure!(local_actors > 0, "worker needs at least one actor thread");
    anyhow::ensure!(
        id_base + local_actors <= cfg.actors.num_actors,
        "worker ids {id_base}..{} exceed the fleet-wide actors.num_actors {} \
         (every worker must carve its slice from the same global layout)",
        id_base + local_actors,
        cfg.actors.num_actors
    );
    let addr = Addr::parse(&cfg.fleet.connect)?;
    let opts = RemoteClientOpts {
        connect_retries: cfg.fleet.connect_retries,
        backoff_ms: cfg.fleet.backoff_ms,
    };
    let shutdown = ShutdownToken::new();
    // One ingest connection per worker process, shared by its actors.
    let ingest = Arc::new(RemoteIngest::connect(
        &addr,
        dims,
        &opts,
        &metrics,
        shutdown.clone(),
    )?);

    let t0 = Instant::now();
    let (actor_stats, actor_errors) = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for t in 0..local_actors {
            let id = id_base + t;
            let (addr, cfg, ingest, metrics, shutdown) = (
                &addr,
                cfg.clone(),
                ingest.clone() as Arc<dyn SequenceSink>,
                metrics.clone(),
                shutdown.clone(),
            );
            joins.push(
                std::thread::Builder::new()
                    .name(format!("rlarch-actor-{id}"))
                    .spawn_scoped(s, move || -> anyhow::Result<ActorStats> {
                        let client = RemoteClient::connect(
                            addr,
                            id,
                            dims,
                            opts,
                            &metrics,
                            shutdown.clone(),
                        )?;
                        let policy: Box<dyn PolicyClient> = Box::new(client);
                        actor::run_actor(actor::ActorArgs {
                            id,
                            cfg,
                            dims,
                            policy,
                            replay: ingest,
                            metrics,
                            shutdown,
                            max_rounds,
                        })
                    })
                    .expect("spawn worker actor"),
            );
        }
        let mut stats = Vec::new();
        let mut errors: Vec<String> = Vec::new();
        for j in joins {
            match j.join().expect("actor panicked") {
                Ok(st) => stats.push(st),
                Err(e) => errors.push(e.to_string()),
            }
        }
        (stats, errors)
    });
    // All actors are down: commit the drain marker on the ingest link
    // so the coordinator logs a clean departure.
    ingest.goodbye();

    let env_steps: u64 = actor_stats.iter().map(|a| a.env_steps).sum();
    let episodes: u64 = actor_stats.iter().map(|a| a.episodes).sum();
    Ok(WorkerReport {
        elapsed_seconds: t0.elapsed().as_secs_f64(),
        env_steps,
        episodes,
        mean_return: weighted_mean_return(&actor_stats),
        actors: actor_stats,
        first_error: actor_errors.first().cloned(),
    })
}
