//! Fleet orchestration: the multi-process deployment of the same
//! SEED-style dataflow `run()` wires in one process (DESIGN.md §14;
//! fault tolerance §15).
//!
//! [`run_serve`] is the coordinator process (`rlarch serve`): the
//! backend, batcher, replay, and learner live here, exactly as in
//! [`super::run`], but instead of spawning actor threads it spawns a
//! [`FleetServer`] that multiplexes remote actor connections into the
//! batcher and remote sequence streams into the replay. [`run_worker`]
//! is an actor process (`rlarch actor --connect`): it runs the
//! unmodified [`actor::run_actor`] loop over a [`RemoteClient`] policy
//! and a [`RemoteIngest`] sink — the split-phase `PolicyClient` trait
//! and the `SequenceSink` seam are the only process boundary.
//!
//! ```text
//!  worker 0..W      (TCP / UDS)          coordinator
//!  actors ──submit──► RemoteClient ═══► FleetServer ──► batcher ──► Backend
//!     ▲                                      │                         │
//!     └──── wait ◄── reply chunks ◄══════════┴── slot-addressed ◄──────┘
//!  actors ──sequences──► RemoteIngest ═══► serve_ingest ──► SequenceReplay
//!                                                              ▲
//!                                                    learner ──┘ (train)
//! ```
//!
//! Determinism: a loopback fleet with the same seeds, the same
//! fleet-global actor-id layout (`id_base` partitioning
//! `cfg.actors.num_actors`), and the same backend produces the same
//! replay stream as the in-process central path — inference is
//! deterministic, the wire preserves f32 bits, and every actor derives
//! its RNG and epsilon from its fleet-global id
//! (`tests/transport_fleet.rs`).
//!
//! Fault tolerance (DESIGN.md §15):
//!
//! * **Supervision** — each worker actor thread runs under a
//!   restart-with-budget supervisor: a panic is caught, counted in
//!   `fleet.actor_restarts`, and the actor reconnects and restarts
//!   after an interruptible backoff, up to
//!   `fleet.actor_restart_budget` restarts before it is declared
//!   failed (surfaced in `WorkerReport::first_error`).
//! * **Checkpoint/restore** — with `fleet.checkpoint_dir` set the
//!   coordinator snapshots the learner every `fleet.checkpoint_every`
//!   steps (model step count + params via the mock backend, replay
//!   cursor, config seed) with a write-temp-then-rename protocol, and
//!   resumes from the newest snapshot on restart. Each incarnation
//!   bumps a generation tag carried in the `Hello` handshake, so a
//!   restarted server refuses workers still synced to the previous
//!   incarnation until they resync fresh.
//! * **Fault injection** — an armed `[faults]` plan is threaded into
//!   the server's per-connection readers and the mock backend's stall
//!   seam; all-off (the default) constructs nothing and is bit-for-bit
//!   the plain path.

use super::batcher::Batcher;
use super::{actor, learner, weighted_mean_return, ActorStats, LearnerStats};
use crate::config::{InferenceMode, SystemConfig};
use crate::exec::ShutdownToken;
use crate::fault::FaultPlan;
use crate::metrics::Registry;
use crate::policy::PolicyClient;
use crate::replay::{ReplayConfig, SequenceReplay, SequenceSink};
use crate::rl::SequencePool;
use crate::runtime::{checkpoint, Backend, MockModel, ModelDims, Tensor};
use crate::serve::{control, BreakerState, Command, ControlServer, ServeGate};
use crate::telemetry::Telemetry;
use crate::transport::{
    Addr, ConnRegistry, FleetServer, FleetServerOpts, Listener, RemoteClient,
    RemoteClientOpts, RemoteIngest,
};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Outcome of a coordinator (`serve`) run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub learner: LearnerStats,
    pub elapsed_seconds: f64,
    /// Sequences committed to replay (all of them arrived by wire).
    pub sequences: u64,
    /// Connections accepted over the run (infer + ingest).
    pub accepts: u64,
    /// Connections that died mid-stream (no goodbye).
    pub disconnects: u64,
    /// Accepts that followed a death: workers coming back.
    pub reconnects: u64,
    /// Rows shed by per-connection backpressure.
    pub shed_rows: u64,
    pub inference_batches: u64,
    pub mean_batch_occupancy: f64,
    pub batcher_errors: u64,
    /// Server incarnation (0 = checkpointing off; first checkpointed
    /// run is generation 1, each resume bumps it).
    pub generation: u32,
    /// Learner steps restored from a checkpoint before this run's own
    /// training began.
    pub resumed_steps: u64,
    /// Snapshots written this run (`fleet.checkpoints`).
    pub checkpoints: u64,
    /// Checkpoint hot-reloads served under traffic (`fleet.reloads`).
    pub reloads: u64,
    /// First attributed fleet error (`conn N (<peer>): ...`), if any —
    /// reaps, bad frames, protocol violations, spawn failures.
    pub first_error: Option<String>,
    /// The coordinator-side fault plan's injection ledger, when a
    /// `[faults]` plan was armed — the chaos soak reconciles `fleet.*`
    /// metrics against it (e.g. `bad_frames == truncated + corrupted`).
    pub injected: Option<crate::fault::InjectedFaults>,
}

/// Outcome of a worker (`actor --connect`) run.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub actors: Vec<ActorStats>,
    pub elapsed_seconds: f64,
    pub env_steps: u64,
    pub episodes: u64,
    pub mean_return: f64,
    /// Supervisor restarts across this worker's actors
    /// (`fleet.actor_restarts`).
    pub actor_restarts: u64,
    /// First actor failure, if any. A worker whose server drained
    /// cleanly reports the goodbye here for actors that were mid-`wait`
    /// when it landed; callers treat it as informational when
    /// `env_steps > 0` and the shutdown was server-initiated.
    pub first_error: Option<String>,
}

/// Coordinator snapshot metadata (DESIGN.md §15): a flat `key=value`
/// text file next to the params bundle. Both files are written to a
/// temp name and renamed into place, so a crash mid-snapshot leaves
/// the previous checkpoint intact.
struct FleetCheckpoint {
    generation: u32,
    steps: u64,
    sequences: u64,
    seed: u64,
}

impl FleetCheckpoint {
    fn state_path(dir: &Path) -> PathBuf {
        dir.join("state.kv")
    }

    fn params_path(dir: &Path) -> PathBuf {
        dir.join("params.bin")
    }

    fn load(dir: &Path) -> anyhow::Result<Option<FleetCheckpoint>> {
        let path = Self::state_path(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => anyhow::bail!("read {path:?}: {e}"),
        };
        let mut ck = FleetCheckpoint {
            generation: 0,
            steps: 0,
            sequences: 0,
            seed: 0,
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad checkpoint line `{line}` in {path:?}"))?;
            match k {
                "generation" => ck.generation = v.parse()?,
                "steps" => ck.steps = v.parse()?,
                "sequences" => ck.sequences = v.parse()?,
                "seed" => ck.seed = v.parse()?,
                other => anyhow::bail!("unknown checkpoint key `{other}` in {path:?}"),
            }
        }
        anyhow::ensure!(ck.generation > 0, "checkpoint {path:?} has no generation");
        Ok(Some(ck))
    }

    fn save(&self, dir: &Path, params: &[Tensor]) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let ptmp = dir.join("params.bin.tmp");
        checkpoint::save_params(&ptmp, params)?;
        std::fs::rename(&ptmp, Self::params_path(dir))?;
        let text = format!(
            "generation={}\nsteps={}\nsequences={}\nseed={}\n",
            self.generation, self.steps, self.sequences, self.seed
        );
        let stmp = dir.join("state.kv.tmp");
        std::fs::write(&stmp, text)?;
        std::fs::rename(&stmp, Self::state_path(dir))?;
        Ok(())
    }
}

/// Everything the serving control plane needs, captured once when the
/// control socket is armed (`[serve] control` / `--control`).
struct ControlCtx {
    gate: Arc<ServeGate>,
    gen_cell: Arc<AtomicU32>,
    registry: ConnRegistry,
    mock: Option<Arc<MockModel>>,
    metrics: Registry,
    shutdown: ShutdownToken,
    drain_timeout: Duration,
    cfg_seed: u64,
    replay: Arc<SequenceReplay>,
}

/// Pause admission and wait (bounded) for the in-flight row count to
/// reach zero. Returns the drain duration and whether it ran dry.
fn drain_inflight(
    gate: &ServeGate,
    timeout: Duration,
    shutdown: &ShutdownToken,
) -> (Duration, bool) {
    let t0 = Instant::now();
    gate.set_admitting(false);
    while gate.inflight_rows() > 0 {
        if t0.elapsed() >= timeout {
            return (t0.elapsed(), false);
        }
        if shutdown.sleep_interruptible(Duration::from_millis(2)) {
            break;
        }
    }
    (t0.elapsed(), true)
}

/// Checkpoint hot-reload under traffic (DESIGN.md §16): pause
/// admission, drain in-flight tickets (bounded by
/// `fleet.drain_timeout_ms`; stragglers are force-failed by severing
/// their connections, attributed in `fleet.shed_inflight_rows`), load
/// and verify the snapshot, swap the model step count, bump the
/// generation fence, sever the data conns so every worker resyncs
/// behind it, and resume. The caller restores admission on error.
fn do_reload(ctx: &ControlCtx, dir: &str) -> Result<String, String> {
    let m = ctx
        .mock
        .as_ref()
        .ok_or_else(|| "reload requires the mock backend (params snapshotting)".to_string())?;
    let dir_p = Path::new(dir);
    let (drained, dry) = drain_inflight(&ctx.gate, ctx.drain_timeout, &ctx.shutdown);
    let mut severed = 0usize;
    if !dry {
        // Straggler tickets past the drain bound: force-fail them by
        // severing their connections — the in-flight replies shed to
        // dead sockets (`fleet.shed_inflight_rows`) and the workers'
        // clients recover and resubmit behind the new fence.
        ctx.metrics.counter("serve.drain_timeouts").inc();
        severed += ctx.registry.sever_all();
    }
    let saved = FleetCheckpoint::load(dir_p)
        .map_err(|e| format!("reload: {e}"))?
        .ok_or_else(|| format!("reload: no checkpoint in {dir}"))?;
    if saved.seed != ctx.cfg_seed {
        return Err(format!(
            "reload: checkpoint seed {} != config seed {}",
            saved.seed, ctx.cfg_seed
        ));
    }
    let disk = checkpoint::load_params(&FleetCheckpoint::params_path(dir_p))
        .map_err(|e| format!("reload: {e}"))?;
    if disk != m.params() {
        return Err(format!(
            "reload: checkpoint params in {dir} do not match the backend \
             (different seed or model dims?)"
        ));
    }
    // The swap proper: model state, then the fence, then the resync
    // kick. Workers reconnecting between the store and the sever just
    // resync once, exactly like after a checkpoint restore.
    m.set_steps(saved.steps);
    let cur = ctx.gen_cell.load(Ordering::Acquire);
    let newg = cur.max(saved.generation) + 1;
    ctx.gen_cell.store(newg, Ordering::Release);
    severed += ctx.registry.sever_all();
    ctx.gate.set_admitting(true);
    ctx.metrics.counter("fleet.reloads").inc();
    let drain_ms = drained.as_secs_f64() * 1e3;
    ctx.metrics.gauge("serve.drain_ms").set(drain_ms);
    Ok(format!(
        "reloaded {dir}: generation {newg}, steps {}, severed {severed} conns, \
         drain {drain_ms:.1} ms",
        saved.steps
    ))
}

/// One-line `stats` reply: `key=value` pairs the CI smoke and any
/// scripted operator can grep.
fn stats_line(ctx: &ControlCtx) -> String {
    let c = |n: &str| ctx.metrics.counter(n).get();
    let breaker = match ctx.gate.breaker_state() {
        None => "off",
        Some(BreakerState::Closed) => "closed",
        Some(BreakerState::Open) => "open",
        Some(BreakerState::HalfOpen) => "half-open",
    };
    let steps = ctx.mock.as_ref().map_or(0, |m| m.steps());
    format!(
        "generation={} admitting={} inflight_rows={} steps={steps} sequences={} \
         reloads={} checkpoints={} drain_timeouts={} sheds_actor={} sheds_eval={} \
         sheds_bulk={} paused_sheds={} breaker_sheds={} breaker={breaker}",
        ctx.gen_cell.load(Ordering::Acquire),
        ctx.gate.is_admitting(),
        ctx.gate.inflight_rows(),
        ctx.replay.inserts(),
        c("fleet.reloads"),
        c("fleet.checkpoints"),
        c("serve.drain_timeouts"),
        c("serve.admission_sheds_actor"),
        c("serve.admission_sheds_eval"),
        c("serve.admission_sheds_bulk"),
        c("serve.paused_sheds"),
        c("serve.breaker_sheds"),
    )
}

/// Build the control-command handler run by the [`ControlServer`]
/// thread. Reload failures resume admission before replying, so a bad
/// snapshot path never wedges the service.
fn control_handler(ctx: ControlCtx) -> control::Handler {
    Box::new(move |cmd| match cmd {
        Command::Health => Ok("healthy".to_string()),
        Command::Ready => {
            let generation = ctx.gen_cell.load(Ordering::Acquire);
            if ctx.gate.is_admitting() {
                Ok(format!("ready generation={generation}"))
            } else {
                Err("not ready: admission paused (drain in progress)".to_string())
            }
        }
        Command::Stats => Ok(stats_line(&ctx)),
        Command::Reload(dir) => {
            let r = do_reload(&ctx, &dir);
            if r.is_err() {
                ctx.gate.set_admitting(true);
            }
            r
        }
        Command::Shutdown => {
            // Graceful drain: stop admitting, run the in-flight rows
            // dry (bounded), then signal — the learner exits with its
            // partial stats, `run_serve` writes the final checkpoint,
            // and the fleet server goodbyes every worker.
            let (drained, dry) = drain_inflight(&ctx.gate, ctx.drain_timeout, &ctx.shutdown);
            if !dry {
                ctx.metrics.counter("serve.drain_timeouts").inc();
            }
            let drain_ms = drained.as_secs_f64() * 1e3;
            ctx.metrics.gauge("serve.drain_ms").set(drain_ms);
            ctx.shutdown.signal();
            Ok(format!("shutting down: drained in {drain_ms:.1} ms"))
        }
    })
}

/// Run the coordinator side of a fleet: backend + batcher + replay +
/// learner in this process, remote actors over `cfg.fleet.listen`.
///
/// Blocks until the learner completes `cfg.learner.max_steps` steps —
/// or, with `[serve] control` armed, until a `shutdown` control command
/// runs the graceful drain (stop admitting → drain in-flight rows →
/// signal; the learner returns its partial stats) — then drains: the
/// fleet server flushes every outstanding reply, sends `Goodbye` on
/// each connection (the workers' shutdown signal), and closes before
/// the batcher is joined.
pub fn run_serve(
    cfg: &SystemConfig,
    backend: Backend,
    metrics: Registry,
) -> anyhow::Result<ServeReport> {
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("config: {e}"))?;
    anyhow::ensure!(
        !cfg.fleet.listen.is_empty(),
        "fleet.listen is empty: nothing to serve on (set [fleet] listen or --listen)"
    );
    anyhow::ensure!(
        cfg.mode == InferenceMode::Central,
        "fleet serving requires mode = \"central\" (remote actors share the batcher)"
    );
    let dims = backend.dims();
    anyhow::ensure!(
        dims.seq_len == cfg.learner.seq_len(),
        "learner seq_len {} != model seq_len {} (burn_in+unroll must match the AOT graph)",
        cfg.learner.seq_len(),
        dims.seq_len
    );
    anyhow::ensure!(
        dims.train_batch == cfg.learner.train_batch,
        "learner train_batch {} != model train_batch {}",
        cfg.learner.train_batch,
        dims.train_batch
    );
    let listener = Listener::bind(&Addr::parse(&cfg.fleet.listen)?)?;

    // Fault plan (None at the all-off default) and its mock-backend
    // stall seam.
    let fault_plan = FaultPlan::from_config(&cfg.faults);
    let mock: Option<Arc<MockModel>> = match &backend {
        Backend::Mock(m) => Some(m.clone()),
        _ => None,
    };
    if let (Some(plan), Some(m)) = (&fault_plan, &mock) {
        m.set_infer_stall(plan);
    }

    // Checkpoint resume: adopt the newest snapshot's learner step and
    // verify its params against the backend before serving anything.
    let ckpt_dir = (!cfg.fleet.checkpoint_dir.is_empty())
        .then(|| PathBuf::from(&cfg.fleet.checkpoint_dir));
    let mut generation: u32 = 0;
    let mut resumed_steps: u64 = 0;
    if let Some(dir) = &ckpt_dir {
        let m = mock.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "fleet.checkpoint_dir requires the mock backend (params snapshotting)"
            )
        })?;
        generation = 1;
        if let Some(saved) = FleetCheckpoint::load(dir)? {
            anyhow::ensure!(
                saved.seed == cfg.seed,
                "checkpoint in {dir:?} was written with seed {}, config has {}",
                saved.seed,
                cfg.seed
            );
            let disk = checkpoint::load_params(&FleetCheckpoint::params_path(dir))?;
            anyhow::ensure!(
                disk == m.params(),
                "checkpoint params in {dir:?} do not match the backend \
                 (different seed or model dims?)"
            );
            m.set_steps(saved.steps);
            resumed_steps = saved.steps;
            generation = saved.generation + 1;
        }
    }

    let pool = cfg.replay.pool.then(|| Arc::new(SequencePool::new()));
    let mut replay = SequenceReplay::new(ReplayConfig::from(&cfg.replay));
    if let Some(p) = &pool {
        replay = replay.with_pool(p.clone());
    }
    let replay = Arc::new(replay);
    let shutdown = ShutdownToken::new();

    let telemetry = Telemetry::from_config(&cfg.telemetry);
    telemetry.install(&metrics);
    let sampler = telemetry.start_sampler(&metrics)?;

    let t0 = Instant::now();
    let (batcher, handle) = Batcher::spawn(cfg.batcher.clone(), backend.clone(), metrics.clone());
    // The serving gate exists only when a `[serve]` feature is on; the
    // default is `None` and the data plane is bit-for-bit the PR 9 path.
    let gate = ServeGate::from_config(&cfg.serve, Instant::now());
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        replay.clone(),
        FleetServerOpts {
            max_inflight_rows: cfg.fleet.max_inflight_rows,
            insert_batch: cfg.replay.insert_batch,
            liveness_timeout_ms: cfg.fleet.liveness_timeout_ms,
            generation,
            faults: fault_plan.clone(),
            gate: gate.clone(),
        },
        metrics.clone(),
        shutdown.clone(),
    );
    let fleet_errors = server.error_slot();
    let gen_cell = server.generation_cell();
    let control_server = if cfg.serve.control.is_empty() {
        None
    } else {
        let ctx = ControlCtx {
            gate: gate.clone().expect("control socket implies the serving gate"),
            gen_cell: gen_cell.clone(),
            registry: server.conn_registry(),
            mock: mock.clone(),
            metrics: metrics.clone(),
            shutdown: shutdown.clone(),
            drain_timeout: Duration::from_millis(cfg.fleet.drain_timeout_ms),
            cfg_seed: cfg.seed,
            replay: replay.clone(),
        };
        let ctl_addr = Addr::parse(&cfg.serve.control)?;
        Some(ControlServer::spawn(
            &ctl_addr,
            shutdown.clone(),
            control_handler(ctx),
        )?)
    };

    // Periodic snapshots ride the learner's per-batch probe: every
    // `fleet.checkpoint_every` trained steps, persist the model step
    // count, params, and replay cursor.
    let on_batch: Option<learner::BatchProbe> = match (&ckpt_dir, &mock) {
        (Some(dir), Some(m)) => {
            let dir = dir.clone();
            let m = m.clone();
            let replay = replay.clone();
            let every = cfg.fleet.checkpoint_every.max(1);
            let seed = cfg.seed;
            let saved_c = metrics.counter("fleet.checkpoints");
            let failed_c = metrics.counter("fleet.checkpoint_errors");
            let errslot = fleet_errors.clone();
            let gen_cell = gen_cell.clone();
            let mut batches = 0u64;
            Some(Box::new(move |_slots: &[usize]| {
                batches += 1;
                if batches % every != 0 {
                    return;
                }
                let ck = FleetCheckpoint {
                    // The live fence: a hot-reload mid-run moves it, and
                    // the next snapshot must carry the bumped value.
                    generation: gen_cell.load(Ordering::Acquire),
                    steps: m.steps(),
                    sequences: replay.inserts(),
                    seed,
                };
                match ck.save(&dir, &m.params()) {
                    Ok(()) => saved_c.inc(),
                    Err(e) => {
                        failed_c.inc();
                        let mut g = errslot.lock().unwrap();
                        if g.is_none() {
                            *g = Some(format!("checkpoint save failed: {e}"));
                        }
                    }
                }
            }) as learner::BatchProbe)
        }
        _ => None,
    };

    // The learner runs on this thread; data arrives by wire.
    let learner_result = learner::run_learner(learner::LearnerArgs {
        cfg: cfg.learner.clone(),
        dims,
        backend: backend.clone(),
        replay: replay.clone(),
        metrics: metrics.clone(),
        shutdown: shutdown.clone(),
        loss_every: 10,
        seed: cfg.seed,
        on_batch,
    });
    // run_learner signals shutdown on its happy path; a train failure
    // must still drain the fleet before this function returns.
    shutdown.signal();

    // A final snapshot pins the completed run, so a restart with a
    // larger step budget resumes exactly at `max_steps`.
    if let (Some(dir), Some(m), Ok(_)) = (&ckpt_dir, &mock, &learner_result) {
        let ck = FleetCheckpoint {
            generation: gen_cell.load(Ordering::Acquire),
            steps: m.steps(),
            sequences: replay.inserts(),
            seed: cfg.seed,
        };
        match ck.save(dir, &m.params()) {
            Ok(()) => metrics.counter("fleet.checkpoints").inc(),
            Err(e) => {
                metrics.counter("fleet.checkpoint_errors").inc();
                let mut g = fleet_errors.lock().unwrap();
                if g.is_none() {
                    *g = Some(format!("final checkpoint save failed: {e}"));
                }
            }
        }
    }

    // Drain order matters: the server's writers must flush outstanding
    // reply chunks (they hold ReplyRange borrows of batcher output
    // slabs) and say goodbye before the batcher can be joined.
    server.join();
    drop(handle);
    batcher.join();
    if let Some(c) = control_server {
        c.join();
    }

    let elapsed = t0.elapsed().as_secs_f64();
    metrics
        .counter("replay.shard_contention")
        .add(replay.shard_contention());
    metrics
        .counter("replay.lock_acquisitions")
        .add(replay.lock_acquisitions());
    if let Some(p) = &pool {
        metrics.gauge("actor.pool_hit_rate").set(p.hit_rate());
    }
    if let Some(s) = sampler {
        s.stop()?;
    }
    telemetry.write_trace()?;

    let batches = metrics.counter("batcher.batches").get();
    let items = metrics.counter("batcher.items").get();
    let first_error = fleet_errors.lock().unwrap().clone();
    Ok(ServeReport {
        learner: learner_result?,
        elapsed_seconds: elapsed,
        sequences: replay.inserts(),
        accepts: metrics.counter("fleet.accepts").get(),
        disconnects: metrics.counter("fleet.disconnects").get(),
        reconnects: metrics.counter("fleet.reconnects").get(),
        shed_rows: metrics.counter("fleet.shed_rows").get(),
        inference_batches: batches,
        mean_batch_occupancy: if batches > 0 {
            items as f64 / batches as f64
        } else {
            0.0
        },
        batcher_errors: metrics.counter("batcher.errors").get(),
        generation: gen_cell.load(Ordering::Acquire),
        resumed_steps,
        checkpoints: metrics.counter("fleet.checkpoints").get(),
        reloads: metrics.counter("fleet.reloads").get(),
        first_error,
        injected: fault_plan.as_ref().map(|p| p.injected()),
    })
}

/// Chaos seam: a [`PolicyClient`] wrapper that panics on its `at`-th
/// submission — but only if it wins the plan's one-shot panic claim,
/// so the supervisor's restart count under a plan is deterministic
/// (the restarted actor's fresh wrapper never fires again).
struct PanicAt {
    inner: Box<dyn PolicyClient>,
    at: u64,
    calls: u64,
    plan: Arc<FaultPlan>,
}

impl PolicyClient for PanicAt {
    fn submit(
        &mut self,
        ticket: usize,
        rows: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<()> {
        self.calls += 1;
        if self.calls == self.at && self.plan.take_panic() {
            panic!("injected actor panic (fault plan, submit #{})", self.calls);
        }
        self.inner.submit(ticket, rows, obs, h, c)
    }

    fn wait(
        &mut self,
        ticket: usize,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<()> {
        self.inner.wait(ticket, q, h, c)
    }
}

/// One restartable attempt of fleet actor `id`: connect, wrap in the
/// fault plan's panic seam if it targets this actor, run.
#[allow(clippy::too_many_arguments)]
fn actor_attempt(
    addr: &Addr,
    id: usize,
    dims: ModelDims,
    opts: RemoteClientOpts,
    cfg: &SystemConfig,
    fault_plan: &Option<Arc<FaultPlan>>,
    ingest: &Arc<RemoteIngest>,
    metrics: &Registry,
    shutdown: &ShutdownToken,
    max_rounds: Option<u64>,
) -> anyhow::Result<ActorStats> {
    let client = RemoteClient::connect(addr, id, dims, opts, metrics, shutdown.clone())?;
    let mut policy: Box<dyn PolicyClient> = Box::new(client);
    if let Some(plan) = fault_plan {
        if let Some(at) = plan.actor_panic_at(id) {
            policy = Box::new(PanicAt {
                inner: policy,
                at,
                calls: 0,
                plan: plan.clone(),
            });
        }
    }
    actor::run_actor(actor::ActorArgs {
        id,
        cfg: cfg.clone(),
        dims,
        policy,
        replay: ingest.clone() as Arc<dyn SequenceSink>,
        metrics: metrics.clone(),
        shutdown: shutdown.clone(),
        max_rounds,
    })
}

/// Render a caught panic payload for error reports.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Run one worker process: `local_actors` actor threads over
/// `cfg.fleet.connect`, with fleet-global ids `id_base ..`.
///
/// `dims` must match the coordinator backend's (the handshake rejects a
/// mismatch); `cfg.actors.num_actors` stays the *fleet-wide* total so
/// every worker derives the same epsilon spectrum and env-seed layout
/// as the in-process run — `id_base` picks this worker's slice of it.
///
/// Each actor thread is supervised: a panic (never an `Err`) is
/// caught, counted in `fleet.actor_restarts`, and retried from a fresh
/// connection after an interruptible backoff, up to
/// `fleet.actor_restart_budget` restarts. A restarted actor restarts
/// its episode stream from scratch — the replay is a distribution, not
/// a ledger, so at-least-once episode delivery is the contract.
///
/// Actor failures do not abort the report: a server drain lands as a
/// goodbye mid-`wait` in whichever actors were blocked, and the rest
/// exit on the signalled token. The caller decides what a nonzero
/// `first_error` means from `env_steps`.
pub fn run_worker(
    cfg: &SystemConfig,
    dims: ModelDims,
    id_base: usize,
    local_actors: usize,
    max_rounds: Option<u64>,
    metrics: Registry,
) -> anyhow::Result<WorkerReport> {
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("config: {e}"))?;
    anyhow::ensure!(
        !cfg.fleet.connect.is_empty(),
        "fleet.connect is empty: nowhere to connect (set [fleet] connect or --connect)"
    );
    anyhow::ensure!(local_actors > 0, "worker needs at least one actor thread");
    anyhow::ensure!(
        id_base + local_actors <= cfg.actors.num_actors,
        "worker ids {id_base}..{} exceed the fleet-wide actors.num_actors {} \
         (every worker must carve its slice from the same global layout)",
        id_base + local_actors,
        cfg.actors.num_actors
    );
    let addr = Addr::parse(&cfg.fleet.connect)?;
    let opts = RemoteClientOpts {
        connect_retries: cfg.fleet.connect_retries,
        backoff_ms: cfg.fleet.backoff_ms,
        heartbeat_ms: cfg.fleet.heartbeat_interval_ms,
        liveness_ms: cfg.fleet.liveness_timeout_ms,
        // Training workers are always `actor` class: the admission
        // ladder never sheds them by policy.
        class: 0,
    };
    let fault_plan = FaultPlan::from_config(&cfg.faults);
    let shutdown = ShutdownToken::new();
    // One ingest connection per worker process, shared by its actors.
    let ingest = Arc::new(RemoteIngest::connect(
        &addr,
        dims,
        &opts,
        &metrics,
        shutdown.clone(),
    )?);

    let restarts_c = metrics.counter("fleet.actor_restarts");
    let spawn_failures = metrics.counter("fleet.spawn_failures");
    let restart_budget = cfg.fleet.actor_restart_budget;
    let backoff = Duration::from_millis(cfg.fleet.backoff_ms.max(1));

    let t0 = Instant::now();
    let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let actor_stats = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for t in 0..local_actors {
            let id = id_base + t;
            let (addr, cfg, fault_plan, ingest, metrics, shutdown, restarts_c) = (
                &addr,
                cfg,
                &fault_plan,
                &ingest,
                metrics.clone(),
                shutdown.clone(),
                restarts_c.clone(),
            );
            let spawned = std::thread::Builder::new()
                .name(format!("rlarch-actor-{id}"))
                .spawn_scoped(s, move || -> anyhow::Result<ActorStats> {
                    // The supervisor: restart-with-budget around the
                    // whole attempt (connect + actor loop), so a panic
                    // mid-episode reconnects from scratch.
                    let mut restarts = 0usize;
                    loop {
                        let attempt =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                actor_attempt(
                                    addr, id, dims, opts, cfg, fault_plan, ingest,
                                    &metrics, &shutdown, max_rounds,
                                )
                            }));
                        match attempt {
                            Ok(result) => return result,
                            Err(p) => {
                                restarts_c.inc();
                                let msg = panic_msg(p.as_ref());
                                if restarts >= restart_budget || shutdown.is_signalled() {
                                    anyhow::bail!(
                                        "actor {id} panicked: {msg} \
                                         (restart budget {restart_budget} exhausted)"
                                    );
                                }
                                restarts += 1;
                                if shutdown.sleep_interruptible(backoff) {
                                    anyhow::bail!(
                                        "actor {id} panicked: {msg} (shutdown during backoff)"
                                    );
                                }
                            }
                        }
                    }
                });
            match spawned {
                Ok(h) => joins.push(h),
                Err(e) => {
                    spawn_failures.inc();
                    errors
                        .lock()
                        .unwrap()
                        .push(format!("spawn actor {id} thread: {e}"));
                }
            }
        }
        let mut stats = Vec::new();
        for j in joins {
            match j.join() {
                Ok(Ok(st)) => stats.push(st),
                Ok(Err(e)) => errors.lock().unwrap().push(e.to_string()),
                // The supervisor catches actor panics; reaching here
                // means the supervisor itself died. Record, don't abort.
                Err(p) => errors
                    .lock()
                    .unwrap()
                    .push(format!("actor supervisor panicked: {}", panic_msg(p.as_ref()))),
            }
        }
        stats
    });
    // All actors are down: commit the drain marker on the ingest link
    // so the coordinator logs a clean departure.
    ingest.goodbye();

    let env_steps: u64 = actor_stats.iter().map(|a| a.env_steps).sum();
    let episodes: u64 = actor_stats.iter().map(|a| a.episodes).sum();
    let first_error = errors.lock().unwrap().first().cloned();
    Ok(WorkerReport {
        elapsed_seconds: t0.elapsed().as_secs_f64(),
        env_steps,
        episodes,
        mean_return: weighted_mean_return(&actor_stats),
        actor_restarts: restarts_c.get(),
        actors: actor_stats,
        first_error,
    })
}
