//! L3 coordinator — the paper's system under study.
//!
//! Wires the SEED-RL dataflow: N actor threads step environments (CPU
//! side), a central inference batcher coalesces their observation slabs
//! into padded-bucket accelerator launches (the pooled slab protocol:
//! recycled submission slabs, persistent reply mailboxes, `Arc`-shared
//! output slabs — zero allocations per round-trip in steady state;
//! DESIGN.md §5), completed sequences buffer in
//! per-actor ingest queues and commit to sharded prioritized replay in
//! `replay.insert_batch`-sized flushes (slabs recycling through the
//! shared `SequencePool`; DESIGN.md §8), and the learner thread trains
//! the AOT'd R2D2 graph and refreshes priorities. Actors reach inference through the
//! split-phase `policy` layer (submit/wait), which lets them pipeline
//! env stepping against in-flight inference; the learner mirrors that
//! design with a prefetch stage (`learner.prefetch_depth`) that samples
//! and assembles the next batch while the backend trains the current
//! one (DESIGN.md §7). The IMPALA-style `Local` mode skips the batcher
//! and performs per-actor inference — the architectural baseline the
//! paper contrasts (Fig. 1).
//!
//! ```text
//!  actors (env CPU) ─submit─► policy ──slabs──► batcher ──► Backend (PJRT)
//!     ▲                         ▲                              │ q, h', c'
//!     └── wait ◄── scatter ◄────┴──── slot-addressed chunks ◄──┘
//!  actors ──sequences──► SequenceReplay ◄──sample── learner ──► train()
//! ```

pub mod actor;
pub mod batcher;
pub mod fleet;
pub mod learner;

pub use actor::ActorStats;
pub use batcher::{
    ActorReply, Batcher, BatcherHandle, InferItem, InferSlab, ReplyChunk, ReplyRange,
    SlabPool,
};
pub use fleet::{ServeReport, WorkerReport, run_serve, run_worker};
pub use learner::{
    BatchProbe, LearnerStats, assemble_batch, assemble_begin, assemble_into, assemble_push,
};

use crate::config::{InferenceMode, SystemConfig};
use crate::exec::ShutdownToken;
use crate::metrics::Registry;
use crate::policy::{CentralClient, LocalClient, PolicyClient};
use crate::replay::{ReplayConfig, SequenceReplay};
use crate::rl::SequencePool;
use crate::runtime::Backend;
use crate::telemetry::Telemetry;
use std::sync::Arc;
use std::time::Instant;

/// Outcome of a coordinated training run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub learner: LearnerStats,
    pub actors: Vec<ActorStats>,
    pub elapsed_seconds: f64,
    pub env_steps: u64,
    pub env_steps_per_sec: f64,
    pub episodes: u64,
    /// Environment slots in flight (num_actors * envs_per_actor).
    pub total_envs: usize,
    /// Mean completed-episode return across the whole pool (exploration
    /// included), weighted by each actor's episode count so actors with
    /// few episodes don't skew the aggregate.
    pub mean_return: f64,
    pub sequences: u64,
    pub inference_batches: u64,
    pub mean_batch_occupancy: f64,
    /// Batched-inference failures the batcher observed (mirrors the
    /// `batcher.errors` counter; 0 on a healthy run).
    pub batcher_errors: u64,
    /// First actor failure message, if any actor exited with an error
    /// (e.g. a batcher inference failure) instead of a clean shutdown.
    pub first_error: Option<String>,
}

/// Episode-weighted mean completed-episode return: each actor's mean
/// counts once per episode behind it, so an actor that finished 2
/// episodes cannot skew the aggregate the way one with 200 can.
pub fn weighted_mean_return(stats: &[ActorStats]) -> f64 {
    let episodes: u64 = stats.iter().map(|a| a.episodes).sum();
    if episodes == 0 {
        return 0.0;
    }
    let weighted: f64 = stats
        .iter()
        .map(|a| a.mean_return * a.episodes as f64)
        .sum();
    weighted / episodes as f64
}

/// Run the full system: actors + (batcher) + learner, until the learner
/// completes `cfg.learner.max_steps` steps.
pub fn run(cfg: &SystemConfig, backend: Backend, metrics: Registry) -> anyhow::Result<RunReport> {
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("config: {e}"))?;
    let dims = backend.dims();
    anyhow::ensure!(
        dims.seq_len == cfg.learner.seq_len(),
        "learner seq_len {} != model seq_len {} (burn_in+unroll must match the AOT graph)",
        cfg.learner.seq_len(),
        dims.seq_len
    );
    anyhow::ensure!(
        dims.train_batch == cfg.learner.train_batch,
        "learner train_batch {} != model train_batch {}",
        cfg.learner.train_batch,
        dims.train_batch
    );

    // The sequence recycling pool (DESIGN.md §8): builders draw emitted
    // slabs from it, replay evictions and learner-released batches feed
    // buffers back. `replay.pool = false` restores the seed's
    // allocate-per-sequence behavior (the emitted values are identical
    // either way).
    let pool = cfg.replay.pool.then(|| Arc::new(SequencePool::new()));
    let mut replay = SequenceReplay::new(ReplayConfig::from(&cfg.replay));
    if let Some(p) = &pool {
        replay = replay.with_pool(p.clone());
    }
    let replay = Arc::new(replay);
    let shutdown = ShutdownToken::new();

    // Telemetry (DESIGN.md §12): install the span tracer before any
    // worker thread mints a recorder, and start the background registry
    // sampler. Both are off by default ([`crate::config::TelemetryConfig`]);
    // the disabled path hands out inert recorders, so the dataflow below
    // is bit-for-bit identical to an uninstrumented run.
    let telemetry = Telemetry::from_config(&cfg.telemetry);
    telemetry.install(&metrics);
    let sampler = telemetry.start_sampler(&metrics)?;

    let t0 = Instant::now();

    // Central mode: one batcher in front of the backend.
    let (batcher, batcher_handle) = match cfg.mode {
        InferenceMode::Central => {
            let (b, h) = Batcher::spawn(cfg.batcher.clone(), backend.clone(), metrics.clone());
            (Some(b), Some(h))
        }
        InferenceMode::Local => (None, None),
    };

    let (learner_stats, actor_stats, actor_errors) =
        std::thread::scope(|s| -> anyhow::Result<_> {
            let mut actor_joins = Vec::new();
            for id in 0..cfg.actors.num_actors {
                let policy: Box<dyn PolicyClient> = match (&cfg.mode, &batcher_handle)
                {
                    (InferenceMode::Central, Some(h)) => Box::new(
                        CentralClient::new(h.clone(), id, dims, &metrics),
                    ),
                    _ => Box::new(LocalClient::new(
                        backend.clone(),
                        cfg.batcher.max_batch,
                        dims,
                        &metrics,
                    )),
                };
                let args = actor::ActorArgs {
                    id,
                    cfg: cfg.clone(),
                    dims,
                    policy,
                    replay: replay.clone(),
                    metrics: metrics.clone(),
                    shutdown: shutdown.clone(),
                    max_rounds: None,
                };
                actor_joins.push(
                    std::thread::Builder::new()
                        .name(format!("rlarch-actor-{id}"))
                        .spawn_scoped(s, move || actor::run_actor(args))
                        .expect("spawn actor"),
                );
            }

            let learner_result = learner::run_learner(learner::LearnerArgs {
                cfg: cfg.learner.clone(),
                dims,
                backend: backend.clone(),
                replay: replay.clone(),
                metrics: metrics.clone(),
                shutdown: shutdown.clone(),
                loss_every: 10,
                seed: cfg.seed,
                on_batch: None,
            });
            // run_learner signals shutdown on its happy path only; a
            // learner error (backend train failure) must also stop the
            // actors, or the joins below would hang forever.
            if learner_result.is_err() {
                shutdown.signal();
            }
            // Actors drain out. A failed actor (e.g. batcher inference
            // failure) is recorded rather than aborting the report: the
            // first message surfaces through `RunReport::first_error`.
            let mut actor_stats = Vec::new();
            let mut actor_errors: Vec<String> = Vec::new();
            for j in actor_joins {
                match j.join().expect("actor panicked") {
                    Ok(stats) => actor_stats.push(stats),
                    Err(e) => actor_errors.push(e.to_string()),
                }
            }
            Ok((learner_result?, actor_stats, actor_errors))
        })?;

    // Drop our handle so the batcher thread can exit, then join it.
    drop(batcher_handle);
    if let Some(b) = batcher {
        b.join();
    }

    let elapsed = t0.elapsed().as_secs_f64();
    let env_steps: u64 = actor_stats.iter().map(|a| a.env_steps).sum();
    let episodes: u64 = actor_stats.iter().map(|a| a.episodes).sum();
    let batches = metrics.counter("batcher.batches").get();
    let items = metrics.counter("batcher.items").get();
    // Contended shard-lock acquisitions over the whole run (actors
    // striping inserts vs the learner's sample/write-back passes), and
    // total acquisitions (the batched-ingest amortization signal).
    metrics
        .counter("replay.shard_contention")
        .add(replay.shard_contention());
    metrics
        .counter("replay.lock_acquisitions")
        .add(replay.lock_acquisitions());
    if let Some(p) = &pool {
        // Final pool effectiveness over the whole run (actors also set
        // this at their own exit; last write wins with the same value).
        metrics.gauge("actor.pool_hit_rate").set(p.hit_rate());
    }

    // Stop the sampler after the final metric writes above so its
    // guaranteed last tick captures the complete run, then flush the
    // span rings to the Chrome trace file.
    if let Some(s) = sampler {
        s.stop()?;
    }
    telemetry.write_trace()?;

    Ok(RunReport {
        learner: learner_stats,
        elapsed_seconds: elapsed,
        env_steps,
        env_steps_per_sec: env_steps as f64 / elapsed.max(1e-9),
        episodes,
        total_envs: cfg.actors.total_envs(),
        mean_return: weighted_mean_return(&actor_stats),
        actors: actor_stats,
        sequences: replay.inserts(),
        inference_batches: batches,
        mean_batch_occupancy: if batches > 0 {
            items as f64 / batches as f64
        } else {
            0.0
        },
        batcher_errors: metrics.counter("batcher.errors").get(),
        first_error: actor_errors.first().cloned(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockModel, ModelDims};

    fn mock_system(actors: usize, mode: InferenceMode) -> (SystemConfig, Backend) {
        let mut cfg = SystemConfig::default();
        cfg.mode = mode;
        cfg.env.name = "catch".into();
        cfg.env.frame_stack = 4;
        cfg.actors.num_actors = actors;
        cfg.learner.burn_in = 2;
        cfg.learner.unroll_len = 4;
        cfg.learner.seq_overlap = 2;
        cfg.learner.train_batch = 4;
        cfg.learner.min_replay = 8;
        cfg.learner.max_steps = 30;
        cfg.replay.capacity = 512;
        cfg.learner.target_update_interval = 10;
        cfg.batcher.max_batch = 8;
        cfg.batcher.batch_sizes = vec![1, 8];
        cfg.batcher.timeout_us = 1_000;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 4,
        };
        (cfg, Backend::Mock(Arc::new(MockModel::new(dims, 11))))
    }

    #[test]
    fn central_mode_end_to_end_with_mock() {
        let (cfg, backend) = mock_system(4, InferenceMode::Central);
        let metrics = Registry::new();
        let report = run(&cfg, backend, metrics.clone()).unwrap();
        assert_eq!(report.learner.steps, 30);
        assert!(report.env_steps > 0);
        assert!(report.episodes > 0);
        assert!(report.inference_batches > 0);
        assert!(report.mean_batch_occupancy >= 1.0);
        assert!(report.sequences > 0);
        // Central mode must actually batch with 4 actors.
        assert!(
            report.mean_batch_occupancy > 1.05,
            "occupancy {}",
            report.mean_batch_occupancy
        );
    }

    #[test]
    fn local_mode_end_to_end_with_mock() {
        let (cfg, backend) = mock_system(2, InferenceMode::Local);
        let report = run(&cfg, backend, Registry::new()).unwrap();
        assert_eq!(report.learner.steps, 30);
        assert!(report.env_steps > 0);
        // No batcher in local mode.
        assert_eq!(report.inference_batches, 0);
    }

    #[test]
    fn seq_len_mismatch_rejected() {
        let (mut cfg, backend) = mock_system(1, InferenceMode::Local);
        cfg.learner.unroll_len = 9; // seq_len 11 != dims 6
        assert!(run(&cfg, backend, Registry::new()).is_err());
    }

    #[test]
    fn weighted_mean_return_weights_by_episode_count() {
        let stats = vec![
            ActorStats {
                episodes: 1,
                mean_return: 100.0,
                ..Default::default()
            },
            ActorStats {
                episodes: 99,
                mean_return: 0.0,
                ..Default::default()
            },
        ];
        // Unweighted averaging would say 50; the lone-episode outlier
        // must only contribute 1/100 of the weight.
        assert!((weighted_mean_return(&stats) - 1.0).abs() < 1e-12);
        assert_eq!(weighted_mean_return(&[]), 0.0);
        assert_eq!(
            weighted_mean_return(&[ActorStats::default()]),
            0.0,
            "zero-episode actors contribute nothing"
        );
    }

    #[test]
    fn vecenv_central_mode_end_to_end() {
        let (mut cfg, backend) = mock_system(2, InferenceMode::Central);
        cfg.actors.envs_per_actor = 4;
        let report = run(&cfg, backend, Registry::new()).unwrap();
        assert_eq!(report.learner.steps, 30);
        assert_eq!(report.total_envs, 8);
        assert!(report.env_steps > 0);
        assert!(report.episodes > 0);
        // 8 env slots behind 2 threads must still fill real batches.
        assert!(
            report.mean_batch_occupancy > 1.05,
            "occupancy {}",
            report.mean_batch_occupancy
        );
    }

    #[test]
    fn pipelined_central_mode_end_to_end() {
        let (mut cfg, backend) = mock_system(2, InferenceMode::Central);
        cfg.actors.envs_per_actor = 4;
        cfg.actors.pipeline_depth = 2;
        let report = run(&cfg, backend, Registry::new()).unwrap();
        assert_eq!(report.learner.steps, 30);
        assert_eq!(report.total_envs, 8);
        assert!(report.env_steps > 0);
        assert!(report.sequences > 0);
        assert_eq!(report.batcher_errors, 0);
        assert!(report.first_error.is_none(), "{:?}", report.first_error);
    }

    #[test]
    fn sharded_replay_and_prefetching_learner_end_to_end() {
        // The learner-side mirror of the actor pipeline test: 4 replay
        // shards + a depth-2 prefetching learner must run the whole
        // dataflow to completion and expose the new metrics.
        let (mut cfg, backend) = mock_system(4, InferenceMode::Central);
        cfg.replay.shards = 4;
        cfg.learner.prefetch_depth = 2;
        let metrics = Registry::new();
        let report = run(&cfg, backend, metrics.clone()).unwrap();
        assert_eq!(report.learner.steps, 30);
        assert!(report.env_steps > 0);
        assert!(report.sequences > 0);
        assert!(report.first_error.is_none(), "{:?}", report.first_error);
        let snap = metrics.snapshot();
        let occ = snap["learner.prefetch_occupancy"];
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
        assert!(snap.contains_key("replay.shard_contention"));
        assert!(snap["learner.assemble_seconds.count"] >= 30.0);
    }

    #[test]
    fn inference_failure_is_surfaced_in_report() {
        let (cfg, _healthy) = mock_system(2, InferenceMode::Central);
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 4,
        };
        let backend = Backend::Mock(Arc::new(
            MockModel::new(dims, 11).with_infer_error("injected GPU fault"),
        ));
        let metrics = Registry::new();
        let report = run(&cfg, backend, metrics.clone()).unwrap();
        // Actors exited with a descriptive error; the first message and
        // the failure counter surface through the report.
        let msg = report.first_error.as_deref().unwrap_or("");
        assert!(msg.contains("injected GPU fault"), "got: {msg}");
        assert!(report.batcher_errors >= 1);
        assert!(metrics.counter("batcher.errors").get() >= 1);
        assert_eq!(report.learner.steps, 0, "no data ever reached replay");
    }

    #[test]
    fn learner_train_failure_terminates_and_propagates() {
        // A backend train failure must stop the actors (not hang the
        // scope joins) and surface as run()'s error.
        let (cfg, _healthy) = mock_system(2, InferenceMode::Central);
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 4,
        };
        let backend = Backend::Mock(Arc::new(
            MockModel::new(dims, 11).with_train_error("injected train fault"),
        ));
        let err = run(&cfg, backend, Registry::new()).unwrap_err().to_string();
        assert!(err.contains("injected train fault"), "got: {err}");
    }

    #[test]
    fn local_inference_failure_is_surfaced_in_report() {
        let (mut cfg, _healthy) = mock_system(1, InferenceMode::Local);
        cfg.mode = InferenceMode::Local;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 4,
        };
        let backend = Backend::Mock(Arc::new(
            MockModel::new(dims, 11).with_infer_error("injected local fault"),
        ));
        let report = run(&cfg, backend, Registry::new()).unwrap();
        let msg = report.first_error.as_deref().unwrap_or("");
        assert!(msg.contains("injected local fault"), "got: {msg}");
        assert_eq!(report.batcher_errors, 0, "no batcher in local mode");
    }

    #[test]
    fn more_actors_increase_batch_occupancy() {
        let (cfg1, b1) = mock_system(1, InferenceMode::Central);
        let (cfg8, b8) = mock_system(8, InferenceMode::Central);
        let r1 = run(&cfg1, b1, Registry::new()).unwrap();
        let r8 = run(&cfg8, b8, Registry::new()).unwrap();
        assert!(
            r8.mean_batch_occupancy > r1.mean_batch_occupancy,
            "8 actors {} <= 1 actor {}",
            r8.mean_batch_occupancy,
            r1.mean_batch_occupancy
        );
    }
}
