//! Circuit breaker for the serving backend (DESIGN.md §16).
//!
//! A pure, clock-free state machine in the `transport::liveness` idiom:
//! every transition takes `now: Instant` from the caller, so unit tests
//! drive it with a synthetic clock and the steady-state path allocates
//! nothing (`micro_transport` gate). The breaker watches *backend*
//! results only — shed replies are flow control, not failures —
//! tripping open after `serve.backend_failure_threshold` consecutive
//! errors. While open, submissions are failed fast with a `shed:` reply
//! (the client's normal resubmit path); after
//! `serve.breaker_cooloff_ms` exactly one half-open probe reaches the
//! backend and its outcome decides between closing and re-opening.

use std::time::{Duration, Instant};

/// Breaker position. `Closed` = healthy (traffic flows), `Open` =
/// tripped (fail-fast sheds), `HalfOpen` = one probe in flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// Consecutive-failure circuit breaker. A `threshold` of 0 disables
/// it: `allow` is always true and results are not tracked (the
/// control-plane-off identity).
#[derive(Clone, Copy, Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooloff: Duration,
    state: BreakerState,
    failures: u32,
    opened_at: Instant,
}

impl CircuitBreaker {
    pub fn new(threshold: u32, cooloff: Duration, now: Instant) -> Self {
        Self {
            threshold,
            cooloff,
            state: BreakerState::Closed,
            failures: 0,
            opened_at: now,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Whether a new submission may reach the backend at `now`. An
    /// `Open` breaker past its cooloff admits exactly one probe (and
    /// moves to `HalfOpen`); further calls return false until the
    /// probe resolves via [`Self::on_success`] / [`Self::on_failure`].
    pub fn allow(&mut self, now: Instant) -> bool {
        if self.threshold == 0 {
            return true;
        }
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if now.duration_since(self.opened_at) >= self.cooloff {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => false,
        }
    }

    /// A backend call completed cleanly: close and reset the count
    /// (one success heals a half-open breaker).
    pub fn on_success(&mut self) {
        if self.threshold == 0 {
            return;
        }
        self.failures = 0;
        self.state = BreakerState::Closed;
    }

    /// A backend call failed at `now`: count it (tripping `Closed` at
    /// the threshold) or re-open around a failed half-open probe.
    pub fn on_failure(&mut self, now: Instant) {
        if self.threshold == 0 {
            return;
        }
        match self.state {
            BreakerState::Closed => {
                self.failures += 1;
                if self.failures >= self.threshold {
                    self.state = BreakerState::Open;
                    self.opened_at = now;
                }
            }
            BreakerState::HalfOpen | BreakerState::Open => {
                self.failures = self.threshold;
                self.state = BreakerState::Open;
                self.opened_at = now;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn trips_after_threshold_consecutive_failures() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(3, ms(100), t0);
        assert_eq!(b.state(), BreakerState::Closed);
        b.on_failure(t0);
        b.on_failure(t0);
        assert!(b.allow(t0), "below threshold: still closed");
        // A success resets the consecutive count.
        b.on_success();
        b.on_failure(t0);
        b.on_failure(t0);
        assert_eq!(b.state(), BreakerState::Closed);
        b.on_failure(t0);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(t0), "open: fail fast");
        assert!(!b.allow(t0 + ms(99)), "cooloff not elapsed");
    }

    #[test]
    fn half_open_probe_recovers_or_reopens() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(1, ms(50), t0);
        b.on_failure(t0);
        assert_eq!(b.state(), BreakerState::Open);
        // Past the cooloff: exactly one probe is admitted.
        assert!(b.allow(t0 + ms(50)));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(t0 + ms(60)), "one probe at a time");
        // Probe succeeds: closed again, traffic flows.
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t0 + ms(61)));
        // Trip again; this time the probe fails: re-open, new cooloff.
        b.on_failure(t0 + ms(70));
        assert!(b.allow(t0 + ms(120)));
        b.on_failure(t0 + ms(121));
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(t0 + ms(150)), "cooloff restarts at the reopen");
        assert!(b.allow(t0 + ms(171)));
    }

    #[test]
    fn zero_threshold_disables_the_breaker() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(0, ms(1), t0);
        for _ in 0..100 {
            b.on_failure(t0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t0));
    }
}
