//! Deadline-aware admission control with priority classes
//! (DESIGN.md §16).
//!
//! Every fleet connection declares a [`PriorityClass`] in its `Hello`
//! (one pad byte of the PR 8 wire format, so generation-0 workers are
//! `actor` class unchanged). The server consults one global
//! [`AdmissionPolicy`] per `Submit` frame; a shed decision is returned
//! through the existing `shed:` reply flow, so client resubmit logic
//! is untouched. The ladder degrades gracefully under overload: `bulk`
//! is shed first, then `eval`, never `actor` — the training fleet's
//! critical path keeps flowing while best-effort traffic backs off.
//!
//! Like the liveness and breaker machines, everything here is pure and
//! clock-free (`now: Instant` comes from the caller) and allocation-free
//! in steady state (`micro_transport` gate): the sliding window is a
//! fixed 8-bucket ring, and shed reasons are `&'static str`.

use std::time::{Duration, Instant};

/// Connection priority, highest first. The wire byte is the
/// discriminant; unknown bytes are refused at the handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PriorityClass {
    /// Training actors: the critical path, never shed by policy.
    Actor = 0,
    /// Evaluation workers: shed only under severe overload.
    Eval = 1,
    /// Best-effort traffic (bulk scoring, A/B probes): shed first.
    Bulk = 2,
}

impl PriorityClass {
    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(Self::Actor),
            1 => Some(Self::Eval),
            2 => Some(Self::Bulk),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Actor => "actor",
            Self::Eval => "eval",
            Self::Bulk => "bulk",
        }
    }
}

/// Sliding-window overload level: how far down the priority ladder the
/// server is currently shedding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overload {
    Clear,
    /// Window at or past `overload_rows`: shed `bulk`.
    ShedBulk,
    /// Window at or past 1.5x `overload_rows`: shed `eval` too.
    /// `actor` is never shed by the detector.
    ShedEvalAndBulk,
}

/// Admitted-rows sliding window over a fixed 8-bucket ring. Buckets
/// cover `window / 8` each; advancing past a bucket zeroes it, so the
/// sum always approximates the trailing window without allocating.
#[derive(Clone, Copy, Debug)]
pub struct OverloadDetector {
    bucket: Duration,
    origin: Instant,
    /// Absolute index of the bucket `now` falls in.
    cur: u64,
    ring: [u64; 8],
    limit_rows: u64,
}

impl OverloadDetector {
    /// `limit_rows` 0 disables the detector (`level` is always
    /// `Clear`); rows are still recorded so the deadline estimate
    /// below has a throughput signal.
    pub fn new(window: Duration, limit_rows: u64, now: Instant) -> Self {
        Self {
            bucket: (window / 8).max(Duration::from_millis(1)),
            origin: now,
            cur: 0,
            ring: [0; 8],
            limit_rows,
        }
    }

    fn advance(&mut self, now: Instant) {
        let idx =
            (now.duration_since(self.origin).as_nanos() / self.bucket.as_nanos()) as u64;
        if idx > self.cur {
            let steps = (idx - self.cur).min(8);
            for i in 1..=steps {
                self.ring[((self.cur + i) % 8) as usize] = 0;
            }
            self.cur = idx;
        }
    }

    /// Count `rows` admitted at `now`.
    pub fn record(&mut self, rows: u64, now: Instant) {
        self.advance(now);
        self.ring[(self.cur % 8) as usize] += rows;
    }

    /// Rows admitted over the trailing window.
    pub fn window_rows(&mut self, now: Instant) -> u64 {
        self.advance(now);
        self.ring.iter().sum()
    }

    /// The nominal window span (8 buckets).
    pub fn window(&self) -> Duration {
        self.bucket * 8
    }

    pub fn level(&mut self, now: Instant) -> Overload {
        if self.limit_rows == 0 {
            return Overload::Clear;
        }
        let sum = self.window_rows(now);
        // 1.5x the limit, in integer math.
        if sum * 2 >= self.limit_rows * 3 {
            Overload::ShedEvalAndBulk
        } else if sum >= self.limit_rows {
            Overload::ShedBulk
        } else {
            Overload::Clear
        }
    }
}

/// Why a submission was shed (static so the hot path never formats).
pub const SHED_OVERLOAD: &str = "overload: bulk traffic shed";
pub const SHED_OVERLOAD_SEVERE: &str = "overload: only actor traffic admitted";
pub const SHED_QUEUE_FULL: &str = "admission queue full";
pub const SHED_DEADLINE: &str = "deadline unmeetable at current backlog";

/// One admission verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    Shed(&'static str),
}

/// The global admission policy: overload ladder, bounded admission
/// queue, and a deadline estimate from the window's own throughput.
/// `actor`-class traffic is exempt from every shed rule; the
/// per-connection in-flight row budget (PR 8) still applies to it.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    overload: OverloadDetector,
    /// Global queued-row bound (0 = unbounded).
    max_queue_rows: u64,
    /// Target time-to-service (0 = no deadline shedding).
    deadline: Duration,
}

impl AdmissionPolicy {
    pub fn new(
        window: Duration,
        overload_rows: u64,
        max_queue_rows: u64,
        deadline: Duration,
        now: Instant,
    ) -> Self {
        Self {
            overload: OverloadDetector::new(window, overload_rows, now),
            max_queue_rows,
            deadline,
        }
    }

    /// Decide one submission of `rows` rows from a `class` connection,
    /// with `queued_rows` already admitted and not yet replied to.
    /// Admitted rows are recorded into the overload window.
    pub fn decide(
        &mut self,
        class: PriorityClass,
        rows: u64,
        queued_rows: u64,
        now: Instant,
    ) -> AdmissionDecision {
        match self.overload.level(now) {
            Overload::ShedEvalAndBulk if class != PriorityClass::Actor => {
                return AdmissionDecision::Shed(SHED_OVERLOAD_SEVERE);
            }
            Overload::ShedBulk if class == PriorityClass::Bulk => {
                return AdmissionDecision::Shed(SHED_OVERLOAD);
            }
            _ => {}
        }
        if class != PriorityClass::Actor {
            if self.max_queue_rows > 0 && queued_rows + rows > self.max_queue_rows {
                return AdmissionDecision::Shed(SHED_QUEUE_FULL);
            }
            if !self.deadline.is_zero() {
                // Estimated wait = backlog / observed window throughput.
                // A backlog with zero observed throughput cannot meet
                // any deadline.
                let served = self.overload.window_rows(now);
                let unmeetable = if served == 0 {
                    queued_rows > 0
                } else {
                    self.overload
                        .window()
                        .mul_f64(queued_rows as f64 / served as f64)
                        > self.deadline
                };
                if unmeetable {
                    return AdmissionDecision::Shed(SHED_DEADLINE);
                }
            }
        }
        self.overload.record(rows, now);
        AdmissionDecision::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn priority_class_wire_byte_roundtrip() {
        for c in [PriorityClass::Actor, PriorityClass::Eval, PriorityClass::Bulk] {
            assert_eq!(PriorityClass::from_u8(c.as_u8()), Some(c));
        }
        assert_eq!(PriorityClass::from_u8(0), Some(PriorityClass::Actor));
        assert_eq!(PriorityClass::from_u8(3), None);
        assert_eq!(PriorityClass::from_u8(255), None);
        assert!(PriorityClass::Actor < PriorityClass::Eval);
        assert!(PriorityClass::Eval < PriorityClass::Bulk);
        assert_eq!(PriorityClass::Bulk.name(), "bulk");
    }

    #[test]
    fn overload_ladder_sheds_bulk_then_eval_never_actor() {
        let t0 = Instant::now();
        let mut p = AdmissionPolicy::new(ms(8000), 100, 0, ms(0), t0);
        // Below the limit: everyone is admitted.
        for _ in 0..9 {
            assert_eq!(p.decide(PriorityClass::Bulk, 10, 0, t0), AdmissionDecision::Admit);
        }
        // Window hits 100: bulk shed, eval and actor still admitted.
        assert_eq!(p.decide(PriorityClass::Eval, 10, 0, t0), AdmissionDecision::Admit);
        assert_eq!(
            p.decide(PriorityClass::Bulk, 10, 0, t0),
            AdmissionDecision::Shed(SHED_OVERLOAD)
        );
        // Push to 1.5x: eval shed too; actor never.
        for _ in 0..5 {
            assert_eq!(p.decide(PriorityClass::Eval, 10, 0, t0), AdmissionDecision::Admit);
        }
        assert_eq!(
            p.decide(PriorityClass::Eval, 10, 0, t0),
            AdmissionDecision::Shed(SHED_OVERLOAD_SEVERE)
        );
        assert_eq!(
            p.decide(PriorityClass::Bulk, 10, 0, t0),
            AdmissionDecision::Shed(SHED_OVERLOAD_SEVERE)
        );
        assert_eq!(p.decide(PriorityClass::Actor, 10, 0, t0), AdmissionDecision::Admit);
    }

    #[test]
    fn window_decays_as_time_passes() {
        let t0 = Instant::now();
        let mut d = OverloadDetector::new(ms(800), 100, t0);
        d.record(200, t0);
        assert_eq!(d.level(t0), Overload::ShedEvalAndBulk);
        // A full window later, the burst has aged out.
        assert_eq!(d.level(t0 + ms(900)), Overload::Clear);
        assert_eq!(d.window_rows(t0 + ms(900)), 0);
    }

    #[test]
    fn queue_bound_and_deadline_exempt_actor_class() {
        let t0 = Instant::now();
        let mut p = AdmissionPolicy::new(ms(800), 0, 64, ms(0), t0);
        assert_eq!(
            p.decide(PriorityClass::Eval, 8, 60, t0),
            AdmissionDecision::Shed(SHED_QUEUE_FULL)
        );
        assert_eq!(p.decide(PriorityClass::Eval, 8, 56, t0), AdmissionDecision::Admit);
        assert_eq!(p.decide(PriorityClass::Actor, 8, 1000, t0), AdmissionDecision::Admit);

        // Deadline: backlog with zero window throughput is unmeetable.
        let mut p = AdmissionPolicy::new(ms(800), 0, 0, ms(50), t0);
        assert_eq!(
            p.decide(PriorityClass::Bulk, 8, 32, t0),
            AdmissionDecision::Shed(SHED_DEADLINE)
        );
        assert_eq!(p.decide(PriorityClass::Actor, 8, 32, t0), AdmissionDecision::Admit);
        // 8 rows now in the window; est. wait for 32 queued rows is
        // 800ms * 32/8 = 3.2s > 50ms: still unmeetable for bulk...
        assert_eq!(
            p.decide(PriorityClass::Bulk, 8, 32, t0),
            AdmissionDecision::Shed(SHED_DEADLINE)
        );
        // ...but an empty backlog always meets the deadline.
        assert_eq!(p.decide(PriorityClass::Bulk, 8, 0, t0), AdmissionDecision::Admit);
    }
}
