//! Resilient policy serving (DESIGN.md §16).
//!
//! ROADMAP open item 4's robustness core: the pooled central batcher
//! wrapped as a long-lived *service*. The data plane is unchanged —
//! PR 8 slab frames into `transport::FleetServer` — and this module
//! adds the serving envelope around it:
//!
//! * [`control`] — a minimal line-delimited text control socket
//!   (`rlarch serve --control <addr>`): `health` / `ready` / `stats` /
//!   `reload <dir>` / `shutdown`, driven by `rlarch ctl` or anything
//!   that can write a line to a socket.
//! * [`admission`] — per-connection [`PriorityClass`]es (`actor` >
//!   `eval` > `bulk`, one `Hello` pad byte), a bounded global
//!   admission queue, deadline-aware shedding, and a sliding-window
//!   overload detector that degrades down the ladder (`bulk` first,
//!   then `eval`, never `actor`).
//! * [`breaker`] — a consecutive-failure [`CircuitBreaker`] in front
//!   of the backend: fail-fast shed replies while open, one half-open
//!   probe to recover.
//!
//! All shedding reuses the transport's `shed:` reply flow, so
//! `RemoteClient` resubmission is untouched; checkpoint hot-reload
//! (drain → swap → generation bump → resync) lives in
//! `coordinator::fleet` where the model and checkpoint machinery are.
//! [`ServeGate`] below is the shared state the data plane consults per
//! submission; with the control plane off it is never constructed and
//! every path is bit-for-bit PR 9 (`serve_defaults_off` equivalence).

pub mod admission;
pub mod breaker;
pub mod control;

pub use admission::{AdmissionDecision, AdmissionPolicy, OverloadDetector, PriorityClass};
pub use breaker::{BreakerState, CircuitBreaker};
pub use control::{parse_line, Command, ControlServer};

use crate::config::ServeConfig;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shed reason while a reload drain has admission paused.
pub const SHED_PAUSED: &str = "serving paused (reload drain)";
/// Shed reason while the circuit breaker is open.
pub const SHED_BREAKER: &str = "circuit open: backend failing";

/// Shared serving state consulted by every `Submit` on the data plane
/// and flipped by the control plane: the admission switch (reload
/// drains and graceful shutdown pause it), the global in-flight row
/// count (the drain barrier), and the optional admission policy and
/// circuit breaker. All hot-path operations are lock-free or a single
/// uncontended mutex, and allocation-free (`micro_transport` gate).
pub struct ServeGate {
    admitting: AtomicBool,
    inflight_rows: AtomicU64,
    admission: Mutex<Option<AdmissionPolicy>>,
    breaker: Mutex<Option<CircuitBreaker>>,
    breaker_enabled: bool,
}

impl ServeGate {
    pub fn new(
        admission: Option<AdmissionPolicy>,
        breaker: Option<CircuitBreaker>,
    ) -> ServeGate {
        ServeGate {
            admitting: AtomicBool::new(true),
            inflight_rows: AtomicU64::new(0),
            breaker_enabled: breaker.is_some(),
            admission: Mutex::new(admission),
            breaker: Mutex::new(breaker),
        }
    }

    /// Build from config; `None` when every serving feature is off
    /// (the gate is then never consulted — the PR 9 identity path).
    pub fn from_config(cfg: &ServeConfig, now: Instant) -> Option<Arc<ServeGate>> {
        if !cfg.enabled() {
            return None;
        }
        let admission = (cfg.admission_rows > 0
            || cfg.overload_rows > 0
            || cfg.deadline_ms > 0)
            .then(|| {
                AdmissionPolicy::new(
                    Duration::from_millis(cfg.overload_window_ms),
                    cfg.overload_rows as u64,
                    cfg.admission_rows as u64,
                    Duration::from_millis(cfg.deadline_ms),
                    now,
                )
            });
        let breaker = (cfg.backend_failure_threshold > 0).then(|| {
            CircuitBreaker::new(
                cfg.backend_failure_threshold as u32,
                Duration::from_millis(cfg.breaker_cooloff_ms),
                now,
            )
        });
        Some(Arc::new(ServeGate::new(admission, breaker)))
    }

    pub fn is_admitting(&self) -> bool {
        self.admitting.load(Ordering::Acquire)
    }

    pub fn set_admitting(&self, on: bool) {
        self.admitting.store(on, Ordering::Release);
    }

    /// Rows admitted and not yet replied to, fleet-wide.
    pub fn inflight_rows(&self) -> u64 {
        self.inflight_rows.load(Ordering::Acquire)
    }

    /// Count `rows` toward the in-flight total (at the same point the
    /// per-connection budget counts them); returns the prior total.
    pub fn begin_rows(&self, rows: u64) -> u64 {
        self.inflight_rows.fetch_add(rows, Ordering::AcqRel)
    }

    /// A reply chunk of `rows` left through a connection writer.
    pub fn end_rows(&self, rows: u64) {
        self.inflight_rows.fetch_sub(rows, Ordering::AcqRel);
    }

    /// Admission verdict for one submission (admit when no policy is
    /// configured). `queued_rows` is the caller's pre-`begin_rows`
    /// in-flight snapshot.
    pub fn decide(
        &self,
        class: PriorityClass,
        rows: u64,
        queued_rows: u64,
        now: Instant,
    ) -> AdmissionDecision {
        match self.admission.lock().unwrap().as_mut() {
            Some(p) => p.decide(class, rows, queued_rows, now),
            None => AdmissionDecision::Admit,
        }
    }

    /// Whether the breaker admits a submission at `now`.
    pub fn breaker_allow(&self, now: Instant) -> bool {
        if !self.breaker_enabled {
            return true;
        }
        match self.breaker.lock().unwrap().as_mut() {
            Some(b) => b.allow(now),
            None => true,
        }
    }

    pub fn breaker_on_success(&self) {
        if !self.breaker_enabled {
            return;
        }
        if let Some(b) = self.breaker.lock().unwrap().as_mut() {
            b.on_success();
        }
    }

    pub fn breaker_on_failure(&self, now: Instant) {
        if !self.breaker_enabled {
            return;
        }
        if let Some(b) = self.breaker.lock().unwrap().as_mut() {
            b.on_failure(now);
        }
    }

    /// Breaker position for `stats` (None = breaker not configured).
    pub fn breaker_state(&self) -> Option<BreakerState> {
        self.breaker.lock().unwrap().as_ref().map(|b| b.state())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_defaults_admit_everything() {
        let g = ServeGate::new(None, None);
        let now = Instant::now();
        assert!(g.is_admitting());
        assert!(g.breaker_allow(now));
        assert_eq!(
            g.decide(PriorityClass::Bulk, 64, 0, now),
            AdmissionDecision::Admit
        );
        assert_eq!(g.begin_rows(8), 0);
        assert_eq!(g.begin_rows(4), 8);
        g.end_rows(12);
        assert_eq!(g.inflight_rows(), 0);
        assert_eq!(g.breaker_state(), None);
        g.breaker_on_success();
        g.breaker_on_failure(now);
    }

    #[test]
    fn from_config_is_none_unless_a_feature_is_on() {
        let now = Instant::now();
        let off = ServeConfig::default();
        assert!(ServeGate::from_config(&off, now).is_none());
        let on = ServeConfig {
            backend_failure_threshold: 3,
            ..ServeConfig::default()
        };
        let g = ServeGate::from_config(&on, now).unwrap();
        assert_eq!(g.breaker_state(), Some(BreakerState::Closed));
        let on = ServeConfig {
            control: "uds:/tmp/x.sock".into(),
            ..ServeConfig::default()
        };
        assert!(ServeGate::from_config(&on, now).is_some());
        let on = ServeConfig {
            overload_rows: 100,
            ..ServeConfig::default()
        };
        let g = ServeGate::from_config(&on, now).unwrap();
        // Overload configured: bulk past the limit is shed.
        assert_eq!(
            g.decide(PriorityClass::Bulk, 200, 0, now),
            AdmissionDecision::Admit
        );
        assert_eq!(
            g.decide(PriorityClass::Bulk, 1, 0, now),
            AdmissionDecision::Shed(admission::SHED_OVERLOAD)
        );
    }

    #[test]
    fn pause_resume_flips_admitting() {
        let g = ServeGate::new(None, None);
        g.set_admitting(false);
        assert!(!g.is_admitting());
        g.set_admitting(true);
        assert!(g.is_admitting());
    }
}
