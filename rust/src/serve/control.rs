//! Line-delimited text control plane for `rlarch serve`
//! (DESIGN.md §16).
//!
//! The data plane speaks slab frames; operations speak one-line text
//! over a second listener (`rlarch serve --control <addr>`), so `nc`,
//! a shell script, or `rlarch ctl` can drive it. Requests are one line
//! (`health`, `ready`, `stats`, `reload <dir>`, `shutdown`); replies
//! are one line starting `ok ` or `err `. The parser never panics on
//! garbage (property-tested) and unknown commands name the offending
//! token in the error reply.
//!
//! [`ControlServer`] owns one polling accept loop + line-reader thread;
//! commands are handed to a single handler closure (the coordinator's
//! reload/drain/shutdown logic in `coordinator::fleet`), so command
//! execution is serialized by construction — there is never more than
//! one reload or drain in flight.

use crate::exec::ShutdownToken;
use crate::transport::{Addr, Listener, Stream};
use std::io::{Read, Write};
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

/// One parsed control command.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Command {
    /// Liveness probe: replies `ok` while the process is up.
    Health,
    /// Readiness probe: `ok` only when admitting traffic.
    Ready,
    /// One-line counters snapshot (generation, steps, reloads, sheds).
    Stats,
    /// Hot-reload a checkpoint directory under traffic.
    Reload(String),
    /// Graceful shutdown: stop admitting, drain, checkpoint, goodbye.
    Shutdown,
}

/// Parse one control line. Never panics; unknown commands, missing or
/// trailing arguments all return an error naming the offending token.
pub fn parse_line(line: &str) -> Result<Command, String> {
    let mut words = line.split_whitespace();
    let head = words.next().ok_or_else(|| "empty command".to_string())?;
    let cmd = match head {
        "health" => Command::Health,
        "ready" => Command::Ready,
        "stats" => Command::Stats,
        "shutdown" => Command::Shutdown,
        "reload" => {
            let dir = words
                .next()
                .ok_or_else(|| "reload: want `reload <dir>`".to_string())?;
            Command::Reload(dir.to_string())
        }
        other => return Err(format!("unknown command `{other}`")),
    };
    if let Some(extra) = words.next() {
        return Err(format!("trailing token `{extra}` after `{head}`"));
    }
    Ok(cmd)
}

/// The control listener thread. Accepts one client at a time (commands
/// are rare and serialized anyway), reads newline-delimited commands,
/// and replies `ok <detail>` / `err <detail>` per line.
pub struct ControlServer {
    thread: Option<thread::JoinHandle<()>>,
    uds_path: Option<PathBuf>,
}

/// The command executor the server thread calls per parsed line; the
/// `Ok`/`Err` string becomes the `ok ...` / `err ...` reply line.
pub type Handler = Box<dyn FnMut(Command) -> Result<String, String> + Send>;

impl ControlServer {
    /// Bind `addr` and serve until `shutdown` is signalled. The
    /// handler runs on the control thread; its `Ok`/`Err` string
    /// becomes the reply line.
    pub fn spawn(
        addr: &Addr,
        shutdown: ShutdownToken,
        mut handler: Handler,
    ) -> anyhow::Result<ControlServer> {
        let listener = Listener::bind(addr)?;
        let uds_path = match addr {
            Addr::Unix(p) => Some(p.clone()),
            Addr::Tcp(_) => None,
        };
        let thread = thread::Builder::new()
            .name("rlarch-control".into())
            .spawn(move || {
                while !shutdown.is_signalled() {
                    match listener.poll_accept() {
                        Ok(Some(stream)) => {
                            serve_client(stream, &shutdown, &mut handler)
                        }
                        Ok(None) => {
                            if shutdown.sleep_interruptible(Duration::from_millis(20))
                            {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(ControlServer {
            thread: Some(thread),
            uds_path,
        })
    }

    /// Join the control thread (the shutdown token must already be
    /// signalled) and remove a UDS socket file.
    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        if let Some(p) = &self.uds_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Serve one control client: accumulate bytes, split on `\n`, handle
/// each line, write the reply. Returns on EOF, I/O error, or shutdown.
fn serve_client(mut stream: Stream, shutdown: &ShutdownToken, handler: &mut Handler) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut pending: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => {
                pending.extend_from_slice(&chunk[..n]);
                while let Some(pos) = pending.iter().position(|&b| b == b'\n') {
                    let line: Vec<u8> = pending.drain(..=pos).collect();
                    let text = String::from_utf8_lossy(&line);
                    let text = text.trim();
                    if text.is_empty() {
                        continue;
                    }
                    let reply = match parse_line(text) {
                        Ok(cmd) => handler(cmd),
                        Err(e) => Err(e),
                    };
                    let out = match &reply {
                        Ok(msg) => format!("ok {msg}\n"),
                        Err(msg) => format!("err {msg}\n"),
                    };
                    if stream.write_all(out.as_bytes()).is_err() {
                        return;
                    }
                    let _ = stream.flush();
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shutdown.is_signalled() {
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// One-shot control client (`rlarch ctl`): send `line`, return the
/// reply line (without the trailing newline).
pub fn send_command(addr: &Addr, line: &str) -> anyhow::Result<String> {
    let mut stream = crate::transport::dial(addr, 0, 1, None)?;
    stream.write_all(line.trim().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut reply = Vec::new();
    let mut byte = [0u8; 64];
    loop {
        match stream.read(&mut byte) {
            Ok(0) => break,
            Ok(n) => {
                reply.extend_from_slice(&byte[..n]);
                if reply.contains(&b'\n') {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(anyhow::anyhow!("control read: {e}")),
        }
    }
    let text = String::from_utf8_lossy(&reply);
    let line = text.lines().next().unwrap_or("").to_string();
    anyhow::ensure!(!line.is_empty(), "control connection closed without a reply");
    Ok(line)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_command() {
        assert_eq!(parse_line("health"), Ok(Command::Health));
        assert_eq!(parse_line("  ready  "), Ok(Command::Ready));
        assert_eq!(parse_line("stats"), Ok(Command::Stats));
        assert_eq!(parse_line("shutdown"), Ok(Command::Shutdown));
        assert_eq!(
            parse_line("reload /tmp/ckpt"),
            Ok(Command::Reload("/tmp/ckpt".into()))
        );
    }

    #[test]
    fn rejects_garbage_naming_the_token() {
        let err = parse_line("explode").unwrap_err();
        assert!(err.contains("`explode`"), "{err}");
        let err = parse_line("reload").unwrap_err();
        assert!(err.contains("reload <dir>"), "{err}");
        let err = parse_line("health now please").unwrap_err();
        assert!(err.contains("`now`"), "{err}");
        let err = parse_line("reload /a /b").unwrap_err();
        assert!(err.contains("`/b`"), "{err}");
        assert!(parse_line("").is_err());
        assert!(parse_line("   \t ").is_err());
    }

    #[test]
    fn control_server_round_trips_over_uds() {
        let dir = std::env::temp_dir().join("rlarch_control_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("ctl_{}.sock", std::process::id()));
        let addr = Addr::Unix(path.clone());
        let shutdown = ShutdownToken::new();
        let server = ControlServer::spawn(
            &addr,
            shutdown.clone(),
            Box::new(|cmd| match cmd {
                Command::Health => Ok("healthy".into()),
                Command::Reload(dir) => Err(format!("no checkpoint at {dir}")),
                _ => Ok("noop".into()),
            }),
        )
        .unwrap();
        assert_eq!(send_command(&addr, "health").unwrap(), "ok healthy");
        assert_eq!(
            send_command(&addr, "reload /nope").unwrap(),
            "err no checkpoint at /nope"
        );
        assert_eq!(
            send_command(&addr, "bogus").unwrap(),
            "err unknown command `bogus`"
        );
        shutdown.signal();
        server.join();
        assert!(!path.exists(), "uds socket file removed on join");
    }
}
