//! Metrics registry: counters, gauges, timers; CSV/markdown reporting.
//!
//! The coordinator and simulator publish into a shared `Registry`
//! (lock-per-metric, cheap enough for the hot path at our rates); benches
//! snapshot it for their reports.

use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic counter.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    v: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bit-cast f64).
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    v: Arc<AtomicU64>,
}

impl Gauge {
    pub fn set(&self, x: f64) {
        self.v.store(x.to_bits(), Ordering::Relaxed);
    }

    /// Atomically add `delta` (CAS loop). Lets multiple writers share a
    /// level-style gauge (e.g. `policy.inflight` across actor threads)
    /// without clobbering each other the way `set` would.
    pub fn add(&self, delta: f64) {
        let _ = self
            .v
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f64::from_bits(bits) + delta).to_bits())
            });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.v.load(Ordering::Relaxed))
    }
}

/// Aggregating timer/summary (mean/std/min/max over recorded values).
#[derive(Clone, Debug, Default)]
pub struct Timer {
    s: Arc<Mutex<Summary>>,
}

impl Timer {
    pub fn record(&self, seconds: f64) {
        self.s.lock().unwrap().add(seconds);
    }

    /// Time a closure and record its duration.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.record(t0.elapsed().as_secs_f64());
        r
    }

    pub fn snapshot(&self) -> Summary {
        self.s.lock().unwrap().clone()
    }
}

/// Registry of named metrics. Cloning shares the underlying maps.
#[derive(Clone, Default)]
pub struct Registry {
    counters: Arc<Mutex<BTreeMap<String, Counter>>>,
    gauges: Arc<Mutex<BTreeMap<String, Gauge>>>,
    timers: Arc<Mutex<BTreeMap<String, Timer>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn timer(&self, name: &str) -> Timer {
        self.timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Flat snapshot of every metric for reports.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), c.get() as f64);
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.insert(k.clone(), g.get());
        }
        for (k, t) in self.timers.lock().unwrap().iter() {
            let s = t.snapshot();
            if s.count() > 0 {
                out.insert(format!("{k}.mean"), s.mean());
                out.insert(format!("{k}.max"), s.max());
                out.insert(format!("{k}.count"), s.count() as f64);
            }
        }
        out
    }

    /// Render a two-column markdown table of the snapshot.
    pub fn to_markdown(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::from("| metric | value |\n|---|---|\n");
        for (k, v) in snap {
            out.push_str(&format!("| {k} | {v:.6} |\n"));
        }
        out
    }

    /// Render `name,value` CSV of the snapshot.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("metric,value\n");
        for (k, v) in self.snapshot() {
            out.push_str(&format!("{k},{v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_shared_across_clones() {
        let r = Registry::new();
        let a = r.counter("steps");
        let b = r.counter("steps");
        a.add(3);
        b.inc();
        assert_eq!(r.counter("steps").get(), 4);
    }

    #[test]
    fn gauge_overwrites() {
        let r = Registry::new();
        r.gauge("power_w").set(70.0);
        r.gauge("power_w").set(250.5);
        assert_eq!(r.gauge("power_w").get(), 250.5);
    }

    #[test]
    fn gauge_add_accumulates_across_clones() {
        let r = Registry::new();
        let g = r.gauge("inflight");
        g.set(0.0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let g = g.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        g.add(1.0);
                        g.add(-1.0);
                    }
                    g.add(1.0);
                });
            }
        });
        // 4 threads each net +1: concurrent add must not lose updates.
        assert_eq!(r.gauge("inflight").get(), 4.0);
    }

    #[test]
    fn timer_aggregates() {
        let r = Registry::new();
        let t = r.timer("step");
        t.record(0.1);
        t.record(0.3);
        let s = t.snapshot();
        assert_eq!(s.count(), 2);
        assert!((s.mean() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn timer_time_closure() {
        let r = Registry::new();
        let out = r.timer("work").time(|| 21 * 2);
        assert_eq!(out, 42);
        assert_eq!(r.timer("work").snapshot().count(), 1);
    }

    #[test]
    fn snapshot_and_render() {
        let r = Registry::new();
        r.counter("a").add(7);
        r.gauge("b").set(1.5);
        r.timer("t").record(2.0);
        let snap = r.snapshot();
        assert_eq!(snap["a"], 7.0);
        assert_eq!(snap["b"], 1.5);
        assert_eq!(snap["t.count"], 1.0);
        assert!(r.to_markdown().contains("| a |"));
        assert!(r.to_csv().starts_with("metric,value\n"));
    }

    #[test]
    fn concurrent_counting() {
        let r = Registry::new();
        let c = r.counter("x");
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}
