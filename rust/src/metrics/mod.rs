//! Metrics registry: counters, gauges, timers; CSV/markdown reporting.
//!
//! The coordinator and simulator publish into a shared `Registry`;
//! benches snapshot it for their reports. Counters and gauges are single
//! atomics. Timers are *striped*: recordings land in one of a fixed set
//! of cache-line-padded per-thread accumulators (selected by a
//! thread-local stripe id) and are merged only at snapshot, so hot-path
//! instrumentation in the actor/batcher/learner threads never serializes
//! on a shared lock. `benches/micro_metrics.rs` pins the record path at
//! 0 steady-state allocations.
//!
//! The registry also carries the optional span [`Tracer`]
//! (see `telemetry::span`): threads fetch a [`SpanRecorder`] the same way
//! they fetch counters. With no tracer installed (the default) the
//! recorder is inert.

use crate::telemetry::span::{SpanRecorder, Tracer};
use crate::util::stats::Summary;
use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic counter.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    v: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bit-cast f64). Tracks whether it was ever
/// written so `Registry::snapshot` can skip registered-but-never-set
/// gauges instead of reporting them as `0.0` garbage.
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    v: Arc<AtomicU64>,
    written: Arc<AtomicBool>,
}

impl Gauge {
    pub fn set(&self, x: f64) {
        self.v.store(x.to_bits(), Ordering::Relaxed);
        self.written.store(true, Ordering::Release);
    }

    /// Atomically add `delta` (CAS loop). Lets multiple writers share a
    /// level-style gauge (e.g. `policy.inflight` across actor threads)
    /// without clobbering each other the way `set` would.
    pub fn add(&self, delta: f64) {
        let _ = self
            .v
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f64::from_bits(bits) + delta).to_bits())
            });
        self.written.store(true, Ordering::Release);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.v.load(Ordering::Relaxed))
    }

    /// Whether `set`/`add` was ever called.
    pub fn written(&self) -> bool {
        self.written.load(Ordering::Acquire)
    }
}

/// Stripe count for timers. A power of two ≥ the worker-thread count of
/// a typical run; threads hash onto stripes round-robin, so two threads
/// only share a stripe (and its uncontended-in-that-case lock) once more
/// than `TIMER_STRIPES` threads record into the *same* timer.
const TIMER_STRIPES: usize = 16;

static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's stripe id, assigned round-robin on first use.
    /// Const-initialized: the first access performs no lazy allocation,
    /// keeping `Timer::record` allocation-free even on a fresh thread.
    static STRIPE_ID: Cell<usize> = const { Cell::new(usize::MAX) };
}

fn stripe_id() -> usize {
    STRIPE_ID.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            v
        } else {
            let v = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) % TIMER_STRIPES;
            c.set(v);
            v
        }
    })
}

/// One timer stripe, padded to a cache line so concurrent writers on
/// different stripes never false-share.
#[repr(align(64))]
#[derive(Debug, Default)]
struct Stripe {
    s: Mutex<Summary>,
}

/// Aggregating timer/summary (mean/std/min/max/sum over recorded
/// values). Recordings go to the calling thread's stripe; `snapshot`
/// merges all stripes via `Summary::merge`. The per-stripe mutex is
/// uncontended in steady state (each worker owns its stripe), so
/// `record` is a thread-local lock + Welford update: no allocation, no
/// cross-thread serialization.
#[derive(Clone, Debug)]
pub struct Timer {
    stripes: Arc<[Stripe; TIMER_STRIPES]>,
}

impl Default for Timer {
    fn default() -> Self {
        Self {
            stripes: Arc::new(Default::default()),
        }
    }
}

impl Timer {
    pub fn record(&self, seconds: f64) {
        self.stripes[stripe_id()].s.lock().unwrap().add(seconds);
    }

    /// Time a closure and record its duration.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.record(t0.elapsed().as_secs_f64());
        r
    }

    pub fn snapshot(&self) -> Summary {
        let mut out = Summary::new();
        for stripe in self.stripes.iter() {
            out.merge(&stripe.s.lock().unwrap());
        }
        out
    }
}

/// Registry of named metrics. Cloning shares the underlying maps.
#[derive(Clone, Default)]
pub struct Registry {
    counters: Arc<Mutex<BTreeMap<String, Counter>>>,
    gauges: Arc<Mutex<BTreeMap<String, Gauge>>>,
    timers: Arc<Mutex<BTreeMap<String, Timer>>>,
    tracer: Arc<Mutex<Option<Arc<Tracer>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn timer(&self, name: &str) -> Timer {
        self.timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Install the span tracer (telemetry-enabled runs only; the
    /// default registry has none and recorders come back inert).
    pub fn install_tracer(&self, t: Arc<Tracer>) {
        *self.tracer.lock().unwrap() = Some(t);
    }

    pub fn tracer(&self) -> Option<Arc<Tracer>> {
        self.tracer.lock().unwrap().clone()
    }

    /// Per-thread span recorder. `label` is lazy formatting arguments
    /// (`format_args!("actor-{id}")`) so the disabled path never builds
    /// the label string — recorder fetch stays allocation-free when no
    /// tracer is installed.
    pub fn span_recorder(&self, label: std::fmt::Arguments<'_>) -> SpanRecorder {
        match self.tracer.lock().unwrap().as_ref() {
            Some(t) => t.recorder(&label.to_string()),
            None => SpanRecorder::disabled(),
        }
    }

    /// Flat snapshot of every metric for reports. Never-recorded timers
    /// and never-written gauges are skipped; timers emit
    /// `.mean`/`.max`/`.count`/`.sum` so rates can be derived offline.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), c.get() as f64);
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            if g.written() {
                out.insert(k.clone(), g.get());
            }
        }
        for (k, t) in self.timers.lock().unwrap().iter() {
            let s = t.snapshot();
            if s.count() > 0 {
                out.insert(format!("{k}.mean"), s.mean());
                out.insert(format!("{k}.max"), s.max());
                out.insert(format!("{k}.count"), s.count() as f64);
                out.insert(format!("{k}.sum"), s.sum());
            }
        }
        out
    }

    /// Render a two-column markdown table of the snapshot.
    pub fn to_markdown(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::from("| metric | value |\n|---|---|\n");
        for (k, v) in snap {
            out.push_str(&format!("| {k} | {v:.6} |\n"));
        }
        out
    }

    /// Render `name,value` CSV of the snapshot.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("metric,value\n");
        for (k, v) in self.snapshot() {
            out.push_str(&format!("{k},{v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_shared_across_clones() {
        let r = Registry::new();
        let a = r.counter("steps");
        let b = r.counter("steps");
        a.add(3);
        b.inc();
        assert_eq!(r.counter("steps").get(), 4);
    }

    #[test]
    fn gauge_overwrites() {
        let r = Registry::new();
        r.gauge("power_w").set(70.0);
        r.gauge("power_w").set(250.5);
        assert_eq!(r.gauge("power_w").get(), 250.5);
    }

    #[test]
    fn gauge_add_accumulates_across_clones() {
        let r = Registry::new();
        let g = r.gauge("inflight");
        g.set(0.0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let g = g.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        g.add(1.0);
                        g.add(-1.0);
                    }
                    g.add(1.0);
                });
            }
        });
        // 4 threads each net +1: concurrent add must not lose updates.
        assert_eq!(r.gauge("inflight").get(), 4.0);
    }

    #[test]
    fn timer_aggregates() {
        let r = Registry::new();
        let t = r.timer("step");
        t.record(0.1);
        t.record(0.3);
        let s = t.snapshot();
        assert_eq!(s.count(), 2);
        assert!((s.mean() - 0.2).abs() < 1e-12);
        assert!((s.sum() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn timer_merges_across_threads() {
        // Striped accumulation must still aggregate every recording: 8
        // threads land on (at least two) different stripes and the
        // snapshot merge sees all of them.
        let r = Registry::new();
        let t = r.timer("striped");
        std::thread::scope(|s| {
            for i in 0..8 {
                let t = t.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        t.record((i + 1) as f64);
                    }
                });
            }
        });
        let s = t.snapshot();
        assert_eq!(s.count(), 800);
        // sum = 100 * (1 + 2 + ... + 8) = 3600
        assert!((s.sum() - 3600.0).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 8.0);
    }

    #[test]
    fn timer_time_closure() {
        let r = Registry::new();
        let out = r.timer("work").time(|| 21 * 2);
        assert_eq!(out, 42);
        assert_eq!(r.timer("work").snapshot().count(), 1);
    }

    #[test]
    fn snapshot_and_render() {
        let r = Registry::new();
        r.counter("a").add(7);
        r.gauge("b").set(1.5);
        r.timer("t").record(2.0);
        let snap = r.snapshot();
        assert_eq!(snap["a"], 7.0);
        assert_eq!(snap["b"], 1.5);
        assert_eq!(snap["t.count"], 1.0);
        assert_eq!(snap["t.sum"], 2.0);
        assert!(r.to_markdown().contains("| a |"));
        assert!(r.to_csv().starts_with("metric,value\n"));
    }

    #[test]
    fn snapshot_skips_unwritten_gauges_and_empty_timers() {
        let r = Registry::new();
        let _registered_only = r.gauge("never_set");
        let _empty = r.timer("never_recorded");
        r.gauge("zeroed").set(0.0);
        let snap = r.snapshot();
        assert!(
            !snap.contains_key("never_set"),
            "unwritten gauge leaked into snapshot"
        );
        assert!(!snap.contains_key("never_recorded.count"));
        // An explicit 0.0 write IS a value and must survive.
        assert_eq!(snap["zeroed"], 0.0);
    }

    #[test]
    fn concurrent_counting() {
        let r = Registry::new();
        let c = r.counter("x");
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn concurrent_registry_access_under_snapshot_loop() {
        // Pins the lock-ordering contract: 8 threads hammer the
        // name->metric maps (registering and writing counters, gauges,
        // and timers) while the main thread snapshots in a loop. No
        // deadlock, no lost writes to the summed-at-end counters.
        let r = Registry::new();
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            for tid in 0..8 {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..500 {
                        r.counter(&format!("c{}", i % 5)).inc();
                        r.gauge(&format!("g{}", i % 3)).set(tid as f64);
                        r.timer(&format!("t{}", i % 4)).record(1e-6);
                        r.counter("total").inc();
                    }
                });
            }
            let stop2 = stop.clone();
            let reg = r.clone();
            let snapper = s.spawn(move || {
                let mut snaps = 0u64;
                while !stop2.load(Ordering::Relaxed) {
                    let snap = reg.snapshot();
                    // Monotone sanity: whatever is visible is coherent.
                    if let Some(v) = snap.get("total") {
                        assert!(*v <= 8.0 * 500.0);
                    }
                    snaps += 1;
                }
                snaps
            });
            // Writers finish when the scope joins them; then stop the
            // snapshot loop. (Spawned handles other than `snapper` are
            // joined implicitly by scope exit.)
            for _ in 0..100 {
                std::thread::yield_now();
            }
            stop.store(true, Ordering::Relaxed);
            let snaps = snapper.join().unwrap();
            assert!(snaps > 0);
        });
        let snap = r.snapshot();
        assert_eq!(snap["total"], 8.0 * 500.0);
        let timer_count: f64 = (0..4)
            .map(|i| snap[&format!("t{i}.count")])
            .sum();
        assert_eq!(timer_count, 8.0 * 500.0);
    }
}
