//! # rlarch — Distributed RL on CPU-GPU systems, reproduced.
//!
//! Library reproduction of *"The Architectural Implications of Distributed
//! Reinforcement Learning on CPU-GPU Systems"* (Inci et al., EMC² 2020):
//! a SEED-RL-style central-inference R2D2 training framework (Rust
//! coordinator + AOT JAX/Pallas compute via PJRT) plus an NVArchSim-style
//! CPU-GPU architectural simulator that regenerates the paper's Figures
//! 2-4. See DESIGN.md for the system inventory and per-experiment index.
//!
//! Layer map:
//! * [`coordinator`] — L3: actors, central inference batcher, learner.
//!   Each actor thread drives a [`vecenv::VecEnv`]; the
//!   `actors.envs_per_actor` knob sets how many environments ride on one
//!   thread (1 = the paper's baseline topology). The batcher runs the
//!   pooled slab protocol — recycled submission slabs, persistent reply
//!   mailboxes, `Arc`-shared output slabs, zero allocations per
//!   round-trip — and launches each flush at the smallest
//!   `batcher.batch_sizes` bucket that fits (padded-AOT shapes; see
//!   DESIGN.md §5).
//! * [`policy`] — split-phase inference clients (`submit`/`wait`): the
//!   seam between actors and inference. `actors.pipeline_depth` splits a
//!   thread's env slots into groups so env stepping overlaps in-flight
//!   inference (1 = the seed's serialized loop, bit-for-bit; see
//!   DESIGN.md §5).
//! * [`vecenv`] — vectorized environment engine: E wrapped environments
//!   stepped in lockstep behind one contiguous `[E, S, S, K]`
//!   observation buffer, decoupling environments-in-flight from CPU
//!   threads consumed (the CuLE-style lever on the paper's CPU/GPU
//!   ratio; see DESIGN.md §4). Two interchangeable engines sit behind
//!   it: the per-slot path (one `Wrapped` per slot — the default) and
//!   the batch-native struct-of-arrays engine ([`env::BatchEnv`],
//!   `env.batch_native = true`), whose single `step_all` advances all E
//!   slots over one contiguous grid slab with one vectorized
//!   frame-stack rotation — bit-for-bit equivalent trajectories,
//!   allocation-free in steady state (DESIGN.md §13).
//! * [`runtime`] — PJRT loading/execution of the AOT HLO artifacts.
//! * [`env`], [`replay`], [`rl`] — RL substrates (ALE-like suite, R2D2
//!   prioritized sequence replay striped over `replay.shards`
//!   per-mutex ring+sum-tree shards, epsilon/return utilities). The
//!   learner mirrors the actor pipeline: `learner.prefetch_depth`
//!   overlaps batch sample/assembly with the in-flight train step
//!   (1 = the seed's serialized loop, bit-for-bit; see DESIGN.md §7).
//!   The transition path is arena-backed and allocation-free in steady
//!   state: `rl::SequenceBuilder` writes borrowed rows straight into
//!   pooled time-major slabs (`rl::SequencePool`), and per-actor
//!   `replay::IngestQueue`s commit `replay.insert_batch` sequences per
//!   shard-grouped flush, with evicted and learner-released buffers
//!   recycling back to the pool (DESIGN.md §8).
//! * [`transport`] — the fleet data plane (DESIGN.md §14):
//!   length-prefixed slab frames over TCP / Unix-domain sockets,
//!   serialized straight from the pooled slab protocol's recycled
//!   buffers (allocation-free in steady state). `transport::RemoteClient`
//!   implements the split-phase [`policy`] trait over a socket — the
//!   unmodified actor loop runs in a worker process (`rlarch actor
//!   --connect`) — and `transport::FleetServer` multiplexes many remote
//!   actors into the batcher (`rlarch serve`) with per-connection
//!   backpressure (bounded in-flight rows, shed-and-retry), reconnect
//!   with backoff, and clean drain. `[fleet]` addresses empty (the
//!   default) = single-process mode, bit-for-bit the seed path.
//! * [`fault`] — deterministic fault injection (DESIGN.md §15): a
//!   seeded `FaultPlan` (`[faults]` config, all rates default 0.0 =
//!   bit-for-bit off) drives drop/delay/truncate/corrupt/kill on wire
//!   frames, stalled mock replies, and one-shot actor panics from
//!   per-`(seed, site, connection-epoch)` streams, with an injected-
//!   fault ledger the chaos soak reconciles against transport
//!   counters. The fault-*tolerance* half lives where the faults land:
//!   heartbeat/liveness/deadline state machines in [`transport`],
//!   restart-with-budget supervision and checkpoint/restore with a
//!   generation fence in [`coordinator`].
//! * [`serve`] — resilient policy serving (DESIGN.md §16): the
//!   batcher-as-a-service envelope around the fleet server. A
//!   line-delimited text control socket (`rlarch serve --control`,
//!   driven by `rlarch ctl`) exposes `health`/`ready`/`stats`,
//!   checkpoint hot-reload under traffic (drain in-flight tickets,
//!   swap the snapshot, bump the `Hello` generation so workers
//!   resync), and graceful shutdown (stop admitting → drain →
//!   checkpoint → goodbye). Per-connection priority classes (`actor`
//!   > `eval` > `bulk` in `Hello`), a sliding-window overload
//!   detector + bounded admission queue with deadline-aware shedding,
//!   and a consecutive-failure circuit breaker all reuse the
//!   transport's `shed:` reply flow. `[serve]` defaults off =
//!   bit-for-bit the PR 9 data plane.
//! * [`simarch`] — the architectural simulator (GPU/CPU/power models);
//!   its system model carries the same `envs_per_actor` and
//!   `pipeline_depth` axes, plus fleet network terms (`net_rtt_s`,
//!   bandwidth), a fault availability term (`fault_rate` ×
//!   `fault_recovery_s`), and a reload availability term
//!   (`reload_rate` × `reload_stall_s`) that default to the
//!   in-process, fault-free identity.
//! * [`telemetry`] — the observability layer (DESIGN.md §12): striped
//!   hot-path timers (in [`metrics`]), lock-free per-thread span rings
//!   rendered as Chrome trace JSON (`--trace-out`), and a background
//!   registry sampler emitting a JSONL time-series with derived gauges
//!   (live CPU/GPU-ratio proxy) plus an end-of-run Fig. 2-style phase
//!   attribution compared against the simarch model
//!   (`telemetry.model_drift`). Off by default; the disabled path is
//!   bit-for-bit and allocation-identical to an uninstrumented run.
//! * [`util`], [`exec`], [`config`], [`cli`], [`metrics`], [`report`] —
//!   dependency-free infrastructure (the offline crate set has no
//!   tokio/serde/clap/criterion).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod exec;
pub mod fault;
pub mod metrics;
pub mod policy;
pub mod replay;
pub mod report;
pub mod simarch;
pub mod rl;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod transport;
pub mod util;
pub mod vecenv;
