//! Per-actor ingest queue: the producer-side half of batched replay
//! inserts.
//!
//! Each actor thread owns one `IngestQueue`. Completed sequences buffer
//! locally (no lock touched) until `insert_batch` of them are pending,
//! then one [`SequenceReplay::add_batch`] flush commits them — grouped
//! by shard, each shard lock taken at most once — so the per-sequence
//! synchronization cost falls roughly as `1 / insert_batch` (measured
//! in `micro_replay`; the simarch actor cycle carries the same
//! amortization). `insert_batch = 1` flushes every push immediately
//! through the identical generation/slot path as [`SequenceReplay::add`]
//! — the seed behavior, bit-for-bit (asserted in
//! `tests/replay_equivalence.rs`).
//!
//! Buffered sequences are invisible to the learner until flushed, so
//! the queue trades up to `insert_batch - 1` sequences of replay
//! freshness per actor for lock amortization — the same freshness-for-
//! throughput trade the learner's prefetch pipeline makes (DESIGN.md
//! §8). The queue flushes any remainder on drop, and actors flush
//! explicitly at shutdown.

use super::SequenceSink;
use crate::rl::Sequence;
use std::sync::Arc;

pub struct IngestQueue {
    replay: Arc<dyn SequenceSink>,
    insert_batch: usize,
    buf: Vec<Sequence>,
    flushes: u64,
}

impl IngestQueue {
    /// `insert_batch` is clamped to >= 1 (1 = flush-per-sequence, the
    /// seed path). The sink is any [`SequenceSink`] — the in-process
    /// replay, or a transport client in a fleet worker.
    pub fn new(replay: Arc<dyn SequenceSink>, insert_batch: usize) -> Self {
        let insert_batch = insert_batch.max(1);
        Self {
            replay,
            insert_batch,
            buf: Vec::with_capacity(insert_batch),
            flushes: 0,
        }
    }

    /// Buffer one completed sequence, flushing when `insert_batch` are
    /// pending.
    pub fn push(&mut self, seq: Sequence) {
        self.buf.push(seq);
        if self.buf.len() >= self.insert_batch {
            self.flush();
        }
    }

    /// Commit everything pending in one `add_batch` (no-op when empty).
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.replay.add_batch(&mut self.buf);
        self.flushes += 1;
    }

    /// Sequences buffered but not yet visible to the learner.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Completed `add_batch` flushes so far.
    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    pub fn insert_batch(&self) -> usize {
        self.insert_batch
    }
}

impl Drop for IngestQueue {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayConfig, SequenceReplay};

    fn seq(tag: f32) -> Sequence {
        Sequence {
            obs: vec![tag; 8],
            actions: vec![0; 2],
            rewards: vec![tag; 2],
            discounts: vec![0.9; 2],
            h0: vec![0.0; 2],
            c0: vec![0.0; 2],
            actor_id: 0,
            valid_len: 2,
        }
    }

    #[test]
    fn flushes_at_insert_batch_and_preserves_order() {
        let r = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 16,
            shards: 4,
            ..Default::default()
        }));
        let mut q = IngestQueue::new(r.clone(), 4);
        for i in 0..3 {
            q.push(seq(i as f32));
            assert_eq!(q.pending(), i + 1);
            assert_eq!(r.len(), 0, "nothing visible before the flush");
        }
        q.push(seq(3.0));
        assert_eq!(q.pending(), 0);
        assert_eq!(q.flushes(), 1);
        assert_eq!(r.len(), 4);
        let tags: Vec<f32> = r.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(tags, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn one_flush_locks_each_shard_at_most_once() {
        let r = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            shards: 4,
            ..Default::default()
        }));
        let mut q = IngestQueue::new(r.clone(), 16);
        let before = r.lock_acquisitions();
        for i in 0..16 {
            q.push(seq(i as f32));
        }
        // 16 sequences over 4 shards: exactly 4 lock acquisitions, not
        // 16 (the seed's flush-per-sequence cost).
        assert_eq!(r.lock_acquisitions() - before, 4);
        assert_eq!(r.len(), 16);
    }

    #[test]
    fn insert_batch_one_flushes_every_push() {
        let r = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 8,
            ..Default::default()
        }));
        let mut q = IngestQueue::new(r.clone(), 1);
        for i in 0..5 {
            q.push(seq(i as f32));
            assert_eq!(q.pending(), 0);
        }
        assert_eq!(q.flushes(), 5);
        assert_eq!(r.len(), 5);
    }

    #[test]
    fn drop_flushes_the_remainder() {
        let r = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 8,
            ..Default::default()
        }));
        {
            let mut q = IngestQueue::new(r.clone(), 8);
            q.push(seq(1.0));
            q.push(seq(2.0));
            assert_eq!(r.len(), 0);
        }
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn zero_insert_batch_clamps_to_one() {
        let r = Arc::new(SequenceReplay::new(ReplayConfig::default()));
        let mut q = IngestQueue::new(r.clone(), 0);
        assert_eq!(q.insert_batch(), 1);
        q.push(seq(1.0));
        assert_eq!(r.len(), 1);
    }
}
