//! Prioritized sequence replay (R2D2 / Ape-X style): sum-tree sampling
//! over fixed-length recurrent sequences with learner-refreshed
//! priorities. This is the Reverb-equivalent substrate (the paper's
//! reference stack uses DeepMind Reverb [3]).

pub mod sequence;
pub mod sum_tree;

pub use sequence::{ReplayConfig, SampledBatch, SequenceReplay};
pub use sum_tree::SumTree;
