//! Prioritized sequence replay (R2D2 / Ape-X style): sum-tree sampling
//! over fixed-length recurrent sequences with learner-refreshed
//! priorities. This is the Reverb-equivalent substrate (the paper's
//! reference stack uses DeepMind Reverb [3]). Actor-side inserts go
//! through the per-actor [`IngestQueue`], which batches them into
//! one-lock-per-shard [`SequenceReplay::add_batch`] flushes
//! (`replay.insert_batch`; 1 = the seed's flush-per-sequence path,
//! bit-for-bit).

pub mod ingest;
pub mod sequence;
pub mod sum_tree;

pub use ingest::IngestQueue;
pub use sequence::{ReplayConfig, SampledBatch, SequenceReplay};
pub use sum_tree::SumTree;
