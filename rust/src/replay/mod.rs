//! Prioritized sequence replay (R2D2 / Ape-X style): sum-tree sampling
//! over fixed-length recurrent sequences with learner-refreshed
//! priorities. This is the Reverb-equivalent substrate (the paper's
//! reference stack uses DeepMind Reverb [3]). Actor-side inserts go
//! through the per-actor [`IngestQueue`], which batches them into
//! one-lock-per-shard [`SequenceReplay::add_batch`] flushes
//! (`replay.insert_batch`; 1 = the seed's flush-per-sequence path,
//! bit-for-bit).
//!
//! The ingest queue feeds a [`SequenceSink`] — the seam that lets the
//! same actor loop write into the in-process [`SequenceReplay`] or, in
//! a fleet worker, into a [`crate::transport::RemoteIngest`] that ships
//! sequences to the coordinator over a socket (DESIGN.md §14).

pub mod ingest;
pub mod sequence;
pub mod sum_tree;

pub use ingest::IngestQueue;
pub use sequence::{ReplayConfig, SampleScratch, SampledBatch, SequenceReplay};
pub use sum_tree::SumTree;

use crate::rl::{Sequence, SequencePool};
use std::sync::Arc;

/// Where completed sequences go: the in-process replay buffer, or a
/// transport client shipping them to a remote coordinator. Implementors
/// drain the batch (empty it, keep its capacity) so the producer-side
/// [`IngestQueue`] buffer stays allocation-free.
pub trait SequenceSink: Send + Sync {
    /// Consume a batch of completed sequences. The vec is drained.
    fn add_batch(&self, batch: &mut Vec<Sequence>);
    /// The recycling pool actors should draw builder slabs from, if the
    /// sink recycles (the replay's eviction pool, or a remote client's
    /// local send-side pool).
    fn recycle_pool(&self) -> Option<Arc<SequencePool>>;
}

impl SequenceSink for SequenceReplay {
    fn add_batch(&self, batch: &mut Vec<Sequence>) {
        SequenceReplay::add_batch(self, batch)
    }
    fn recycle_pool(&self) -> Option<Arc<SequencePool>> {
        self.pool().cloned()
    }
}
