//! R2D2 prioritized sequence replay buffer.
//!
//! Stores fixed-length sequences in a ring; samples with probability
//! proportional to priority^alpha through a sum tree; priorities are
//! refreshed from the learner's TD-error output after every train step.
//! New sequences enter at the current max priority (so nothing starves
//! before its first update) — the standard Ape-X/R2D2 scheme.

use super::sum_tree::SumTree;
use crate::rl::Sequence;
use crate::util::prng::Pcg32;
use std::sync::{Arc, Mutex};

pub struct ReplayConfig {
    pub capacity: usize,
    /// Priority exponent alpha (0 = uniform sampling).
    pub alpha: f64,
    /// Floor added to updated priorities so nothing becomes unsampleable.
    pub min_priority: f64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            capacity: 4_096,
            alpha: 0.9,
            min_priority: 1e-3,
        }
    }
}

struct Inner {
    slots: Vec<Option<Arc<Sequence>>>,
    tree: SumTree,
    write: usize,
    len: usize,
    inserts: u64,
    /// Raw (pre-alpha) max priority seen, for new-sequence initialization.
    max_raw_priority: f64,
}

/// Thread-safe prioritized sequence buffer (actors insert, learner
/// samples + updates). A single mutex is sufficient at our rates; see
/// EXPERIMENTS.md §Perf for the contention measurement.
pub struct SequenceReplay {
    cfg: ReplayConfig,
    inner: Mutex<Inner>,
}

/// A sampled batch: shared sequence handles + slot ids for the priority
/// refresh. `Arc` keeps sampling allocation-free on the sequence payload
/// (a clone of a 32 KiB obs sequence per row dominated the sample path;
/// see EXPERIMENTS.md §Perf).
pub struct SampledBatch {
    pub sequences: Vec<Arc<Sequence>>,
    pub slots: Vec<usize>,
}

impl SequenceReplay {
    pub fn new(cfg: ReplayConfig) -> Self {
        let capacity = cfg.capacity;
        Self {
            cfg,
            inner: Mutex::new(Inner {
                slots: (0..capacity).map(|_| None).collect(),
                tree: SumTree::new(capacity),
                write: 0,
                len: 0,
                inserts: 0,
                max_raw_priority: 1.0,
            }),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn inserts(&self) -> u64 {
        self.inner.lock().unwrap().inserts
    }

    /// Insert at max priority; overwrites the oldest slot when full.
    pub fn add(&self, seq: Sequence) {
        let mut g = self.inner.lock().unwrap();
        let idx = g.write;
        let raw = g.max_raw_priority;
        let prio = self.shaped(raw);
        g.slots[idx] = Some(Arc::new(seq));
        g.tree.set(idx, prio);
        g.write = (g.write + 1) % self.cfg.capacity;
        g.len = (g.len + 1).min(self.cfg.capacity);
        g.inserts += 1;
    }

    /// Sample `batch` sequences (with replacement across the priority
    /// distribution; stratified over equal mass segments, the standard
    /// PER scheme). Returns None until the buffer holds >= batch items.
    pub fn sample(&self, batch: usize, rng: &mut Pcg32) -> Option<SampledBatch> {
        let g = self.inner.lock().unwrap();
        if g.len < batch || g.tree.total() <= 0.0 {
            return None;
        }
        let total = g.tree.total();
        let seg = total / batch as f64;
        let mut sequences = Vec::with_capacity(batch);
        let mut slots = Vec::with_capacity(batch);
        for i in 0..batch {
            let u = (i as f64 + rng.next_f64()) * seg;
            let slot = g.tree.sample(u);
            match &g.slots[slot] {
                Some(seq) => {
                    sequences.push(seq.clone());
                    slots.push(slot);
                }
                None => {
                    // Tree/slot mismatch is a bug: priorities for empty
                    // slots must be zero.
                    unreachable!("sampled an empty slot {slot}");
                }
            }
        }
        Some(SampledBatch { sequences, slots })
    }

    /// Refresh priorities (raw TD-error magnitudes) after a train step.
    /// Slots overwritten since sampling are skipped (stale update).
    pub fn update_priorities(&self, slots: &[usize], raw_priorities: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        for (&slot, &p) in slots.iter().zip(raw_priorities) {
            if g.slots[slot].is_none() {
                continue;
            }
            let raw = (p as f64).max(self.cfg.min_priority);
            g.max_raw_priority = g.max_raw_priority.max(raw);
            let shaped = self.shaped(raw);
            g.tree.set(slot, shaped);
        }
    }

    /// Mean raw insert-time priority currently in the tree (diagnostic).
    pub fn total_priority(&self) -> f64 {
        self.inner.lock().unwrap().tree.total()
    }

    /// Snapshot of the buffered sequences in insertion order (oldest
    /// first). Diagnostic/test API: the actor-equivalence tests compare
    /// whole replay contents across loop implementations.
    pub fn snapshot(&self) -> Vec<Arc<Sequence>> {
        let g = self.inner.lock().unwrap();
        let cap = self.cfg.capacity;
        // Oldest entry: the write cursor when the ring has wrapped,
        // slot 0 otherwise.
        let start = if g.len == cap { g.write } else { 0 };
        (0..g.len)
            .filter_map(|i| g.slots[(start + i) % cap].clone())
            .collect()
    }

    fn shaped(&self, raw: f64) -> f64 {
        raw.max(self.cfg.min_priority).powf(self.cfg.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(tag: f32) -> Sequence {
        Sequence {
            obs: vec![tag; 8],
            actions: vec![0; 2],
            rewards: vec![tag; 2],
            discounts: vec![0.9; 2],
            h0: vec![0.0; 2],
            c0: vec![0.0; 2],
            actor_id: 0,
            valid_len: 2,
        }
    }

    #[test]
    fn sample_requires_min_fill() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 8,
            ..Default::default()
        });
        let mut rng = Pcg32::seeded(0);
        assert!(r.sample(4, &mut rng).is_none());
        for i in 0..4 {
            r.add(seq(i as f32));
        }
        let b = r.sample(4, &mut rng).unwrap();
        assert_eq!(b.sequences.len(), 4);
        assert_eq!(b.slots.len(), 4);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            ..Default::default()
        });
        for i in 0..6 {
            r.add(seq(i as f32));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.inserts(), 6);
        let mut rng = Pcg32::seeded(1);
        let b = r.sample(4, &mut rng).unwrap();
        // Tags 0 and 1 must be gone.
        for s in &b.sequences {
            assert!(s.rewards[0] >= 2.0);
        }
    }

    #[test]
    fn snapshot_returns_insertion_order() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            ..Default::default()
        });
        for i in 0..3 {
            r.add(seq(i as f32));
        }
        let tags: Vec<f32> = r.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(tags, vec![0.0, 1.0, 2.0]);
        // Wrap: 6 inserts into capacity 4 keeps the newest 4, oldest first.
        for i in 3..6 {
            r.add(seq(i as f32));
        }
        let tags: Vec<f32> = r.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(tags, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn priority_update_shifts_sampling() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 8,
            alpha: 1.0,
            min_priority: 1e-3,
        });
        for i in 0..8 {
            r.add(seq(i as f32));
        }
        // Depress every slot except slot 5.
        let slots: Vec<usize> = (0..8).collect();
        let mut prios = vec![1e-3f32; 8];
        prios[5] = 100.0;
        r.update_priorities(&slots, &prios);
        let mut rng = Pcg32::seeded(2);
        let mut hits5 = 0;
        let n = 200;
        for _ in 0..n {
            let b = r.sample(1, &mut rng).unwrap();
            if b.slots[0] == 5 {
                hits5 += 1;
            }
        }
        assert!(hits5 > n * 9 / 10, "slot 5 sampled {hits5}/{n}");
    }

    #[test]
    fn alpha_zero_is_uniform() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            alpha: 0.0,
            min_priority: 1e-3,
        });
        for i in 0..4 {
            r.add(seq(i as f32));
        }
        r.update_priorities(&[0, 1, 2, 3], &[100.0, 1.0, 1.0, 1.0]);
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0u32; 4];
        for _ in 0..8_000 {
            counts[r.sample(1, &mut rng).unwrap().slots[0]] += 1;
        }
        for c in counts {
            assert!((1_500..2_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn concurrent_add_and_sample() {
        let r = std::sync::Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 128,
            ..Default::default()
        }));
        std::thread::scope(|s| {
            for t in 0..4 {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..200 {
                        r.add(seq((t * 1000 + i) as f32));
                    }
                });
            }
            let r2 = r.clone();
            s.spawn(move || {
                let mut rng = Pcg32::seeded(4);
                let mut sampled = 0;
                while sampled < 50 {
                    if let Some(b) = r2.sample(8, &mut rng) {
                        r2.update_priorities(&b.slots, &vec![0.5; 8]);
                        sampled += 1;
                    }
                }
            });
        });
        assert_eq!(r.inserts(), 800);
    }
}
