//! R2D2 prioritized sequence replay buffer, sharded.
//!
//! Stores fixed-length sequences in a ring; samples with probability
//! proportional to priority^alpha through a sum tree; priorities are
//! refreshed from the learner's TD-error output after every train step.
//! New sequences enter at the current max priority (so nothing starves
//! before its first update) — the standard Ape-X/R2D2 scheme.
//!
//! The ring is striped across `shards` independent ring+sum-tree shards,
//! each behind its own mutex: global slot `g` lives in shard `g % S` at
//! local index `g / S`, so consecutive actor inserts land on different
//! shards and writer threads stop serializing on one global lock (the
//! contention measurement is in EXPERIMENTS.md §Perf). Sampling is
//! stratified *across* shards — a batch's rows are allocated to shards
//! proportional to each shard's priority mass (largest-remainder
//! rounding), then stratified *within* each shard over equal-mass
//! segments, the standard PER scheme. With `shards = 1` both the insert
//! path and the sampling path reduce to the classic single-ring buffer
//! bit-for-bit: one `next_f64` per row against segments of the single
//! tree's total (asserted against a verbatim seed replica in
//! `tests/replay_equivalence.rs`).
//!
//! Every insert carries a monotonically increasing generation tag, and
//! sampled batches return the tags alongside the slot ids: a priority
//! update whose tag no longer matches the slot's occupant is dropped as
//! stale, so a slot overwritten between `sample` and `update_priorities`
//! can never have the old batch's TD-error applied to the new sequence.
//!
//! Inserts can be batched: [`SequenceReplay::add_batch`] reserves a
//! contiguous generation range with one cursor bump and groups the
//! batch by shard so each flush takes each shard lock **at most once**
//! (the per-actor [`super::IngestQueue`] is the producer-side buffer
//! that feeds it). A batch of one is exactly [`SequenceReplay::add`] —
//! same generation, same shard, same lock — which is what keeps
//! `insert_batch = 1` bit-for-bit with the seed path. When a
//! [`SequencePool`] is attached (`with_pool`), every eviction — a ring
//! overwrite dropping its old occupant — releases the evicted
//! sequence's buffers back to the pool, closing the
//! pool → builder → ingest → replay → pool recycling loop (DESIGN.md
//! §8).

use super::sum_tree::SumTree;
use crate::rl::{Sequence, SequencePool};
use crate::util::prng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

pub struct ReplayConfig {
    pub capacity: usize,
    /// Priority exponent alpha (0 = uniform sampling).
    pub alpha: f64,
    /// Floor added to updated priorities so nothing becomes unsampleable.
    pub min_priority: f64,
    /// Independent ring+sum-tree shards the capacity is striped across
    /// (must divide `capacity`). 1 = the classic single-mutex buffer.
    pub shards: usize,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            capacity: 4_096,
            alpha: 0.9,
            min_priority: 1e-3,
            shards: 1,
        }
    }
}

/// The `[replay]` config table maps 1:1 onto the buffer's own knobs.
impl From<&crate::config::ReplayBufferConfig> for ReplayConfig {
    fn from(c: &crate::config::ReplayBufferConfig) -> Self {
        Self {
            capacity: c.capacity,
            alpha: c.alpha,
            min_priority: c.min_priority,
            shards: c.shards,
        }
    }
}

/// One occupied ring slot: the stored sequence plus the insert
/// generation that guards priority updates against overwrites.
struct SlotEntry {
    seq: Arc<Sequence>,
    generation: u64,
}

struct Shard {
    slots: Vec<Option<SlotEntry>>,
    tree: SumTree,
    len: usize,
    /// Raw (pre-alpha) max priority seen by this shard, for
    /// new-sequence initialization (per-shard, like the per-ring value
    /// it generalizes; shards exchange no priority state).
    max_raw_priority: f64,
}

/// Thread-safe prioritized sequence buffer (actors insert, learner
/// samples + updates), striped over per-shard mutexes; see the module
/// docs and EXPERIMENTS.md §Perf for the contention measurement.
pub struct SequenceReplay {
    cfg: ReplayConfig,
    shards: Vec<Mutex<Shard>>,
    /// Global insert cursor; also the generation tag of the next insert.
    cursor: AtomicU64,
    /// Lock acquisitions that found a shard mutex already held.
    contention: AtomicU64,
    /// Total shard-lock acquisitions (contended or not) — the batched
    /// ingest's amortization signal: `micro_replay` reports
    /// acquisitions-per-sequence across `insert_batch` settings.
    lock_ops: AtomicU64,
    /// Recycling pool evicted sequences are released into (none = the
    /// seed behavior: evictions just drop).
    pool: Option<Arc<SequencePool>>,
}

/// A sampled batch: shared sequence handles + global slot ids and insert
/// generations for the priority refresh. `Arc` keeps sampling
/// allocation-free on the sequence payload (a clone of a 32 KiB obs
/// sequence per row dominated the sample path; see EXPERIMENTS.md
/// §Perf). The learner's hot path uses [`SequenceReplay::sample_into`]
/// instead, which skips even the `Arc` refcount churn by visiting rows
/// as borrows under the shard lock.
pub struct SampledBatch {
    pub sequences: Vec<Arc<Sequence>>,
    pub slots: Vec<usize>,
    /// Insert generation of each sampled slot; pass back to
    /// [`SequenceReplay::update_priorities`] so updates racing an
    /// overwrite are dropped instead of retagging the new occupant.
    pub generations: Vec<u64>,
}

/// Reusable sampling workspace: the per-shard mass/quota/remainder
/// buffers [`SequenceReplay::sample_into`] would otherwise allocate per
/// call. One per sampling thread; contents are scratch, valid only
/// within a call.
#[derive(Default)]
pub struct SampleScratch {
    masses: Vec<f64>,
    quotas: Vec<usize>,
    remainders: Vec<(f64, usize)>,
}

impl SampleScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

impl SequenceReplay {
    pub fn new(cfg: ReplayConfig) -> Self {
        assert!(cfg.capacity > 0, "replay capacity must be > 0");
        assert!(cfg.shards >= 1, "replay shards must be >= 1");
        assert!(
            cfg.capacity / cfg.shards * cfg.shards == cfg.capacity,
            "replay shards ({}) must divide capacity ({})",
            cfg.shards,
            cfg.capacity
        );
        let per_shard = cfg.capacity / cfg.shards;
        let shards = (0..cfg.shards)
            .map(|_| {
                Mutex::new(Shard {
                    slots: (0..per_shard).map(|_| None).collect(),
                    tree: SumTree::new(per_shard),
                    len: 0,
                    max_raw_priority: 1.0,
                })
            })
            .collect();
        Self {
            cfg,
            shards,
            cursor: AtomicU64::new(0),
            contention: AtomicU64::new(0),
            lock_ops: AtomicU64::new(0),
            pool: None,
        }
    }

    /// Attach a recycling pool: sequences evicted by ring overwrites are
    /// released into it (buffer recycles once the last `Arc` holder lets
    /// go). Builder-style, called before the replay is shared.
    pub fn with_pool(mut self, pool: Arc<SequencePool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// The attached recycling pool, if any (actors draw builder slabs
    /// from it; the learner releases sampled batches back into it).
    pub fn pool(&self) -> Option<&Arc<SequencePool>> {
        self.pool.as_ref()
    }

    pub fn len(&self) -> usize {
        (0..self.shards.len()).map(|s| self.lock_shard(s).len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total insert *attempts* (the generation cursor). A wrap-racing
    /// add that loses its slot to a newer generation still counts —
    /// unlike the seed's committed-write counter — so this can exceed
    /// the number of sequences ever stored by the (vanishingly rare)
    /// number of same-slot races.
    pub fn inserts(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Number of shards the capacity is striped across.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Lock acquisitions so far that found a shard mutex already held —
    /// the contention signal behind the `replay.shard_contention`
    /// metric.
    pub fn shard_contention(&self) -> u64 {
        self.contention.load(Ordering::Relaxed)
    }

    /// Total shard-lock acquisitions so far (contended or not): the
    /// denominator check for batched ingest — one flush of `k`
    /// sequences over `S` shards costs at most `min(k, S)` acquisitions
    /// instead of `k`.
    pub fn lock_acquisitions(&self) -> u64 {
        self.lock_ops.load(Ordering::Relaxed)
    }

    /// Lock shard `s`, counting the acquisition as contended when the
    /// mutex was already held.
    fn lock_shard(&self, s: usize) -> MutexGuard<'_, Shard> {
        self.lock_ops.fetch_add(1, Ordering::Relaxed);
        if let Ok(g) = self.shards[s].try_lock() {
            return g;
        }
        self.contention.fetch_add(1, Ordering::Relaxed);
        self.shards[s].lock().unwrap()
    }

    /// Write `seq` into an already-locked shard's `local` slot under
    /// `generation` — the shared slot-commit path of [`Self::add`] and
    /// [`Self::add_batch`]. Evicted occupants are released to the
    /// attached pool (if any).
    fn insert_at(&self, g: &mut Shard, local: usize, seq: Sequence, generation: u64) {
        if let Some(e) = &g.slots[local] {
            // A wrap-racing older insert must not clobber a newer one.
            if e.generation > generation {
                if let Some(p) = &self.pool {
                    p.put(seq);
                }
                return;
            }
        } else {
            g.len += 1;
        }
        let prio = self.shaped(g.max_raw_priority);
        let evicted = std::mem::replace(
            &mut g.slots[local],
            Some(SlotEntry {
                seq: Arc::new(seq),
                generation,
            }),
        );
        if let (Some(p), Some(e)) = (&self.pool, evicted) {
            p.release(e.seq);
        }
        g.tree.set(local, prio);
    }

    /// Insert at max priority; overwrites the oldest slot when full.
    /// Striped: consecutive inserts land on consecutive shards.
    pub fn add(&self, seq: Sequence) {
        let generation = self.cursor.fetch_add(1, Ordering::Relaxed);
        let global = (generation % self.cfg.capacity as u64) as usize;
        let n = self.shards.len();
        let (shard, local) = (global % n, global / n);
        let mut g = self.lock_shard(shard);
        self.insert_at(&mut g, local, seq, generation);
    }

    /// Insert a batch of sequences, reserving their contiguous
    /// generation range with one cursor bump and taking each shard's
    /// lock **at most once** — the amortization the per-actor
    /// [`super::IngestQueue`] buys. Within each shard, slots commit in
    /// generation order; across the whole batch the generation/slot
    /// assignment is exactly what `len(batch)` consecutive [`Self::add`]
    /// calls would produce, so a batch of one *is* `add`, bit-for-bit.
    /// The vec is drained (emptied, capacity kept) so callers can reuse
    /// its storage allocation-free.
    pub fn add_batch(&self, batch: &mut Vec<Sequence>) {
        let k = batch.len() as u64;
        if k == 0 {
            return;
        }
        debug_assert!(
            k as usize <= self.cfg.capacity,
            "insert batch ({k}) larger than replay capacity ({})",
            self.cfg.capacity
        );
        let base = self.cursor.fetch_add(k, Ordering::Relaxed);
        let n = self.shards.len() as u64;
        let cap = self.cfg.capacity as u64;
        for s in 0..n {
            // Shards divide the capacity, so item i's shard is
            // (base + i) % n independent of ring wrap; the batch lands
            // on shards cyclically starting from base's.
            let first = (s + n - base % n) % n;
            if first >= k {
                continue;
            }
            let mut g = self.lock_shard(s as usize);
            let mut i = first;
            while i < k {
                let generation = base + i;
                let local = ((generation % cap) / n) as usize;
                let seq = std::mem::take(&mut batch[i as usize]);
                self.insert_at(&mut g, local, seq, generation);
                i += n;
            }
        }
        batch.clear();
    }

    /// Sample `batch` sequences (with replacement across the priority
    /// distribution). Rows are allocated to shards proportional to each
    /// shard's priority mass, then stratified over equal mass segments
    /// within the shard, the standard PER scheme; at `shards = 1` this
    /// is exactly classic stratified sampling over one tree, consuming
    /// one `next_f64` per row. Returns None until the buffer holds
    /// >= batch items.
    pub fn sample(&self, batch: usize, rng: &mut Pcg32) -> Option<SampledBatch> {
        let mut scratch = SampleScratch::new();
        let mut sequences = Vec::with_capacity(batch);
        let mut slots = Vec::with_capacity(batch);
        let mut generations = Vec::with_capacity(batch);
        let ok = self.sample_with(batch, rng, &mut scratch, |_, slot, generation, e| {
            sequences.push(e.seq.clone());
            slots.push(slot);
            generations.push(generation);
        });
        if !ok {
            return None;
        }
        Some(SampledBatch {
            sequences,
            slots,
            generations,
        })
    }

    /// The zero-`Arc`-churn sample path: identical RNG stream, slot
    /// choices, and generation tags as [`Self::sample`], but each drawn
    /// sequence is handed to `visit(row, &seq)` as a **borrow pinned
    /// under its shard lock** — no refcount traffic, no handle vec, and
    /// (with a reused `scratch`/`slots`/`generations`) no allocation at
    /// steady state. The generation tags still land in `generations`
    /// for the post-train priority refresh, so the stale-update guard
    /// is unchanged. `visit` runs inside a shard critical section: copy
    /// the rows out (the learner's batch assembly) and return — calling
    /// back into the replay from `visit` deadlocks.
    ///
    /// Returns false (without touching `visit`) until the buffer holds
    /// >= `batch` items. `slots`/`generations` are cleared and refilled.
    pub fn sample_into(
        &self,
        batch: usize,
        rng: &mut Pcg32,
        scratch: &mut SampleScratch,
        slots: &mut Vec<usize>,
        generations: &mut Vec<u64>,
        mut visit: impl FnMut(usize, &Sequence),
    ) -> bool {
        slots.clear();
        generations.clear();
        self.sample_with(batch, rng, scratch, |row, slot, generation, e| {
            slots.push(slot);
            generations.push(generation);
            visit(row, &e.seq);
        })
    }

    /// Shared stratified-sampling core of [`Self::sample`] and
    /// [`Self::sample_into`]: `row(i, global_slot, generation, entry)`
    /// fires once per drawn row, in draw order, under the owning
    /// shard's lock. Consumes exactly one `next_f64` per row.
    fn sample_with(
        &self,
        batch: usize,
        rng: &mut Pcg32,
        scratch: &mut SampleScratch,
        mut row: impl FnMut(usize, usize, u64, &SlotEntry),
    ) -> bool {
        let n = self.shards.len();
        // Pass 1: shard priority masses (short per-shard critical
        // sections; entries are never removed, so a mass observed > 0
        // stays > 0 for pass 2).
        let mut len = 0usize;
        scratch.masses.clear();
        for s in 0..n {
            let g = self.lock_shard(s);
            len += g.len;
            scratch.masses.push(g.tree.total());
        }
        let total: f64 = scratch.masses.iter().sum();
        if len < batch || total <= 0.0 {
            return false;
        }
        allocate_rows_into(
            batch,
            &scratch.masses,
            &mut scratch.quotas,
            &mut scratch.remainders,
        );
        let mut r = 0usize;
        // Pass 2: stratified sampling within each shard that drew rows.
        for (s, &k) in scratch.quotas.iter().enumerate() {
            if k == 0 {
                continue;
            }
            let g = self.lock_shard(s);
            let seg = g.tree.total() / k as f64;
            for i in 0..k {
                let u = (i as f64 + rng.next_f64()) * seg;
                let local = g.tree.sample(u);
                match &g.slots[local] {
                    Some(e) => {
                        row(r, local * n + s, e.generation, e);
                        r += 1;
                    }
                    None => {
                        // Tree/slot mismatch is a bug: priorities for
                        // empty slots must be zero.
                        unreachable!("sampled an empty slot {local} in shard {s}");
                    }
                }
            }
        }
        true
    }

    /// Refresh priorities (raw TD-error magnitudes) after a train step.
    /// `generations` are the insert tags returned by [`Self::sample`]:
    /// an update whose tag no longer matches the slot's occupant (the
    /// slot was overwritten since sampling) is dropped as stale instead
    /// of applying the old batch's TD-error to the new sequence.
    pub fn update_priorities(
        &self,
        slots: &[usize],
        generations: &[u64],
        raw_priorities: &[f32],
    ) {
        debug_assert_eq!(slots.len(), generations.len());
        let n = self.shards.len();
        for s in 0..n {
            if !slots.iter().any(|&slot| slot % n == s) {
                continue;
            }
            let mut g = self.lock_shard(s);
            for ((&slot, &generation), &p) in
                slots.iter().zip(generations).zip(raw_priorities)
            {
                if slot % n != s {
                    continue;
                }
                let local = slot / n;
                // Empty, or overwritten since sampling: stale, drop.
                let fresh = matches!(
                    &g.slots[local],
                    Some(e) if e.generation == generation
                );
                if !fresh {
                    continue;
                }
                let raw = (p as f64).max(self.cfg.min_priority);
                g.max_raw_priority = g.max_raw_priority.max(raw);
                let shaped = self.shaped(raw);
                g.tree.set(local, shaped);
            }
        }
    }

    /// Total priority mass currently in the trees (diagnostic).
    pub fn total_priority(&self) -> f64 {
        (0..self.shards.len())
            .map(|s| self.lock_shard(s).tree.total())
            .sum()
    }

    /// Current (shaped) priority of one global slot (diagnostic/test
    /// API; the stale-update regression tests watch individual slots).
    pub fn priority_of(&self, slot: usize) -> f64 {
        let n = self.shards.len();
        self.lock_shard(slot % n).tree.get(slot / n)
    }

    /// Snapshot of the buffered sequences in insertion order (oldest
    /// first). Diagnostic/test API: the actor-equivalence tests compare
    /// whole replay contents across loop implementations.
    pub fn snapshot(&self) -> Vec<Arc<Sequence>> {
        let n = self.shards.len();
        let guards: Vec<MutexGuard<'_, Shard>> =
            (0..n).map(|s| self.lock_shard(s)).collect();
        let cap = self.cfg.capacity;
        let count: usize = guards.iter().map(|g| g.len).sum();
        // Oldest entry once the ring has wrapped: one past the newest
        // *committed* generation — the atomic cursor can run ahead of
        // an in-flight add that has reserved a generation but not yet
        // written its slot, and deriving the start from it would rotate
        // the order. Global slot 0 otherwise.
        let start = if count == cap {
            let newest = guards
                .iter()
                .flat_map(|g| g.slots.iter().flatten().map(|e| e.generation))
                .max()
                .unwrap_or(0);
            ((newest + 1) % cap as u64) as usize
        } else {
            0
        };
        (0..count)
            .filter_map(|i| {
                let g = (start + i) % cap;
                guards[g % n].slots[g / n].as_ref().map(|e| e.seq.clone())
            })
            .collect()
    }

    fn shaped(&self, raw: f64) -> f64 {
        raw.max(self.cfg.min_priority).powf(self.cfg.alpha)
    }
}

/// Largest-remainder allocation of `batch` rows proportional to shard
/// priority masses. Deterministic (no RNG): exact quotas are floored,
/// then leftover rows go to the largest fractional remainders (ties to
/// the lower shard index). Zero-mass shards never receive rows.
#[cfg(test)]
fn allocate_rows(batch: usize, masses: &[f64]) -> Vec<usize> {
    let mut quotas = Vec::new();
    let mut remainders = Vec::new();
    allocate_rows_into(batch, masses, &mut quotas, &mut remainders);
    quotas
}

/// Allocation-free body of `allocate_rows`: writes quotas into reused
/// scratch vecs (cleared first) so the steady-state sample path never
/// allocates.
fn allocate_rows_into(
    batch: usize,
    masses: &[f64],
    quotas: &mut Vec<usize>,
    remainders: &mut Vec<(f64, usize)>,
) {
    let total: f64 = masses.iter().sum();
    quotas.clear();
    remainders.clear();
    let mut assigned = 0usize;
    for (i, &m) in masses.iter().enumerate() {
        let exact = batch as f64 * m / total;
        let q = exact.floor() as usize;
        quotas.push(q);
        assigned += q;
        if m > 0.0 {
            remainders.push((exact - q as f64, i));
        }
    }
    remainders.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
    });
    for &(_, i) in remainders.iter() {
        if assigned == batch {
            break;
        }
        quotas[i] += 1;
        assigned += 1;
    }
    // Float-sum slack can leave a row unplaced; park leftovers on
    // positive-mass shards round-robin.
    let mut i = 0usize;
    while assigned < batch {
        if masses[i % masses.len()] > 0.0 {
            quotas[i % masses.len()] += 1;
            assigned += 1;
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(tag: f32) -> Sequence {
        Sequence {
            obs: vec![tag; 8],
            actions: vec![0; 2],
            rewards: vec![tag; 2],
            discounts: vec![0.9; 2],
            h0: vec![0.0; 2],
            c0: vec![0.0; 2],
            actor_id: 0,
            valid_len: 2,
        }
    }

    #[test]
    fn sample_requires_min_fill() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 8,
            ..Default::default()
        });
        let mut rng = Pcg32::seeded(0);
        assert!(r.sample(4, &mut rng).is_none());
        for i in 0..4 {
            r.add(seq(i as f32));
        }
        let b = r.sample(4, &mut rng).unwrap();
        assert_eq!(b.sequences.len(), 4);
        assert_eq!(b.slots.len(), 4);
        assert_eq!(b.generations.len(), 4);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            ..Default::default()
        });
        for i in 0..6 {
            r.add(seq(i as f32));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.inserts(), 6);
        let mut rng = Pcg32::seeded(1);
        let b = r.sample(4, &mut rng).unwrap();
        // Tags 0 and 1 must be gone.
        for s in &b.sequences {
            assert!(s.rewards[0] >= 2.0);
        }
    }

    #[test]
    fn snapshot_returns_insertion_order() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            ..Default::default()
        });
        for i in 0..3 {
            r.add(seq(i as f32));
        }
        let tags: Vec<f32> = r.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(tags, vec![0.0, 1.0, 2.0]);
        // Wrap: 6 inserts into capacity 4 keeps the newest 4, oldest first.
        for i in 3..6 {
            r.add(seq(i as f32));
        }
        let tags: Vec<f32> = r.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(tags, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn sharded_snapshot_keeps_global_insertion_order() {
        for shards in [2usize, 4] {
            let r = SequenceReplay::new(ReplayConfig {
                capacity: 8,
                shards,
                ..Default::default()
            });
            for i in 0..11 {
                r.add(seq(i as f32));
            }
            assert_eq!(r.len(), 8);
            let tags: Vec<f32> =
                r.snapshot().iter().map(|s| s.rewards[0]).collect();
            let expect: Vec<f32> = (3..11).map(|i| i as f32).collect();
            assert_eq!(tags, expect, "shards={shards}");
        }
    }

    #[test]
    fn priority_update_shifts_sampling() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 8,
            alpha: 1.0,
            min_priority: 1e-3,
            shards: 1,
        });
        for i in 0..8 {
            r.add(seq(i as f32));
        }
        // Depress every slot except slot 5. First-pass inserts: the
        // generation of slot i is i.
        let slots: Vec<usize> = (0..8).collect();
        let generations: Vec<u64> = (0..8).collect();
        let mut prios = vec![1e-3f32; 8];
        prios[5] = 100.0;
        r.update_priorities(&slots, &generations, &prios);
        let mut rng = Pcg32::seeded(2);
        let mut hits5 = 0;
        let n = 200;
        for _ in 0..n {
            let b = r.sample(1, &mut rng).unwrap();
            if b.slots[0] == 5 {
                hits5 += 1;
            }
        }
        assert!(hits5 > n * 9 / 10, "slot 5 sampled {hits5}/{n}");
    }

    #[test]
    fn sharded_sampling_tracks_priority_mass() {
        // 2 shards, all mass on one slot of shard 1: stratified
        // allocation must send essentially every row there.
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 8,
            alpha: 1.0,
            min_priority: 1e-3,
            shards: 2,
        });
        for i in 0..8 {
            r.add(seq(i as f32));
        }
        let slots: Vec<usize> = (0..8).collect();
        let generations: Vec<u64> = (0..8).collect();
        let mut prios = vec![1e-3f32; 8];
        prios[5] = 100.0; // global slot 5 = shard 1, local 2
        r.update_priorities(&slots, &generations, &prios);
        let mut rng = Pcg32::seeded(7);
        let mut hits5 = 0;
        let n = 100;
        for _ in 0..n {
            let b = r.sample(4, &mut rng).unwrap();
            hits5 += b.slots.iter().filter(|&&s| s == 5).count();
        }
        assert!(hits5 > 4 * n * 8 / 10, "slot 5 drew {hits5}/{}", 4 * n);
    }

    #[test]
    fn stale_update_after_overwrite_is_dropped() {
        // Regression: a slot overwritten between sample and
        // update_priorities must NOT receive the old batch's TD-error.
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            alpha: 1.0,
            min_priority: 1e-3,
            shards: 1,
        });
        for i in 0..4 {
            r.add(seq(i as f32));
        }
        let mut rng = Pcg32::seeded(3);
        let b = r.sample(4, &mut rng).unwrap();
        // Force an overwrite of every sampled slot before the update
        // lands (one full ring wrap).
        for i in 4..8 {
            r.add(seq(i as f32));
        }
        let before: Vec<f64> =
            b.slots.iter().map(|&s| r.priority_of(s)).collect();
        r.update_priorities(&b.slots, &b.generations, &[100.0; 4]);
        for (i, &slot) in b.slots.iter().enumerate() {
            assert_eq!(
                r.priority_of(slot),
                before[i],
                "stale update leaked into overwritten slot {slot}"
            );
        }
        // A fresh sample's generations do match, and its update lands.
        let b2 = r.sample(4, &mut rng).unwrap();
        r.update_priorities(&b2.slots, &b2.generations, &[100.0; 4]);
        assert!(
            (r.priority_of(b2.slots[0]) - 100.0).abs() < 1e-9,
            "fresh update must apply"
        );
    }

    #[test]
    fn alpha_zero_is_uniform() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            alpha: 0.0,
            min_priority: 1e-3,
            shards: 1,
        });
        for i in 0..4 {
            r.add(seq(i as f32));
        }
        r.update_priorities(&[0, 1, 2, 3], &[0, 1, 2, 3], &[100.0, 1.0, 1.0, 1.0]);
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0u32; 4];
        for _ in 0..8_000 {
            counts[r.sample(1, &mut rng).unwrap().slots[0]] += 1;
        }
        for c in counts {
            assert!((1_500..2_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn sample_into_matches_sample_exactly() {
        // The borrow path must consume the same RNG stream and return
        // the same slots/generations/row data as the Arc path — at 1
        // shard and sharded.
        for shards in [1usize, 4] {
            let mk = || {
                let r = SequenceReplay::new(ReplayConfig {
                    capacity: 16,
                    shards,
                    ..Default::default()
                });
                for i in 0..12 {
                    r.add(seq(i as f32));
                }
                r
            };
            let (a, b) = (mk(), mk());
            let mut rng_a = Pcg32::seeded(11);
            let mut rng_b = Pcg32::seeded(11);
            let mut scratch = SampleScratch::new();
            let mut slots = Vec::new();
            let mut generations = Vec::new();
            for round in 0..5 {
                let got = a.sample(6, &mut rng_a).unwrap();
                let mut rows: Vec<f32> = Vec::new();
                let ok = b.sample_into(
                    6,
                    &mut rng_b,
                    &mut scratch,
                    &mut slots,
                    &mut generations,
                    |row, s| {
                        assert_eq!(row, rows.len(), "rows visit in draw order");
                        rows.push(s.rewards[0]);
                    },
                );
                assert!(ok, "round {round}");
                assert_eq!(slots, got.slots, "shards={shards} round={round}");
                assert_eq!(generations, got.generations);
                let want: Vec<f32> =
                    got.sequences.iter().map(|s| s.rewards[0]).collect();
                assert_eq!(rows, want);
            }
        }
    }

    #[test]
    fn sample_into_underfilled_returns_false_without_visiting() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 8,
            ..Default::default()
        });
        r.add(seq(0.0));
        let mut rng = Pcg32::seeded(0);
        let mut scratch = SampleScratch::new();
        let (mut slots, mut generations) = (vec![9], vec![9u64]);
        let ok = r.sample_into(4, &mut rng, &mut scratch, &mut slots, &mut generations, |_, _| {
            panic!("visit must not fire on an underfilled buffer");
        });
        assert!(!ok);
        assert!(slots.is_empty() && generations.is_empty());
    }

    #[test]
    fn allocate_rows_is_proportional_and_exact() {
        assert_eq!(allocate_rows(8, &[1.0]), vec![8]);
        assert_eq!(allocate_rows(8, &[1.0, 1.0]), vec![4, 4]);
        assert_eq!(allocate_rows(8, &[3.0, 1.0]), vec![6, 2]);
        // Zero-mass shards draw nothing; totals always sum to batch.
        assert_eq!(allocate_rows(5, &[0.0, 1.0, 0.0]), vec![0, 5, 0]);
        let q = allocate_rows(7, &[1.0, 1.0, 1.0]);
        assert_eq!(q.iter().sum::<usize>(), 7);
        assert!(q.iter().all(|&k| (2..=3).contains(&k)), "{q:?}");
    }

    #[test]
    fn add_batch_matches_sequential_adds() {
        // Any chunking of the insert stream through add_batch must land
        // every sequence in the same slot with the same generation as
        // one-at-a-time add() — including across ring wraps.
        for shards in [1usize, 2, 4] {
            for chunk in [1usize, 3, 4, 7] {
                let golden = SequenceReplay::new(ReplayConfig {
                    capacity: 8,
                    shards,
                    ..Default::default()
                });
                let batched = SequenceReplay::new(ReplayConfig {
                    capacity: 8,
                    shards,
                    ..Default::default()
                });
                let mut pending: Vec<Sequence> = Vec::new();
                for i in 0..19 {
                    golden.add(seq(i as f32));
                    pending.push(seq(i as f32));
                    if pending.len() == chunk {
                        batched.add_batch(&mut pending);
                    }
                }
                batched.add_batch(&mut pending);
                assert!(pending.is_empty());
                assert_eq!(golden.len(), batched.len());
                assert_eq!(golden.inserts(), batched.inserts());
                let a: Vec<f32> =
                    golden.snapshot().iter().map(|s| s.rewards[0]).collect();
                let b: Vec<f32> =
                    batched.snapshot().iter().map(|s| s.rewards[0]).collect();
                assert_eq!(a, b, "shards={shards} chunk={chunk}");
                // Identical buffer state: identical sample streams.
                let mut r1 = Pcg32::seeded(5);
                let mut r2 = Pcg32::seeded(5);
                let s1 = golden.sample(4, &mut r1).unwrap();
                let s2 = batched.sample(4, &mut r2).unwrap();
                assert_eq!(s1.slots, s2.slots);
                assert_eq!(s1.generations, s2.generations);
            }
        }
    }

    #[test]
    fn add_batch_takes_each_shard_lock_at_most_once() {
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 32,
            shards: 4,
            ..Default::default()
        });
        let mut batch: Vec<Sequence> = (0..8).map(|i| seq(i as f32)).collect();
        let before = r.lock_acquisitions();
        r.add_batch(&mut batch);
        assert_eq!(r.lock_acquisitions() - before, 4);
        // A batch smaller than the shard count touches only its shards.
        let mut batch: Vec<Sequence> = (0..2).map(|i| seq(i as f32)).collect();
        let before = r.lock_acquisitions();
        r.add_batch(&mut batch);
        assert_eq!(r.lock_acquisitions() - before, 2);
    }

    #[test]
    fn eviction_releases_buffers_to_the_pool() {
        use crate::rl::SequencePool;
        let pool = Arc::new(SequencePool::with_capacity(16));
        let r = SequenceReplay::new(ReplayConfig {
            capacity: 4,
            shards: 2,
            ..Default::default()
        })
        .with_pool(pool.clone());
        assert!(r.pool().is_some());
        for i in 0..4 {
            r.add(seq(i as f32));
        }
        assert_eq!(pool.free_len(), 0, "no evictions yet");
        // One full wrap: 4 evictions, each buffer unshared -> recycled.
        for i in 4..8 {
            r.add(seq(i as f32));
        }
        assert_eq!(pool.free_len(), 4);
        // A sampled handle keeps its buffer alive past eviction; the
        // learner-side release recycles it once replay has let go.
        let mut rng = Pcg32::seeded(9);
        let held = r.sample(1, &mut rng).unwrap();
        let arc = held.sequences[0].clone();
        drop(held);
        let evictions_before = pool.free_len();
        for i in 8..12 {
            r.add(seq(i as f32));
        }
        // 4 evictions, but the held slot's buffer could not recycle yet.
        assert_eq!(pool.free_len(), evictions_before + 3);
        pool.release(arc);
        assert_eq!(pool.free_len(), evictions_before + 4);
    }

    #[test]
    fn concurrent_add_and_sample() {
        for shards in [1usize, 4] {
            let r = Arc::new(SequenceReplay::new(ReplayConfig {
                capacity: 128,
                shards,
                ..Default::default()
            }));
            std::thread::scope(|s| {
                for t in 0..4 {
                    let r = r.clone();
                    s.spawn(move || {
                        for i in 0..200 {
                            r.add(seq((t * 1000 + i) as f32));
                        }
                    });
                }
                let r2 = r.clone();
                s.spawn(move || {
                    let mut rng = Pcg32::seeded(4);
                    let mut sampled = 0;
                    while sampled < 50 {
                        if let Some(b) = r2.sample(8, &mut rng) {
                            r2.update_priorities(
                                &b.slots,
                                &b.generations,
                                &[0.5; 8],
                            );
                            sampled += 1;
                        }
                    }
                });
            });
            assert_eq!(r.inserts(), 800, "shards={shards}");
            assert_eq!(r.len(), 128, "shards={shards}");
        }
    }
}
