//! Sum tree (segment tree over priorities) — the prioritized-replay
//! sampling structure. O(log n) update and prefix-sum sampling.

#[derive(Clone, Debug)]
pub struct SumTree {
    /// Complete binary tree in an array; leaves are the last `cap` slots.
    tree: Vec<f64>,
    cap: usize,
}

impl SumTree {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        let cap = capacity.next_power_of_two();
        Self {
            tree: vec![0.0; 2 * cap],
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn total(&self) -> f64 {
        self.tree[1]
    }

    pub fn get(&self, idx: usize) -> f64 {
        assert!(idx < self.cap);
        self.tree[self.cap + idx]
    }

    /// Set leaf `idx` to `priority` (>= 0), updating ancestors.
    pub fn set(&mut self, idx: usize, priority: f64) {
        assert!(idx < self.cap, "index {idx} out of capacity {}", self.cap);
        assert!(priority >= 0.0 && priority.is_finite());
        let mut i = self.cap + idx;
        self.tree[i] = priority;
        i /= 2;
        while i >= 1 {
            self.tree[i] = self.tree[2 * i] + self.tree[2 * i + 1];
            if i == 1 {
                break;
            }
            i /= 2;
        }
    }

    /// Find the leaf whose cumulative range contains `prefix`
    /// (0 <= prefix < total). Returns the leaf index.
    pub fn sample(&self, mut prefix: f64) -> usize {
        debug_assert!(self.total() > 0.0, "sampling an empty tree");
        prefix = prefix.clamp(0.0, self.total() * (1.0 - 1e-12));
        let mut i = 1;
        while i < self.cap {
            let left = self.tree[2 * i];
            if prefix < left {
                i = 2 * i;
            } else {
                prefix -= left;
                i = 2 * i + 1;
            }
        }
        i - self.cap
    }

    /// Max leaf priority (for new-sample initialization).
    pub fn max_priority(&self) -> f64 {
        self.tree[self.cap..]
            .iter()
            .copied()
            .fold(0.0f64, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::quickcheck::{forall, prop_assert, prop_close};

    #[test]
    fn total_is_sum_of_leaves() {
        let mut t = SumTree::new(5); // rounds to 8
        t.set(0, 1.0);
        t.set(3, 2.5);
        t.set(4, 0.5);
        assert!((t.total() - 4.0).abs() < 1e-12);
        t.set(3, 0.0);
        assert!((t.total() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn sample_respects_ranges() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 3.0);
        t.set(2, 0.0);
        t.set(3, 6.0);
        assert_eq!(t.sample(0.5), 0);
        assert_eq!(t.sample(1.0), 1);
        assert_eq!(t.sample(3.9), 1);
        assert_eq!(t.sample(4.0), 3);
        assert_eq!(t.sample(9.99), 3);
    }

    #[test]
    fn zero_priority_never_sampled() {
        let mut t = SumTree::new(8);
        t.set(2, 5.0);
        t.set(6, 5.0);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..1_000 {
            let i = t.sample(rng.next_f64() * t.total());
            assert!(i == 2 || i == 6);
        }
    }

    #[test]
    fn sampling_frequency_tracks_priorities() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 2.0);
        t.set(2, 3.0);
        t.set(3, 4.0);
        let mut rng = Pcg32::seeded(9);
        let mut counts = [0u32; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[t.sample(rng.next_f64() * t.total())] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            let expect = (i + 1) as f64 / 10.0;
            let got = *c as f64 / n as f64;
            assert!((got - expect).abs() < 0.01, "leaf {i}: {got} vs {expect}");
        }
    }

    #[test]
    fn max_priority_tracks_updates() {
        let mut t = SumTree::new(4);
        assert_eq!(t.max_priority(), 0.0);
        t.set(1, 7.0);
        t.set(2, 3.0);
        assert_eq!(t.max_priority(), 7.0);
        t.set(1, 0.5);
        assert_eq!(t.max_priority(), 3.0);
    }

    #[test]
    fn property_total_and_sample_consistent() {
        forall(100, |g| {
            let cap = g.usize(1..64);
            let mut t = SumTree::new(cap);
            let mut shadow = vec![0.0f64; t.capacity()];
            for _ in 0..g.usize(1..128) {
                let idx = g.usize(0..t.capacity());
                let p = g.f64(0.0..10.0);
                t.set(idx, p);
                shadow[idx] = p;
            }
            let total: f64 = shadow.iter().sum();
            prop_close(t.total(), total, 1e-9)?;
            if total > 0.0 {
                let u = g.f64(0.0..1.0) * total;
                let leaf = t.sample(u);
                prop_assert(shadow[leaf] > 0.0, "sampled zero-priority leaf")?;
                // Check the prefix invariant: sum of leaves before `leaf`
                // <= u < prefix + leaf priority.
                let prefix: f64 = shadow[..leaf].iter().sum();
                prop_assert(
                    u >= prefix - 1e-9 && u < prefix + shadow[leaf] + 1e-9,
                    "prefix range violated",
                )?;
            }
            Ok(())
        });
    }
}
