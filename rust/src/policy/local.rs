//! IMPALA-style split-phase client: direct backend inference, chunked
//! at `max_batch` rows (the largest compiled AOT batch) over borrowed
//! sub-slices of the caller's slabs.

use super::PolicyClient;
use crate::metrics::{Gauge, Registry};
use crate::runtime::{Backend, InferSlices, ModelDims};

/// Per-ticket reply buffers, reused across steps so the client itself
/// allocates no slabs in the steady-state submit/wait cycle (backend
/// replies still allocate their own outputs).
#[derive(Default)]
struct Slot {
    rows: usize,
    q: Vec<f32>,
    h: Vec<f32>,
    c: Vec<f32>,
}

/// Split-phase client over a local backend. Local inference has no
/// remote latency to overlap, so the call runs synchronously inside
/// `submit` and `wait` only scatters — the honest model of the paper's
/// per-actor-inference baseline (pipeline depth buys nothing here).
pub struct LocalClient {
    backend: Backend,
    max_batch: usize,
    dims: ModelDims,
    slots: Vec<Option<Slot>>,
    spare: Vec<Slot>,
    /// Shared across every actor's client: submissions currently in
    /// flight, pool-wide (incremented on submit, decremented on wait).
    inflight_gauge: Gauge,
}

impl LocalClient {
    pub fn new(
        backend: Backend,
        max_batch: usize,
        dims: ModelDims,
        metrics: &Registry,
    ) -> Self {
        Self {
            backend,
            max_batch: max_batch.max(1),
            dims,
            slots: Vec::new(),
            spare: Vec::new(),
            inflight_gauge: metrics.gauge("policy.inflight"),
        }
    }
}

impl Drop for LocalClient {
    fn drop(&mut self) {
        // Mirror CentralClient: give abandoned tickets' gauge increments
        // back so `policy.inflight` reads 0 after a run.
        let abandoned = self.slots.iter().filter(|s| s.is_some()).count();
        if abandoned > 0 {
            self.inflight_gauge.add(-(abandoned as f64));
        }
    }
}

impl PolicyClient for LocalClient {
    fn submit(
        &mut self,
        ticket: usize,
        rows: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<()> {
        let d = self.dims;
        anyhow::ensure!(rows > 0, "submit with no rows");
        anyhow::ensure!(obs.len() == rows * d.obs_len, "obs slab length");
        anyhow::ensure!(
            h.len() == rows * d.hidden && c.len() == rows * d.hidden,
            "recurrent slab length"
        );
        if self.slots.len() <= ticket {
            self.slots.resize_with(ticket + 1, || None);
        }
        anyhow::ensure!(
            self.slots[ticket].is_none(),
            "ticket {ticket} already in flight"
        );
        let mut slot = self.spare.pop().unwrap_or_default();
        slot.rows = rows;
        slot.q.clear();
        slot.h.clear();
        slot.c.clear();
        // Chunked at the AOT batch cap: borrowed sub-slices straight
        // into the backend — no per-chunk slab copies.
        let mut start = 0usize;
        while start < rows {
            let n = self.max_batch.min(rows - start);
            let r = self.backend.infer_slices(InferSlices {
                n,
                h: &h[start * d.hidden..(start + n) * d.hidden],
                c: &c[start * d.hidden..(start + n) * d.hidden],
                obs: &obs[start * d.obs_len..(start + n) * d.obs_len],
            })?;
            slot.q.extend_from_slice(&r.q);
            slot.h.extend_from_slice(&r.h);
            slot.c.extend_from_slice(&r.c);
            start += n;
        }
        self.slots[ticket] = Some(slot);
        self.inflight_gauge.add(1.0);
        Ok(())
    }

    fn wait(
        &mut self,
        ticket: usize,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<()> {
        let slot = self
            .slots
            .get_mut(ticket)
            .and_then(Option::take)
            .ok_or_else(|| anyhow::anyhow!("wait on idle ticket {ticket}"))?;
        self.inflight_gauge.add(-1.0);
        let d = self.dims;
        anyhow::ensure!(q.len() == slot.rows * d.num_actions, "q slab length");
        anyhow::ensure!(
            h.len() == slot.rows * d.hidden && c.len() == slot.rows * d.hidden,
            "recurrent slab length"
        );
        q.copy_from_slice(&slot.q);
        h.copy_from_slice(&slot.h);
        c.copy_from_slice(&slot.c);
        self.spare.push(slot);
        Ok(())
    }
}
