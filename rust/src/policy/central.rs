//! SEED-style split-phase client: one multi-row slab submission to the
//! central batcher per `submit`, slot-addressed reply chunks scattered
//! into the caller's slabs at `wait`.

use super::PolicyClient;
use crate::coordinator::batcher::{BatcherHandle, InferItem, ReplyChunk};
use crate::metrics::{Gauge, Registry};
use crate::runtime::ModelDims;
use std::sync::mpsc;

struct Pending {
    rx: mpsc::Receiver<ReplyChunk>,
    rows: usize,
}

/// Split-phase client over the central inference batcher. `submit`
/// sends the whole row slab as one [`InferItem`] with a single reply
/// channel; the batcher may serve it as several batches, and `wait`
/// scatters each chunk by its slot offset — no per-row vectors, no
/// per-row channels.
pub struct CentralClient {
    handle: BatcherHandle,
    actor: usize,
    dims: ModelDims,
    inflight: Vec<Option<Pending>>,
    /// Shared across every actor's client: submissions currently in
    /// flight, pool-wide (incremented on submit, decremented on wait).
    inflight_gauge: Gauge,
}

impl CentralClient {
    pub fn new(
        handle: BatcherHandle,
        actor: usize,
        dims: ModelDims,
        metrics: &Registry,
    ) -> Self {
        Self {
            handle,
            actor,
            dims,
            inflight: Vec::new(),
            inflight_gauge: metrics.gauge("policy.inflight"),
        }
    }
}

impl Drop for CentralClient {
    fn drop(&mut self) {
        // The pipelined actor exits with up to one un-waited submission
        // per group; give their gauge increments back so the pool-wide
        // `policy.inflight` reads 0 after a run, not num_actors * depth.
        let abandoned = self.inflight.iter().filter(|p| p.is_some()).count();
        if abandoned > 0 {
            self.inflight_gauge.add(-(abandoned as f64));
        }
    }
}

impl PolicyClient for CentralClient {
    fn submit(
        &mut self,
        ticket: usize,
        rows: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<()> {
        let d = &self.dims;
        anyhow::ensure!(rows > 0, "submit with no rows");
        anyhow::ensure!(obs.len() == rows * d.obs_len, "obs slab length");
        anyhow::ensure!(
            h.len() == rows * d.hidden && c.len() == rows * d.hidden,
            "recurrent slab length"
        );
        if self.inflight.len() <= ticket {
            self.inflight.resize_with(ticket + 1, || None);
        }
        anyhow::ensure!(
            self.inflight[ticket].is_none(),
            "ticket {ticket} already in flight"
        );
        let (rtx, rrx) = mpsc::channel();
        self.handle.submit(InferItem {
            actor: self.actor,
            rows,
            obs: obs.to_vec(),
            h: h.to_vec(),
            c: c.to_vec(),
            reply: rtx,
        })?;
        self.inflight[ticket] = Some(Pending { rx: rrx, rows });
        self.inflight_gauge.add(1.0);
        Ok(())
    }

    fn wait(
        &mut self,
        ticket: usize,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<()> {
        let pending = self
            .inflight
            .get_mut(ticket)
            .and_then(Option::take)
            .ok_or_else(|| anyhow::anyhow!("wait on idle ticket {ticket}"))?;
        self.inflight_gauge.add(-1.0);
        let d = &self.dims;
        let n = pending.rows;
        anyhow::ensure!(q.len() == n * d.num_actions, "q slab length");
        anyhow::ensure!(
            h.len() == n * d.hidden && c.len() == n * d.hidden,
            "recurrent slab length"
        );
        let mut done = 0usize;
        while done < n {
            let chunk = pending
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("{}", self.handle.gone_message()))?;
            let data = match chunk.result {
                Ok(data) => data,
                Err(e) => {
                    return Err(anyhow::anyhow!("central inference failed: {e}"))
                }
            };
            let (s, k) = (chunk.slot0, chunk.rows);
            anyhow::ensure!(s + k <= n, "chunk rows out of range");
            q[s * d.num_actions..(s + k) * d.num_actions].copy_from_slice(&data.q);
            h[s * d.hidden..(s + k) * d.hidden].copy_from_slice(&data.h);
            c[s * d.hidden..(s + k) * d.hidden].copy_from_slice(&data.c);
            done += k;
        }
        Ok(())
    }
}
