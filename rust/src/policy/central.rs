//! SEED-style split-phase client over the pooled slab protocol: one
//! multi-row submission to the central batcher per `submit`, carried in
//! a recycled `InferSlab`; `wait` scatters range-addressed reply
//! chunks from the client's persistent mailbox straight into the
//! caller's slabs. Steady state allocates nothing (the
//! `micro_batcher --quick` gate).

use super::PolicyClient;
use crate::coordinator::batcher::{BatcherHandle, InferItem, ReplyChunk, SlabPool};
use crate::exec::channel::{mailbox, Receiver};
use crate::metrics::{Gauge, Registry};
use crate::runtime::ModelDims;
use std::sync::Arc;

struct Pending {
    rows: usize,
    /// The wire tag this submission travels under (a monotone
    /// per-client counter, not the caller's ticket): reply chunks echo
    /// it, so a chunk from a generation whose `wait` already returned
    /// (e.g. with an error, leaving sibling chunks in the mailbox) can
    /// never be mistaken for a later submission reusing the ticket.
    tag: usize,
}

/// Split-phase client over the central inference batcher.
///
/// Registered once: the client holds one persistent reply mailbox for
/// its whole life; every submission mints a counted route to it
/// (`ticket`-tagged, so several in-flight submissions demultiplex on
/// one mailbox) and carries a recycled input slab from the batcher's
/// shared [`SlabPool`]. `wait` scatters each chunk's rows from the
/// batch's shared output slab by slot offset — no per-step channels, no
/// per-row vectors, no reply copies beyond the one scatter into the
/// caller's `[E, hidden]` buffers.
pub struct CentralClient {
    handle: BatcherHandle,
    pool: Arc<SlabPool>,
    actor: usize,
    dims: ModelDims,
    /// Persistent reply mailbox; reads as disconnected exactly when no
    /// in-flight submission holds a route to it (see `exec::channel`).
    mailbox: Receiver<ReplyChunk>,
    /// Chunks received while waiting on a different in-flight
    /// submission, parked for its own `wait` (capacity settles; steady
    /// state is allocation-free). Chunks whose tag matches no in-flight
    /// submission are stale (their generation's `wait` already errored
    /// out) and are discarded instead.
    stash: Vec<ReplyChunk>,
    inflight: Vec<Option<Pending>>,
    /// Next wire tag (see [`Pending::tag`]).
    next_tag: usize,
    /// Shared across every actor's client: submissions currently in
    /// flight, pool-wide (incremented on submit, decremented on wait).
    inflight_gauge: Gauge,
}

impl CentralClient {
    pub fn new(
        handle: BatcherHandle,
        actor: usize,
        dims: ModelDims,
        metrics: &Registry,
    ) -> Self {
        let pool = handle.slab_pool();
        Self {
            handle,
            pool,
            actor,
            dims,
            mailbox: mailbox(8),
            stash: Vec::new(),
            inflight: Vec::new(),
            next_tag: 0,
            inflight_gauge: metrics.gauge("policy.inflight"),
        }
    }

    /// Does any in-flight submission travel under this wire tag?
    fn tag_in_flight(&self, tag: usize) -> bool {
        self.inflight.iter().flatten().any(|p| p.tag == tag)
    }

    /// Scatter one reply chunk into the output slabs; returns the rows
    /// it covered.
    fn scatter(
        d: ModelDims,
        n: usize,
        chunk: ReplyChunk,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<usize> {
        let range = match chunk.result {
            Ok(range) => range,
            Err(e) => return Err(anyhow::anyhow!("central inference failed: {e}")),
        };
        let (s, k, r0) = (chunk.slot0, chunk.rows, range.row0);
        anyhow::ensure!(s + k <= n, "chunk rows out of range");
        let (na, hd) = (d.num_actions, d.hidden);
        q[s * na..(s + k) * na]
            .copy_from_slice(&range.slab.q[r0 * na..(r0 + k) * na]);
        h[s * hd..(s + k) * hd]
            .copy_from_slice(&range.slab.h[r0 * hd..(r0 + k) * hd]);
        c[s * hd..(s + k) * hd]
            .copy_from_slice(&range.slab.c[r0 * hd..(r0 + k) * hd]);
        Ok(k)
    }
}

impl Drop for CentralClient {
    fn drop(&mut self) {
        // The pipelined actor exits with up to one un-waited submission
        // per group; give their gauge increments back so the pool-wide
        // `policy.inflight` reads 0 after a run, not num_actors * depth.
        let abandoned = self.inflight.iter().filter(|p| p.is_some()).count();
        if abandoned > 0 {
            self.inflight_gauge.add(-(abandoned as f64));
        }
    }
}

impl PolicyClient for CentralClient {
    fn submit(
        &mut self,
        ticket: usize,
        rows: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<()> {
        if self.inflight.len() <= ticket {
            self.inflight.resize_with(ticket + 1, || None);
        }
        anyhow::ensure!(
            self.inflight[ticket].is_none(),
            "ticket {ticket} already in flight"
        );
        // Exact-dims validation happens once, in `handle.submit` (with
        // this actor's id in the message) — copying first is safe, the
        // slab just carries whatever lengths it was given.
        let mut slab = self.pool.acquire();
        slab.fill_from(obs, h, c);
        let tag = self.next_tag;
        self.next_tag = self.next_tag.wrapping_add(1);
        self.handle.submit(InferItem {
            actor: self.actor,
            ticket: tag,
            rows,
            slab,
            reply: self.mailbox.sender(),
        })?;
        self.inflight[ticket] = Some(Pending { rows, tag });
        self.inflight_gauge.add(1.0);
        Ok(())
    }

    fn wait(
        &mut self,
        ticket: usize,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<()> {
        let d = self.dims;
        // Validate the caller's output slabs BEFORE taking the pending
        // entry, so a rejected wait leaves the ticket in flight (a
        // resubmit is refused) instead of freeing it with replies still
        // en route.
        let (n, tag) = {
            let p = self
                .inflight
                .get(ticket)
                .and_then(Option::as_ref)
                .ok_or_else(|| anyhow::anyhow!("wait on idle ticket {ticket}"))?;
            (p.rows, p.tag)
        };
        anyhow::ensure!(q.len() == n * d.num_actions, "q slab length");
        anyhow::ensure!(
            h.len() == n * d.hidden && c.len() == n * d.hidden,
            "recurrent slab length"
        );
        self.inflight[ticket] = None;
        self.inflight_gauge.add(-1.0);
        let mut done = 0usize;
        // First redeem chunks a previous wait parked for this
        // submission; stash entries whose generation is no longer in
        // flight (an earlier wait returned on an error chunk before its
        // siblings arrived) are stale — discard them.
        let mut i = 0;
        while i < self.stash.len() {
            if self.stash[i].ticket == tag {
                let chunk = self.stash.swap_remove(i);
                done += Self::scatter(d, n, chunk, q, h, c)?;
            } else if !self.tag_in_flight(self.stash[i].ticket) {
                self.stash.swap_remove(i);
            } else {
                i += 1;
            }
        }
        while done < n {
            let chunk = self
                .mailbox
                .recv()
                .ok_or_else(|| anyhow::anyhow!("{}", self.handle.gone_message()))?;
            if chunk.ticket == tag {
                done += Self::scatter(d, n, chunk, q, h, c)?;
            } else if self.tag_in_flight(chunk.ticket) {
                // Another in-flight submission's reply: park it.
                self.stash.push(chunk);
            }
            // else: a stale generation's leftover chunk — discard.
        }
        Ok(())
    }
}
