//! Policy layer: split-phase inference clients for actor threads.
//!
//! The seed actor loop blocked on every inference round-trip, so env
//! CPUs idled while the GPU ran and vice versa — artificially inflating
//! the CPU/GPU ratio the paper says the system needs. This layer splits
//! the round-trip into `submit` / `wait` halves behind one trait, so the
//! actor can keep stepping environments for one slot group while another
//! group's inference is in flight (GA3C/SRL-style decoupling; see
//! DESIGN.md §5).
//!
//! Two implementations mirror the paper's Fig. 1 architectures:
//!
//! * [`CentralClient`] — SEED: one multi-row submission to the central
//!   batcher per call, carried in a recycled slab from the batcher's
//!   shared pool; replies arrive on the client's persistent mailbox as
//!   range-addressed chunks into a shared output slab and scatter
//!   straight into the caller's `[rows, hidden]` slabs. The steady-state
//!   round-trip is allocation-free (the `micro_batcher --quick` gate).
//!   Overlap is real: the GPU (or batcher thread) works between
//!   `submit` and `wait`.
//! * [`LocalClient`] — IMPALA baseline: direct backend calls, chunked
//!   at `max_batch` rows via borrowed sub-slices. Inference runs
//!   synchronously inside `submit`, so pipelining buys nothing here —
//!   the honest model of per-actor inference, which has no remote
//!   latency to hide.
//!
//! Tickets are caller-chosen small integers (the actor uses its slot
//! group index), at most one outstanding submission per ticket. The
//! `policy.inflight` gauge tracks outstanding submissions.
//!
//! Telemetry (DESIGN.md §12) observes this seam from the caller's
//! side: the actor loop wraps each `submit`/`wait` call in
//! `policy_submit`/`policy_wait` spans, so both client kinds are
//! covered identically without instrumentation inside the clients —
//! keeping these hot paths free of even the disabled-recorder check.

mod central;
mod local;

pub use central::CentralClient;
pub use local::LocalClient;

/// Split-phase inference: `submit` starts a request, `wait` blocks for
/// it and scatters the results. Implementations are single-actor
/// objects (one per actor thread), not shared handles.
pub trait PolicyClient: Send {
    /// Begin inference on `rows` rows of `obs`/`h`/`c` (row-major
    /// slabs). `ticket` must not already be in flight.
    fn submit(
        &mut self,
        ticket: usize,
        rows: usize,
        obs: &[f32],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<()>;

    /// Block until `ticket`'s replies land; scatter q-values and the
    /// next recurrent state into the `[rows, ·]` output slabs.
    fn wait(
        &mut self,
        ticket: usize,
        q: &mut [f32],
        h: &mut [f32],
        c: &mut [f32],
    ) -> anyhow::Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BatcherConfig;
    use crate::coordinator::Batcher;
    use crate::metrics::Registry;
    use crate::runtime::{Backend, InferRequest, MockModel, ModelDims};
    use std::sync::Arc;

    fn dims() -> ModelDims {
        ModelDims {
            obs_len: 8,
            hidden: 4,
            num_actions: 3,
            seq_len: 4,
            train_batch: 2,
        }
    }

    fn filled_obs(d: &ModelDims, rows: usize) -> Vec<f32> {
        let mut obs = vec![0.0f32; rows * d.obs_len];
        for i in 0..rows {
            obs[i * d.obs_len..(i + 1) * d.obs_len].fill(i as f32 / rows as f32);
        }
        obs
    }

    fn expect_rows(backend: &Backend, d: &ModelDims, obs: &[f32], rows: usize) -> Vec<f32> {
        let mut q = Vec::new();
        for i in 0..rows {
            let direct = backend
                .infer(InferRequest {
                    n: 1,
                    h: vec![0.0; d.hidden],
                    c: vec![0.0; d.hidden],
                    obs: obs[i * d.obs_len..(i + 1) * d.obs_len].to_vec(),
                })
                .unwrap();
            q.extend_from_slice(&direct.q);
        }
        q
    }

    fn roundtrip(
        client: &mut dyn PolicyClient,
        d: &ModelDims,
        rows: usize,
        obs: &[f32],
    ) -> Vec<f32> {
        let h = vec![0.0f32; rows * d.hidden];
        let c = vec![0.0f32; rows * d.hidden];
        client.submit(0, rows, obs, &h, &c).unwrap();
        let mut q = vec![0.0f32; rows * d.num_actions];
        let mut h_out = vec![0.0f32; rows * d.hidden];
        let mut c_out = vec![0.0f32; rows * d.hidden];
        client.wait(0, &mut q, &mut h_out, &mut c_out).unwrap();
        q
    }

    #[test]
    fn central_client_scatters_rows_like_direct_calls() {
        let d = dims();
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 3)));
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                timeout_us: 300,
                batch_sizes: vec![4],
            },
            backend.clone(),
            m.clone(),
        );
        let mut client = CentralClient::new(handle, 0, d, &m);
        // 6 rows at cap 4: spans two batches, still lands in slot order.
        let obs = filled_obs(&d, 6);
        let q = roundtrip(&mut client, &d, 6, &obs);
        assert_eq!(q, expect_rows(&backend, &d, &obs, 6));
        assert_eq!(m.gauge("policy.inflight").get(), 0.0);
        drop(client);
        batcher.join();
    }

    #[test]
    fn local_client_chunks_like_direct_calls() {
        let d = dims();
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 3)));
        let m = Registry::new();
        // max_batch 4 forces the 6-row submission through 2 chunks.
        let mut client = LocalClient::new(backend.clone(), 4, d, &m);
        let obs = filled_obs(&d, 6);
        let q = roundtrip(&mut client, &d, 6, &obs);
        assert_eq!(q, expect_rows(&backend, &d, &obs, 6));
    }

    #[test]
    fn central_and_local_agree() {
        let d = dims();
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 7)));
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(
            BatcherConfig {
                max_batch: 8,
                timeout_us: 300,
                batch_sizes: vec![8],
            },
            backend.clone(),
            m.clone(),
        );
        let mut central = CentralClient::new(handle, 0, d, &m);
        let mut local = LocalClient::new(backend, 8, d, &m);
        let obs = filled_obs(&d, 5);
        assert_eq!(
            roundtrip(&mut central, &d, 5, &obs),
            roundtrip(&mut local, &d, 5, &obs)
        );
        drop(central);
        batcher.join();
    }

    #[test]
    fn ticket_misuse_is_rejected() {
        let d = dims();
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 3)));
        let m = Registry::new();
        let mut client = LocalClient::new(backend, 4, d, &m);
        let obs = vec![0.1f32; d.obs_len];
        let (h, c) = (vec![0.0f32; d.hidden], vec![0.0f32; d.hidden]);
        // wait with nothing in flight
        let mut q = vec![0.0f32; d.num_actions];
        let (mut ho, mut co) = (vec![0.0f32; d.hidden], vec![0.0f32; d.hidden]);
        assert!(client.wait(0, &mut q, &mut ho, &mut co).is_err());
        // double submit on one ticket
        client.submit(0, 1, &obs, &h, &c).unwrap();
        assert!(client.submit(0, 1, &obs, &h, &c).is_err());
        client.wait(0, &mut q, &mut ho, &mut co).unwrap();
    }

    #[test]
    fn dropping_a_client_drains_its_inflight_gauge() {
        // An actor exits with un-waited submissions (the pipelined loop's
        // epilogue); the gauge must return to 0 when the client drops.
        let d = dims();
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 3)));
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                timeout_us: 100,
                batch_sizes: vec![4],
            },
            backend.clone(),
            m.clone(),
        );
        let mut central = CentralClient::new(handle, 0, d, &m);
        let mut local = LocalClient::new(backend, 4, d, &m);
        let obs = filled_obs(&d, 2);
        let h = vec![0.0f32; 2 * d.hidden];
        let c = vec![0.0f32; 2 * d.hidden];
        central.submit(0, 2, &obs, &h, &c).unwrap();
        central.submit(1, 2, &obs, &h, &c).unwrap();
        local.submit(0, 2, &obs, &h, &c).unwrap();
        assert_eq!(m.gauge("policy.inflight").get(), 3.0);
        drop(central);
        drop(local);
        assert_eq!(m.gauge("policy.inflight").get(), 0.0);
        batcher.join();
    }

    #[test]
    fn central_wait_surfaces_inference_failure() {
        let d = dims();
        let backend = Backend::Mock(Arc::new(
            MockModel::new(d, 3).with_infer_error("injected GPU fault"),
        ));
        let m = Registry::new();
        let (batcher, handle) = Batcher::spawn(
            BatcherConfig {
                max_batch: 4,
                timeout_us: 100,
                batch_sizes: vec![4],
            },
            backend,
            m.clone(),
        );
        let mut client = CentralClient::new(handle, 0, d, &m);
        let obs = filled_obs(&d, 2);
        let h = vec![0.0f32; 2 * d.hidden];
        let c = vec![0.0f32; 2 * d.hidden];
        client.submit(0, 2, &obs, &h, &c).unwrap();
        let mut q = vec![0.0f32; 2 * d.num_actions];
        let (mut ho, mut co) = (vec![0.0f32; 2 * d.hidden], vec![0.0f32; 2 * d.hidden]);
        let err = client.wait(0, &mut q, &mut ho, &mut co).unwrap_err().to_string();
        assert!(err.contains("injected GPU fault"), "got: {err}");
        assert_eq!(m.gauge("policy.inflight").get(), 0.0);
        drop(client);
        batcher.join();
    }
}
