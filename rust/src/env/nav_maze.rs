//! NavMaze: procedurally generated maze navigation to a goal cell.
//!
//! Actions: 0 = up, 1 = down, 2 = left, 3 = right.
//! Reward: +1 on reaching the goal (terminal), -0.01 per step (time
//! pressure), walls block movement. Mazes are generated with a seeded
//! recursive-backtracker walk over a half-resolution lattice so every
//! cell is reachable; a new maze is drawn each episode.

use super::{new_frame, put, Environment, Frame, Step, GRID};
use crate::util::prng::Pcg32;

const STEP_PENALTY: f32 = -0.01;
const MAX_STEPS: usize = 400;

pub struct NavMaze {
    rng: Pcg32,
    walls: [[bool; GRID]; GRID],
    agent: (usize, usize),
    goal: (usize, usize),
    steps: usize,
}

impl NavMaze {
    pub fn new(seed: u64) -> Self {
        let mut m = Self {
            rng: Pcg32::seeded(seed),
            walls: [[false; GRID]; GRID],
            agent: (0, 0),
            goal: (GRID - 1, GRID - 1),
            steps: 0,
        };
        m.generate();
        m
    }

    /// Recursive-backtracker over odd cells; even cells become walls
    /// unless carved. Guarantees full connectivity of the carved lattice.
    fn generate(&mut self) {
        for row in self.walls.iter_mut() {
            row.iter_mut().for_each(|w| *w = true);
        }
        // Lattice cells at odd indices (1,3,5,7,9 clipped to GRID-2).
        let cells: Vec<usize> = (0..GRID / 2).map(|i| 2 * i + 1).collect();
        let n = cells.len();
        let mut visited = vec![vec![false; n]; n];
        let mut stack = vec![(0usize, 0usize)];
        visited[0][0] = true;
        self.walls[cells[0]][cells[0]] = false;
        while let Some(&(r, c)) = stack.last() {
            let mut neighbours = Vec::new();
            if r > 0 && !visited[r - 1][c] {
                neighbours.push((r - 1, c));
            }
            if r + 1 < n && !visited[r + 1][c] {
                neighbours.push((r + 1, c));
            }
            if c > 0 && !visited[r][c - 1] {
                neighbours.push((r, c - 1));
            }
            if c + 1 < n && !visited[r][c + 1] {
                neighbours.push((r, c + 1));
            }
            if neighbours.is_empty() {
                stack.pop();
                continue;
            }
            let (nr, nc) = *{
                let i = self.rng.index(neighbours.len());
                &neighbours[i]
            };
            visited[nr][nc] = true;
            // Carve destination and the wall between.
            self.walls[cells[nr]][cells[nc]] = false;
            let wall_r = (cells[r] + cells[nr]) / 2;
            let wall_c = (cells[c] + cells[nc]) / 2;
            self.walls[wall_r][wall_c] = false;
            stack.push((nr, nc));
        }
        // Agent at the first carved cell, goal at the last.
        self.agent = (cells[0], cells[0]);
        self.goal = (cells[n - 1], cells[n - 1]);
        self.steps = 0;
    }

    fn render(&self, frame: &mut Frame) {
        for r in 0..GRID {
            for c in 0..GRID {
                frame[r * GRID + c] = if self.walls[r][c] { 0.25 } else { 0.0 };
            }
        }
        put(frame, self.goal.0, self.goal.1, 0.75);
        put(frame, self.agent.0, self.agent.1, 1.0);
    }
}

impl Environment for NavMaze {
    fn reset(&mut self, frame: &mut Frame) {
        self.generate();
        if frame.len() != GRID * GRID {
            *frame = new_frame();
        }
        self.render(frame);
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        let (r, c) = self.agent;
        let (nr, nc) = match action {
            0 => (r.saturating_sub(1), c),
            1 => ((r + 1).min(GRID - 1), c),
            2 => (r, c.saturating_sub(1)),
            3 => (r, (c + 1).min(GRID - 1)),
            _ => (r, c),
        };
        if !self.walls[nr][nc] {
            self.agent = (nr, nc);
        }
        self.steps += 1;
        let step = if self.agent == self.goal {
            Step::terminal(1.0)
        } else if self.steps >= MAX_STEPS {
            Step {
                reward: STEP_PENALTY,
                done: true,
                truncated: true,
            }
        } else {
            Step::cont(STEP_PENALTY)
        };
        self.render(frame);
        step
    }

    fn name(&self) -> &'static str {
        "nav_maze"
    }

    fn real_actions(&self) -> usize {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::testutil::*;

    fn bfs_path_exists(m: &NavMaze) -> bool {
        let mut seen = [[false; GRID]; GRID];
        let mut queue = std::collections::VecDeque::from([m.agent]);
        seen[m.agent.0][m.agent.1] = true;
        while let Some((r, c)) = queue.pop_front() {
            if (r, c) == m.goal {
                return true;
            }
            let mut push = |nr: usize, nc: usize, seen: &mut [[bool; GRID]; GRID], q: &mut std::collections::VecDeque<(usize, usize)>| {
                if !m.walls[nr][nc] && !seen[nr][nc] {
                    seen[nr][nc] = true;
                    q.push_back((nr, nc));
                }
            };
            if r > 0 {
                push(r - 1, c, &mut seen, &mut queue);
            }
            if r + 1 < GRID {
                push(r + 1, c, &mut seen, &mut queue);
            }
            if c > 0 {
                push(r, c - 1, &mut seen, &mut queue);
            }
            if c + 1 < GRID {
                push(r, c + 1, &mut seen, &mut queue);
            }
        }
        false
    }

    #[test]
    fn goal_always_reachable() {
        for seed in 0..25 {
            let m = NavMaze::new(seed);
            assert!(bfs_path_exists(&m), "seed {seed}: goal unreachable");
        }
    }

    #[test]
    fn walls_block_movement() {
        let mut m = NavMaze::new(0);
        let mut frame = new_frame();
        m.reset(&mut frame);
        let start = m.agent;
        // Try all four moves; whenever a wall is adjacent, position holds.
        for a in 0..4 {
            let before = m.agent;
            let (r, c) = before;
            let target = match a {
                0 => (r.saturating_sub(1), c),
                1 => ((r + 1).min(GRID - 1), c),
                2 => (r, c.saturating_sub(1)),
                _ => (r, (c + 1).min(GRID - 1)),
            };
            m.step(a, &mut frame);
            if m.walls[target.0][target.1] {
                assert_eq!(m.agent, before);
            }
            m.agent = start; // restore for the next direction
        }
    }

    #[test]
    fn truncates_at_max_steps() {
        let mut m = NavMaze::new(2);
        let mut frame = new_frame();
        m.reset(&mut frame);
        let mut last = Step::cont(0.0);
        for _ in 0..MAX_STEPS {
            last = m.step(0, &mut frame); // bump into the top forever
            if last.done {
                break;
            }
        }
        assert!(last.done);
        assert!(last.truncated);
    }

    #[test]
    fn random_walk_eventually_scores() {
        // A long random walk in a connected maze hits the goal sometimes.
        let mut m = NavMaze::new(8);
        let mut frame = new_frame();
        m.reset(&mut frame);
        let mut rng = Pcg32::seeded(123);
        let mut successes = 0;
        for _ in 0..60_000 {
            let s = m.step(rng.index(4), &mut frame);
            if s.done {
                if s.reward > 0.0 {
                    successes += 1;
                }
                m.reset(&mut frame);
            }
        }
        assert!(successes > 0);
        assert_frame_valid(&frame);
    }
}
