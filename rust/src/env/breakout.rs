//! Breakout-lite: three brick rows, a paddle, one ball, three lives.
//!
//! Actions: 0 = noop, 1 = left, 2 = right, 3 = fire (serves the ball when
//! it is dead; otherwise noop — mirroring ALE Breakout's FIRE semantics).
//! Reward: +1 per brick; -1 on a lost life. Episode ends when bricks are
//! cleared or lives run out.

use super::{new_frame, put, Environment, Frame, Step, GRID};
use crate::util::prng::Pcg32;

const LIVES: u32 = 3;
const PADDLE_W: usize = 3;
const BRICK_ROWS: usize = 3;

pub struct Breakout {
    rng: Pcg32,
    bricks: [[bool; GRID]; BRICK_ROWS], // rows 1..=BRICK_ROWS
    ball_r: i32,
    ball_c: i32,
    vel_r: i32,
    vel_c: i32,
    ball_live: bool,
    paddle: usize,
    lives: u32,
}

impl Breakout {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::seeded(seed),
            bricks: [[true; GRID]; BRICK_ROWS],
            ball_r: 0,
            ball_c: 0,
            vel_r: 0,
            vel_c: 0,
            ball_live: false,
            paddle: GRID / 2 - 1,
            lives: LIVES,
        }
    }

    fn serve(&mut self) {
        self.ball_r = (BRICK_ROWS + 2) as i32;
        self.ball_c = self.rng.index(GRID) as i32;
        self.vel_r = 1;
        self.vel_c = if self.rng.chance(0.5) { 1 } else { -1 };
        self.ball_live = true;
    }

    fn bricks_left(&self) -> usize {
        self.bricks
            .iter()
            .map(|row| row.iter().filter(|&&b| b).count())
            .sum()
    }

    fn render(&self, frame: &mut Frame) {
        frame.iter_mut().for_each(|v| *v = 0.0);
        for (i, row) in self.bricks.iter().enumerate() {
            for (c, &b) in row.iter().enumerate() {
                if b {
                    put(frame, i + 1, c, 0.75);
                }
            }
        }
        if self.ball_live {
            put(frame, self.ball_r as usize, self.ball_c as usize, 1.0);
        }
        for i in 0..PADDLE_W {
            put(frame, GRID - 1, (self.paddle + i).min(GRID - 1), 0.5);
        }
    }

    fn paddle_covers(&self, col: i32) -> bool {
        col >= self.paddle as i32 && col < (self.paddle + PADDLE_W) as i32
    }
}

impl Environment for Breakout {
    fn reset(&mut self, frame: &mut Frame) {
        self.bricks = [[true; GRID]; BRICK_ROWS];
        self.lives = LIVES;
        self.paddle = GRID / 2 - 1;
        self.ball_live = false;
        self.serve();
        if frame.len() != GRID * GRID {
            *frame = new_frame();
        }
        self.render(frame);
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        if self.lives == 0 || self.bricks_left() == 0 {
            // Stepping a finished episode (caller should reset): no-op.
            return Step::terminal(0.0);
        }
        match action {
            1 => self.paddle = self.paddle.saturating_sub(1),
            2 => self.paddle = (self.paddle + 1).min(GRID - PADDLE_W),
            3 if !self.ball_live => self.serve(),
            _ => {}
        }
        if !self.ball_live {
            self.render(frame);
            return Step::cont(0.0);
        }

        let mut reward = 0.0;
        // Move with wall bounces.
        let mut nr = self.ball_r + self.vel_r;
        let mut nc = self.ball_c + self.vel_c;
        if nc < 0 {
            nc = 1;
            self.vel_c = 1;
        } else if nc >= GRID as i32 {
            nc = GRID as i32 - 2;
            self.vel_c = -1;
        }
        if nr <= 0 {
            nr = 1;
            self.vel_r = 1;
        }

        // Brick collision.
        if (1..=BRICK_ROWS as i32).contains(&nr) {
            let (ri, ci) = ((nr - 1) as usize, nc as usize);
            if self.bricks[ri][ci] {
                self.bricks[ri][ci] = false;
                reward += 1.0;
                self.vel_r = -self.vel_r;
                nr = self.ball_r; // bounce back the way it came
            }
        }

        let mut done = false;
        if nr >= (GRID - 1) as i32 {
            if self.paddle_covers(nc) {
                self.vel_r = -1;
                nr = (GRID - 2) as i32;
                // English: paddle edge redirects the ball.
                if nc == self.paddle as i32 {
                    self.vel_c = -1;
                } else if nc == (self.paddle + PADDLE_W - 1) as i32 {
                    self.vel_c = 1;
                }
            } else {
                reward -= 1.0;
                self.lives -= 1;
                self.ball_live = false;
                if self.lives == 0 {
                    done = true;
                }
            }
        }
        if self.ball_live {
            self.ball_r = nr;
            self.ball_c = nc;
        }
        if self.bricks_left() == 0 {
            done = true;
        }
        self.render(frame);
        Step {
            reward,
            done,
            truncated: false,
        }
    }

    fn name(&self) -> &'static str {
        "breakout"
    }

    fn real_actions(&self) -> usize {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::testutil::*;

    #[test]
    fn starts_with_full_bricks() {
        let env = Breakout::new(0);
        assert_eq!(env.bricks_left(), BRICK_ROWS * GRID);
    }

    #[test]
    fn fire_required_after_life_loss() {
        let mut env = Breakout::new(1);
        let mut frame = new_frame();
        env.reset(&mut frame);
        // Park the paddle far left, let the ball drop.
        for _ in 0..200 {
            let s = env.step(1, &mut frame);
            if s.reward < 0.0 {
                break;
            }
        }
        assert!(!env.ball_live);
        // Without FIRE nothing moves.
        let before = frame.clone();
        env.step(0, &mut frame);
        assert_eq!(before, frame);
        env.step(3, &mut frame);
        assert!(env.ball_live);
    }

    #[test]
    fn tracking_play_clears_bricks() {
        let mut env = Breakout::new(4);
        let mut frame = new_frame();
        env.reset(&mut frame);
        let mut bricks_broken = 0.0;
        for _ in 0..5_000 {
            let action = if !env.ball_live {
                3
            } else {
                let bc = env.ball_c;
                let centre = env.paddle as i32 + 1;
                if bc < centre {
                    1
                } else if bc > centre {
                    2
                } else {
                    0
                }
            };
            let s = env.step(action, &mut frame);
            if s.reward > 0.0 {
                bricks_broken += s.reward;
            }
            assert_frame_valid(&frame);
            if s.done {
                break;
            }
        }
        assert!(bricks_broken >= 5.0, "broke {bricks_broken}");
    }

    #[test]
    fn episode_terminates_for_any_policy() {
        for seed in 0..4 {
            let mut env = Breakout::new(seed);
            let (_, episodes) = drive(&mut env, 3, 20_000);
            assert!(episodes > 0, "seed {seed} never terminated");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = Breakout::new(seed);
            let mut frame = new_frame();
            env.reset(&mut frame);
            let mut out = Vec::new();
            for i in 0..300 {
                out.push(env.step(i % 4, &mut frame).reward as i32);
            }
            out
        };
        assert_eq!(run(77), run(77));
    }
}
