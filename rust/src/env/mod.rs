//! ALE-like environment substrate.
//!
//! The paper's workload is SEED-RL's R2D2 on the Arcade Learning
//! Environment. Atari ROMs are not redistributable, so this module
//! provides a suite of small deterministic arcade games with the same
//! interface contract (pixel-ish observations, small discrete action set,
//! episodic reward, sticky actions, frame stacking) and a calibrated
//! per-step CPU cost knob so actor-side load matches the ALE regime on
//! this host (see `config::EnvConfig::step_cost_us`).
//!
//! All games render to a GRID x GRID single-channel float frame in [0,1];
//! wrappers stack the last K frames into the [S, S, K] observation the
//! agent network consumes.

pub mod breakout;
pub mod catch;
pub mod grid_pong;
pub mod nav_maze;
pub mod registry;
pub mod soa;
pub mod wrappers;

pub use registry::{make_env, registered_envs};
pub use soa::{make_batch_env, BatchEnv};
pub use wrappers::{FrameStack, StepCost, StickyActions, Wrapped};

/// Grid side length shared by the whole suite (matches the AOT'd agent's
/// `obs_size`).
pub const GRID: usize = 10;

/// Number of discrete actions shared by the whole suite (matches the
/// AOT'd agent's `num_actions`). Games that need fewer map extras to noop.
pub const NUM_ACTIONS: usize = 4;

/// One environment step's outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct Step {
    pub reward: f32,
    /// Episode ended (terminal state reached or truncated).
    pub done: bool,
    /// True when `done` came from truncation (time limit), not a terminal.
    pub truncated: bool,
}

impl Step {
    pub fn cont(reward: f32) -> Self {
        Self {
            reward,
            done: false,
            truncated: false,
        }
    }

    pub fn terminal(reward: f32) -> Self {
        Self {
            reward,
            done: true,
            truncated: false,
        }
    }
}

/// A single-channel frame: GRID*GRID floats in [0,1], row-major.
pub type Frame = Vec<f32>;

/// The environment contract (ALE-shaped).
pub trait Environment: Send {
    /// Reset to a fresh episode; render the initial frame into `frame`.
    fn reset(&mut self, frame: &mut Frame);

    /// Apply `action`, advance one step, render into `frame`.
    fn step(&mut self, action: usize, frame: &mut Frame) -> Step;

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Actions this game actually distinguishes (<= NUM_ACTIONS).
    fn real_actions(&self) -> usize;
}

/// Allocate a zeroed frame of the suite's size.
pub fn new_frame() -> Frame {
    vec![0.0; GRID * GRID]
}

/// Set cell (row, col) to `v` (bounds-checked in debug).
#[inline]
pub(crate) fn put(frame: &mut Frame, row: usize, col: usize, v: f32) {
    debug_assert!(row < GRID && col < GRID);
    frame[row * GRID + col] = v;
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Drive `env` for `steps` steps with a fixed action; return total
    /// reward and number of episode boundaries crossed.
    pub fn drive(env: &mut dyn Environment, action: usize, steps: usize) -> (f32, usize) {
        let mut frame = new_frame();
        env.reset(&mut frame);
        let mut total = 0.0;
        let mut episodes = 0;
        for _ in 0..steps {
            let s = env.step(action, &mut frame);
            total += s.reward;
            if s.done {
                episodes += 1;
                env.reset(&mut frame);
            }
        }
        (total, episodes)
    }

    /// Frames must always be in [0,1].
    pub fn assert_frame_valid(frame: &Frame) {
        assert_eq!(frame.len(), GRID * GRID);
        for &v in frame {
            assert!((0.0..=1.0).contains(&v), "frame value {v} out of range");
        }
    }
}
