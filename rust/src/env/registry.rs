//! Environment registry: name -> constructor, so configs and CLIs select
//! games by string (ALE-style).

use super::breakout::Breakout;
use super::catch::Catch;
use super::grid_pong::GridPong;
use super::nav_maze::NavMaze;
use super::Environment;

/// Names accepted by `make_env`, in display order.
pub fn registered_envs() -> &'static [&'static str] {
    &["grid_pong", "breakout", "catch", "nav_maze"]
}

/// Construct a base environment by registered name.
pub fn make_env(name: &str, seed: u64) -> anyhow::Result<Box<dyn Environment>> {
    match name {
        "grid_pong" => Ok(Box::new(GridPong::new(seed))),
        "breakout" => Ok(Box::new(Breakout::new(seed))),
        "catch" => Ok(Box::new(Catch::new(seed))),
        "nav_maze" => Ok(Box::new(NavMaze::new(seed))),
        other => anyhow::bail!(
            "unknown env `{other}` (registered: {:?})",
            registered_envs()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{new_frame, NUM_ACTIONS};

    #[test]
    fn all_registered_names_construct() {
        for name in registered_envs() {
            let mut env = make_env(name, 0).unwrap();
            let mut f = new_frame();
            env.reset(&mut f);
            assert_eq!(env.name(), *name);
            assert!(env.real_actions() <= NUM_ACTIONS);
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(make_env("space_invaders", 0).is_err());
    }

    #[test]
    fn extra_actions_are_safe_noops() {
        // Every game must tolerate the full shared action space.
        for name in registered_envs() {
            let mut env = make_env(name, 1).unwrap();
            let mut f = new_frame();
            env.reset(&mut f);
            for a in 0..NUM_ACTIONS {
                let s = env.step(a, &mut f);
                if s.done {
                    env.reset(&mut f);
                }
            }
        }
    }
}
