//! ALE-style wrappers: sticky actions, frame stacking, per-step CPU cost,
//! and episode bookkeeping, composed into `Wrapped` (the type the actor
//! threads drive).

use super::{new_frame, Environment, Frame, Step, GRID};
use crate::config::EnvConfig;
use crate::util::prng::Pcg32;
use std::time::{Duration, Instant};

/// Sticky actions (Machado et al.): with probability p, repeat the
/// previous action instead of the requested one. The standard ALE
/// stochasticity device — prevents open-loop policies.
pub struct StickyActions<E: Environment> {
    inner: E,
    prob: f64,
    rng: Pcg32,
    last_action: usize,
}

impl<E: Environment> StickyActions<E> {
    pub fn new(inner: E, prob: f64, seed: u64) -> Self {
        Self {
            inner,
            prob,
            rng: Pcg32::seeded(seed),
            last_action: 0,
        }
    }
}

impl<E: Environment> Environment for StickyActions<E> {
    fn reset(&mut self, frame: &mut Frame) {
        self.last_action = 0;
        self.inner.reset(frame);
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        let effective = if self.rng.chance(self.prob) {
            self.last_action
        } else {
            action
        };
        self.last_action = effective;
        self.inner.step(effective, frame)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn real_actions(&self) -> usize {
        self.inner.real_actions()
    }
}

/// Burns CPU for a configured duration per step, emulating heavier
/// environment simulators (the knob that calibrates actor-side load to
/// the ALE regime; see DESIGN.md §2). Spin-waits below 50us (sleep
/// granularity), sleeps above.
pub struct StepCost<E: Environment> {
    inner: E,
    cost: Duration,
}

impl<E: Environment> StepCost<E> {
    pub fn new(inner: E, cost_us: u64) -> Self {
        Self {
            inner,
            cost: Duration::from_micros(cost_us),
        }
    }

    fn burn(&self) {
        if self.cost.is_zero() {
            return;
        }
        if self.cost < Duration::from_micros(50) {
            let t0 = Instant::now();
            while t0.elapsed() < self.cost {
                std::hint::spin_loop();
            }
        } else {
            std::thread::sleep(self.cost);
        }
    }
}

impl<E: Environment> Environment for StepCost<E> {
    fn reset(&mut self, frame: &mut Frame) {
        self.inner.reset(frame);
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        self.burn();
        self.inner.step(action, frame)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn real_actions(&self) -> usize {
        self.inner.real_actions()
    }
}

/// Stacks the last K frames into a [S, S, K] channel-last observation
/// (the layout `model.AgentConfig.obs_shape` expects). On reset the stack
/// is filled with copies of the initial frame.
pub struct FrameStack {
    k: usize,
    history: Vec<Frame>, // most recent last
}

impl FrameStack {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Self {
            k,
            history: Vec::with_capacity(k),
        }
    }

    pub fn reset(&mut self, frame: &Frame) {
        self.history.clear();
        for _ in 0..self.k {
            self.history.push(frame.clone());
        }
    }

    pub fn push(&mut self, frame: &Frame) {
        if self.history.len() == self.k {
            self.history.remove(0);
        }
        self.history.push(frame.clone());
    }

    /// Write the stacked observation into `out` ([S*S*K] floats,
    /// channel-last: out[(r*S + c)*K + ch], ch 0 = oldest).
    pub fn observe(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), GRID * GRID * self.k);
        for (ch, frame) in self.history.iter().enumerate() {
            for (i, &v) in frame.iter().enumerate() {
                out[i * self.k + ch] = v;
            }
        }
    }

    pub fn obs_len(&self) -> usize {
        GRID * GRID * self.k
    }
}

/// Fully wrapped environment with episode bookkeeping: the unit an actor
/// thread owns. Observations come out stacked and channel-last.
pub struct Wrapped {
    env: Box<dyn Environment>,
    stack: FrameStack,
    frame: Frame,
    max_episode_len: usize,
    pub episode_return: f32,
    pub episode_len: usize,
    pub episodes_completed: u64,
    pub total_steps: u64,
    /// Return of the last *completed* episode.
    pub last_return: f32,
}

impl Wrapped {
    pub fn from_config(cfg: &EnvConfig, instance_seed: u64) -> anyhow::Result<Self> {
        let base = super::registry::make_env(&cfg.name, cfg.seed ^ instance_seed)?;
        let sticky = StickyActions::new(
            BoxedEnv(base),
            cfg.sticky_action_prob,
            cfg.seed.wrapping_add(instance_seed).wrapping_mul(0x9E37),
        );
        let costed = StepCost::new(sticky, cfg.step_cost_us);
        Ok(Self {
            env: Box::new(costed),
            stack: FrameStack::new(cfg.frame_stack),
            frame: new_frame(),
            max_episode_len: cfg.max_episode_len,
            episode_return: 0.0,
            episode_len: 0,
            episodes_completed: 0,
            total_steps: 0,
            last_return: 0.0,
        })
    }

    pub fn obs_len(&self) -> usize {
        self.stack.obs_len()
    }

    /// Reset and write the initial stacked observation.
    pub fn reset(&mut self, obs: &mut [f32]) {
        self.env.reset(&mut self.frame);
        self.stack.reset(&self.frame);
        self.stack.observe(obs);
        self.episode_return = 0.0;
        self.episode_len = 0;
    }

    /// Step; on episode end auto-resets (returning done=true for the
    /// transition) so actors never stall. Observation written is the
    /// *post-step* stacked obs (initial obs of the next episode if done).
    pub fn step(&mut self, action: usize, obs: &mut [f32]) -> Step {
        let mut step = self.env.step(action, &mut self.frame);
        self.episode_return += step.reward;
        self.episode_len += 1;
        self.total_steps += 1;
        if !step.done && self.episode_len >= self.max_episode_len {
            step.done = true;
            step.truncated = true;
        }
        if step.done {
            self.episodes_completed += 1;
            self.last_return = self.episode_return;
            self.env.reset(&mut self.frame);
            self.stack.reset(&self.frame);
            self.episode_return = 0.0;
            self.episode_len = 0;
        } else {
            self.stack.push(&self.frame);
        }
        self.stack.observe(obs);
        step
    }

    pub fn name(&self) -> &'static str {
        self.env.name()
    }
}

/// Adapter so `Box<dyn Environment>` can feed the generic wrappers.
struct BoxedEnv(Box<dyn Environment>);

impl Environment for BoxedEnv {
    fn reset(&mut self, frame: &mut Frame) {
        self.0.reset(frame)
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        self.0.step(action, frame)
    }

    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn real_actions(&self) -> usize {
        self.0.real_actions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::catch::Catch;

    #[test]
    fn sticky_actions_repeat_sometimes() {
        // With prob 1.0 every action after the first is the first action.
        struct Recorder {
            seen: Vec<usize>,
        }
        impl Environment for Recorder {
            fn reset(&mut self, _f: &mut Frame) {}
            fn step(&mut self, a: usize, _f: &mut Frame) -> Step {
                self.seen.push(a);
                Step::cont(0.0)
            }
            fn name(&self) -> &'static str {
                "rec"
            }
            fn real_actions(&self) -> usize {
                4
            }
        }
        let mut env = StickyActions::new(Recorder { seen: vec![] }, 1.0, 0);
        let mut f = new_frame();
        env.reset(&mut f);
        for a in [2, 3, 1, 0] {
            env.step(a, &mut f);
        }
        // prob=1.0: always repeat last (initially 0).
        assert_eq!(env.inner.seen, vec![0, 0, 0, 0]);

        let mut env = StickyActions::new(Recorder { seen: vec![] }, 0.0, 0);
        env.reset(&mut f);
        for a in [2, 3, 1, 0] {
            env.step(a, &mut f);
        }
        assert_eq!(env.inner.seen, vec![2, 3, 1, 0]);
    }

    #[test]
    fn step_cost_burns_time() {
        let mut env = StepCost::new(Catch::new(0), 200);
        let mut f = new_frame();
        env.reset(&mut f);
        let t0 = Instant::now();
        for _ in 0..10 {
            env.step(0, &mut f);
        }
        assert!(t0.elapsed() >= Duration::from_micros(1_500));
    }

    #[test]
    fn frame_stack_layout_channel_last() {
        let mut fs = FrameStack::new(2);
        let f1 = vec![0.1; GRID * GRID];
        let mut f2 = vec![0.2; GRID * GRID];
        f2[0] = 0.9;
        fs.reset(&f1);
        fs.push(&f2);
        let mut obs = vec![0.0; GRID * GRID * 2];
        fs.observe(&mut obs);
        // Cell 0: channel 0 = old frame (0.1), channel 1 = new frame (0.9).
        assert_eq!(obs[0], 0.1);
        assert_eq!(obs[1], 0.9);
        assert_eq!(obs[2], 0.1);
        assert_eq!(obs[3], 0.2);
    }

    #[test]
    fn wrapped_auto_resets_and_counts_episodes() {
        let cfg = EnvConfig {
            name: "catch".into(),
            frame_stack: 4,
            sticky_action_prob: 0.0,
            max_episode_len: 50,
            step_cost_us: 0,
            seed: 1,
            batch_native: false,
        };
        let mut w = Wrapped::from_config(&cfg, 0).unwrap();
        let mut obs = vec![0.0; w.obs_len()];
        w.reset(&mut obs);
        let mut dones = 0;
        for _ in 0..100 {
            if w.step(0, &mut obs).done {
                dones += 1;
            }
        }
        assert!(dones >= 9, "catch episodes are 9 steps: got {dones}");
        assert_eq!(w.episodes_completed, dones as u64);
        assert_eq!(w.total_steps, 100);
    }

    #[test]
    fn wrapped_truncates_long_episodes() {
        let cfg = EnvConfig {
            name: "nav_maze".into(),
            frame_stack: 2,
            sticky_action_prob: 0.0,
            max_episode_len: 10,
            step_cost_us: 0,
            seed: 3,
            batch_native: false,
        };
        let mut w = Wrapped::from_config(&cfg, 0).unwrap();
        let mut obs = vec![0.0; w.obs_len()];
        w.reset(&mut obs);
        let mut steps_to_done = 0;
        loop {
            steps_to_done += 1;
            if w.step(0, &mut obs).done {
                break;
            }
            assert!(steps_to_done <= 10);
        }
        assert_eq!(steps_to_done, 10);
    }
}
