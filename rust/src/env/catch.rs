//! Catch: a falling block must be caught by a 1-cell paddle (bsuite-style).
//!
//! Actions: 0 = noop, 1 = left, 2 = right, 3 = noop.
//! Reward: +1 on catch, -1 on miss; episode ends on either after the
//! block reaches the bottom row. The simplest game in the suite — the
//! quickstart example trains on it because a few hundred learner steps
//! already lift the catch rate well above chance.

use super::{new_frame, put, Environment, Frame, Step, GRID};
use crate::util::prng::Pcg32;

pub struct Catch {
    rng: Pcg32,
    ball_row: usize,
    ball_col: usize,
    paddle_col: usize,
}

impl Catch {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::seeded(seed),
            ball_row: 0,
            ball_col: 0,
            paddle_col: GRID / 2,
        }
    }

    fn render(&self, frame: &mut Frame) {
        frame.iter_mut().for_each(|v| *v = 0.0);
        put(frame, self.ball_row, self.ball_col, 1.0);
        put(frame, GRID - 1, self.paddle_col, 0.5);
    }
}

impl Environment for Catch {
    fn reset(&mut self, frame: &mut Frame) {
        self.ball_row = 0;
        self.ball_col = self.rng.index(GRID);
        self.paddle_col = GRID / 2;
        if frame.len() != GRID * GRID {
            *frame = new_frame();
        }
        self.render(frame);
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        if self.ball_row >= GRID - 1 {
            // Stepping a finished episode (caller should reset): no-op.
            return Step::terminal(0.0);
        }
        match action {
            1 => self.paddle_col = self.paddle_col.saturating_sub(1),
            2 => self.paddle_col = (self.paddle_col + 1).min(GRID - 1),
            _ => {}
        }
        self.ball_row += 1;
        let step = if self.ball_row == GRID - 1 {
            if self.ball_col == self.paddle_col {
                Step::terminal(1.0)
            } else {
                Step::terminal(-1.0)
            }
        } else {
            Step::cont(0.0)
        };
        self.render(frame);
        step
    }

    fn name(&self) -> &'static str {
        "catch"
    }

    fn real_actions(&self) -> usize {
        3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::testutil::*;

    #[test]
    fn episode_length_is_grid_minus_one() {
        let mut env = Catch::new(0);
        let mut frame = new_frame();
        env.reset(&mut frame);
        for i in 0..GRID - 1 {
            let s = env.step(0, &mut frame);
            assert_eq!(s.done, i == GRID - 2, "step {i}");
            assert_frame_valid(&frame);
        }
    }

    #[test]
    fn perfect_play_always_catches() {
        let mut env = Catch::new(7);
        let mut frame = new_frame();
        let mut total = 0.0;
        for _ in 0..20 {
            env.reset(&mut frame);
            loop {
                // Read ball/paddle from the frame: move toward the ball.
                let ball = frame.iter().position(|&v| v == 1.0).unwrap();
                let paddle = frame.iter().position(|&v| v == 0.5).unwrap();
                let (bc, pc) = (ball % GRID, paddle % GRID);
                let action = match bc.cmp(&pc) {
                    std::cmp::Ordering::Less => 1,
                    std::cmp::Ordering::Greater => 2,
                    std::cmp::Ordering::Equal => 0,
                };
                let s = env.step(action, &mut frame);
                total += s.reward;
                if s.done {
                    break;
                }
            }
        }
        assert_eq!(total, 20.0, "ball always reachable: start row 0");
    }

    #[test]
    fn random_play_is_near_chance() {
        let mut env = Catch::new(11);
        let (total, episodes) = drive(&mut env, 0, 5_000);
        assert!(episodes > 400);
        // Static paddle catches ~1/GRID of drops: strongly negative total.
        assert!(total < -(episodes as f32) * 0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = Catch::new(seed);
            let mut frame = new_frame();
            env.reset(&mut frame);
            let mut rs = Vec::new();
            for a in [0, 1, 2, 1, 0, 2, 2, 1, 0] {
                rs.push(env.step(a, &mut frame).reward);
            }
            (rs, frame.clone())
        };
        assert_eq!(run(5), run(5));
        // Different seeds: different drop columns (almost surely).
        let cols: Vec<usize> = (0..8)
            .map(|s| {
                let mut env = Catch::new(s);
                let mut f = new_frame();
                env.reset(&mut f);
                f.iter().position(|&v| v == 1.0).unwrap() % GRID
            })
            .collect();
        assert!(cols.iter().any(|&c| c != cols[0]));
    }
}
