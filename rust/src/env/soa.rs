//! Batch-native environment engine: one call steps all E slots over
//! struct-of-arrays state (DESIGN.md §13).
//!
//! The per-slot path ([`super::wrappers::Wrapped`] behind
//! [`crate::vecenv::VecEnv`]) pays E object dispatches, E frame-stack
//! deque rotations, and E row copies per batched step — the dominant
//! actor-side CPU term the paper measures. The SoA engine keeps every
//! logical plane contiguous across slots instead: one `[E, S, S]` grid
//! buffer, one `[E, S, S, K]` stacked-observation slab, and one `[E]`
//! array per scalar (episode returns, step counts, sticky-action state,
//! RNG streams). A step over a slot range is then four passes:
//!
//!   1. per-slot game dynamics into the grid plane (scalar SoA fields),
//!   2. ONE `copy_within` over the shared observation slab — the
//!      vectorized frame-stack shift replacing E deque rotations (every
//!      position moves one channel left; the cross-cell bleed lands only
//!      on newest-channel positions, which pass 3 rewrites),
//!   3. per-slot scatter of the new frame into the newest channel (done
//!      slots refill all K channels, the stack-reset semantics),
//!   4. one contiguous copy of the stepped sub-slab into the caller's
//!      observation rows.
//!
//! Every buffer is preallocated at construction, so steady-state
//! `step_all` performs zero heap allocations (gated by `micro_env` in
//! CI). Behavior is bit-for-bit identical to the per-slot path — same
//! RNG streams, same step order, same auto-reset and truncation
//! semantics — asserted per game by the tests below and across random
//! configurations by `tests/property_invariants.rs`. This is the CuLE
//! direction (PAPERS.md, 1907.08467): batch-native layout first, the
//! stepping stone to GPU-resident envs.

use super::{Step, GRID};
use crate::config::EnvConfig;
use crate::util::prng::Pcg32;
use std::time::{Duration, Instant};

const CELLS: usize = GRID * GRID;

/// A batch-native environment engine: E env slots stepped through one
/// call over struct-of-arrays state. The dispatch seam `VecEnv` selects
/// with `env.batch_native` (the per-slot `Wrapped` path is the
/// bit-for-bit reference).
pub trait BatchEnv: Send {
    /// Environment slots behind this engine.
    fn num_envs(&self) -> usize;

    /// Per-slot observation length (S * S * K floats).
    fn obs_len(&self) -> usize;

    /// Reset every slot; write all initial observations into
    /// `obs_batch` (`[E, S, S, K]`).
    fn reset_all(&mut self, obs_batch: &mut [f32]);

    /// Step the contiguous slot range `start .. start + actions.len()`
    /// in one call; write each slot's post-step observation into its
    /// row of `obs_rows` and append one `Step` per slot to `steps` (in
    /// slot order). Slots whose episode ends auto-reset.
    fn step_range(
        &mut self,
        start: usize,
        actions: &[usize],
        obs_rows: &mut [f32],
        steps: &mut Vec<Step>,
    );

    /// Step all E slots in one call (`step_range` over the whole pool).
    fn step_all(&mut self, actions: &[usize], obs_batch: &mut [f32], steps: &mut Vec<Step>) {
        self.step_range(0, actions, obs_batch, steps);
    }

    /// Total env steps across all slots.
    fn total_steps(&self) -> u64;

    /// Completed episodes across all slots.
    fn episodes_completed(&self) -> u64;

    /// Return of `slot`'s last completed episode.
    fn last_return(&self, slot: usize) -> f32;

    /// Environment name (shared by every slot).
    fn name(&self) -> &'static str;
}

/// Build the SoA engine for `cfg.name` with `num_envs` slots. Slot `i`
/// uses instance seed `base_instance_seed + i` — the same layout as
/// `VecEnv`'s per-slot construction, so the two paths share RNG streams
/// exactly.
pub fn make_batch_env(
    cfg: &EnvConfig,
    num_envs: usize,
    base_instance_seed: u64,
) -> anyhow::Result<Box<dyn BatchEnv>> {
    anyhow::ensure!(num_envs > 0, "batch env needs at least one slot");
    // Per-slot game seeds, identical to `Wrapped::from_config`'s
    // `cfg.seed ^ instance_seed`.
    let seeds: Vec<u64> = (0..num_envs)
        .map(|i| cfg.seed ^ (base_instance_seed + i as u64))
        .collect();
    Ok(match cfg.name.as_str() {
        "catch" => Box::new(SoaEngine::new(CatchSoa::new(&seeds), cfg, base_instance_seed)),
        "grid_pong" => Box::new(SoaEngine::new(
            GridPongSoa::new(&seeds),
            cfg,
            base_instance_seed,
        )),
        "breakout" => Box::new(SoaEngine::new(
            BreakoutSoa::new(&seeds),
            cfg,
            base_instance_seed,
        )),
        "nav_maze" => Box::new(SoaEngine::new(
            NavMazeSoa::new(&seeds),
            cfg,
            base_instance_seed,
        )),
        other => anyhow::bail!(
            "unknown env `{other}` (registered: {:?})",
            super::registry::registered_envs()
        ),
    })
}

/// Game dynamics over struct-of-arrays state: every field is an `[E]`
/// plane indexed by slot. `reset_slot`/`step_slot` must replicate the
/// per-slot `Environment` impl bit-for-bit (same RNG draw order) — the
/// equivalence tests pin this per game.
pub trait SoaGame: Send {
    fn name(&self) -> &'static str;
    /// Slots this game's planes were built for.
    fn num_envs(&self) -> usize;
    /// Reset slot `i` to a fresh episode; render into its grid row.
    fn reset_slot(&mut self, i: usize, frame: &mut [f32]);
    /// Advance slot `i` one step; render into its grid row.
    fn step_slot(&mut self, i: usize, action: usize, frame: &mut [f32]) -> Step;
}

/// The shared engine: wrapper semantics (sticky actions, step cost,
/// frame stacking, episode bookkeeping) over any [`SoaGame`], with all
/// wrapper state SoA as well.
pub struct SoaEngine<G: SoaGame> {
    game: G,
    e: usize,
    k: usize,
    /// `[E, S, S]` raw frame plane (one grid row per slot).
    grid: Vec<f32>,
    /// `[E, S, S, K]` stacked channel-last observation slab.
    stack: Vec<f32>,
    sticky_prob: f64,
    sticky_rng: Vec<Pcg32>,
    last_action: Vec<usize>,
    cost: Duration,
    max_episode_len: usize,
    episode_return: Vec<f32>,
    episode_len: Vec<usize>,
    episodes_completed: Vec<u64>,
    total_steps: Vec<u64>,
    last_return: Vec<f32>,
}

impl<G: SoaGame> SoaEngine<G> {
    pub fn new(game: G, cfg: &EnvConfig, base_instance_seed: u64) -> Self {
        let e = game.num_envs();
        let k = cfg.frame_stack.max(1);
        // Sticky-action RNG streams match the per-slot wrapper's seed
        // layout exactly.
        let sticky_rng = (0..e)
            .map(|i| {
                let instance = base_instance_seed + i as u64;
                Pcg32::seeded(cfg.seed.wrapping_add(instance).wrapping_mul(0x9E37))
            })
            .collect();
        Self {
            game,
            e,
            k,
            grid: vec![0.0; e * CELLS],
            stack: vec![0.0; e * CELLS * k],
            sticky_prob: cfg.sticky_action_prob,
            sticky_rng,
            last_action: vec![0; e],
            cost: Duration::from_micros(cfg.step_cost_us),
            max_episode_len: cfg.max_episode_len,
            episode_return: vec![0.0; e],
            episode_len: vec![0; e],
            episodes_completed: vec![0; e],
            total_steps: vec![0; e],
            last_return: vec![0.0; e],
        }
    }

    /// Emulate heavier simulators exactly like the per-slot `StepCost`
    /// wrapper: spin below 50us (sleep granularity), sleep above, skip
    /// at zero.
    fn burn(&self) {
        if self.cost.is_zero() {
            return;
        }
        if self.cost < Duration::from_micros(50) {
            let t0 = Instant::now();
            while t0.elapsed() < self.cost {
                std::hint::spin_loop();
            }
        } else {
            std::thread::sleep(self.cost);
        }
    }
}

impl<G: SoaGame> BatchEnv for SoaEngine<G> {
    fn num_envs(&self) -> usize {
        self.e
    }

    fn obs_len(&self) -> usize {
        CELLS * self.k
    }

    fn reset_all(&mut self, obs_batch: &mut [f32]) {
        assert_eq!(obs_batch.len(), self.e * CELLS * self.k, "obs batch size");
        let k = self.k;
        for i in 0..self.e {
            self.last_action[i] = 0;
            self.game
                .reset_slot(i, &mut self.grid[i * CELLS..(i + 1) * CELLS]);
            self.episode_return[i] = 0.0;
            self.episode_len[i] = 0;
            // Stack reset: K copies of the initial frame, channel-last.
            let frame = &self.grid[i * CELLS..(i + 1) * CELLS];
            let row = &mut self.stack[i * CELLS * k..(i + 1) * CELLS * k];
            for (cell, &v) in frame.iter().enumerate() {
                row[cell * k..(cell + 1) * k].fill(v);
            }
        }
        obs_batch.copy_from_slice(&self.stack);
    }

    fn step_range(
        &mut self,
        start: usize,
        actions: &[usize],
        obs_rows: &mut [f32],
        steps: &mut Vec<Step>,
    ) {
        let len = actions.len();
        let k = self.k;
        assert!(start + len <= self.e, "slot range out of bounds");
        assert_eq!(obs_rows.len(), len * CELLS * k, "obs rows size");
        if len == 0 {
            return;
        }

        // Pass 1: dynamics + episode bookkeeping, slot by slot over the
        // SoA planes (identical order and RNG draws to the per-slot
        // wrapper chain: step cost, then sticky draw, then game step).
        for (j, &action) in actions.iter().enumerate() {
            let i = start + j;
            self.burn();
            let effective = if self.sticky_rng[i].chance(self.sticky_prob) {
                self.last_action[i]
            } else {
                action
            };
            self.last_action[i] = effective;
            let mut step =
                self.game
                    .step_slot(i, effective, &mut self.grid[i * CELLS..(i + 1) * CELLS]);
            self.episode_return[i] += step.reward;
            self.episode_len[i] += 1;
            self.total_steps[i] += 1;
            if !step.done && self.episode_len[i] >= self.max_episode_len {
                step.done = true;
                step.truncated = true;
            }
            if step.done {
                self.episodes_completed[i] += 1;
                self.last_return[i] = self.episode_return[i];
                // Auto-reset: sticky state clears (the wrapper chain's
                // reset), the game redraws its episode RNG, bookkeeping
                // zeroes.
                self.last_action[i] = 0;
                self.game
                    .reset_slot(i, &mut self.grid[i * CELLS..(i + 1) * CELLS]);
                self.episode_return[i] = 0.0;
                self.episode_len[i] = 0;
            }
            steps.push(step);
        }

        // Pass 2: the vectorized frame-stack shift — one copy_within
        // over the stepped `[len, S, S, K]` sub-slab. Every channel
        // moves one slot toward "older"; the only positions that pick
        // up a neighbouring cell's value are the newest-channel ones,
        // and pass 3 rewrites exactly those.
        let a = start * CELLS * k;
        let b = (start + len) * CELLS * k;
        if k > 1 {
            self.stack.copy_within(a + 1..b, a);
        }

        // Pass 3: scatter the post-step frames into the newest channel;
        // done slots refill all K channels (stack reset on the next
        // episode's initial frame).
        let newly = &steps[steps.len() - len..];
        for (j, step) in newly.iter().enumerate() {
            let i = start + j;
            let frame = &self.grid[i * CELLS..(i + 1) * CELLS];
            let row = &mut self.stack[i * CELLS * k..(i + 1) * CELLS * k];
            if step.done {
                for (cell, &v) in frame.iter().enumerate() {
                    row[cell * k..(cell + 1) * k].fill(v);
                }
            } else {
                for (cell, &v) in frame.iter().enumerate() {
                    row[cell * k + k - 1] = v;
                }
            }
        }

        // Pass 4: hand the stepped sub-slab to the caller in one copy.
        obs_rows.copy_from_slice(&self.stack[a..b]);
    }

    fn total_steps(&self) -> u64 {
        self.total_steps.iter().sum()
    }

    fn episodes_completed(&self) -> u64 {
        self.episodes_completed.iter().sum()
    }

    fn last_return(&self, slot: usize) -> f32 {
        self.last_return[slot]
    }

    fn name(&self) -> &'static str {
        self.game.name()
    }
}

#[inline]
fn put(frame: &mut [f32], row: usize, col: usize, v: f32) {
    debug_assert!(row < GRID && col < GRID);
    frame[row * GRID + col] = v;
}

// ---------------------------------------------------------------------------
// Catch (SoA planes of `super::catch::Catch`)
// ---------------------------------------------------------------------------

pub struct CatchSoa {
    rng: Vec<Pcg32>,
    ball_row: Vec<usize>,
    ball_col: Vec<usize>,
    paddle_col: Vec<usize>,
}

impl CatchSoa {
    pub fn new(seeds: &[u64]) -> Self {
        Self {
            rng: seeds.iter().map(|&s| Pcg32::seeded(s)).collect(),
            ball_row: vec![0; seeds.len()],
            ball_col: vec![0; seeds.len()],
            paddle_col: vec![GRID / 2; seeds.len()],
        }
    }

    fn render_slot(&self, i: usize, frame: &mut [f32]) {
        frame.fill(0.0);
        put(frame, self.ball_row[i], self.ball_col[i], 1.0);
        put(frame, GRID - 1, self.paddle_col[i], 0.5);
    }
}

impl SoaGame for CatchSoa {
    fn name(&self) -> &'static str {
        "catch"
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_slot(&mut self, i: usize, frame: &mut [f32]) {
        self.ball_row[i] = 0;
        self.ball_col[i] = self.rng[i].index(GRID);
        self.paddle_col[i] = GRID / 2;
        self.render_slot(i, frame);
    }

    fn step_slot(&mut self, i: usize, action: usize, frame: &mut [f32]) -> Step {
        if self.ball_row[i] >= GRID - 1 {
            // Stepping a finished episode (caller should reset): no-op.
            return Step::terminal(0.0);
        }
        match action {
            1 => self.paddle_col[i] = self.paddle_col[i].saturating_sub(1),
            2 => self.paddle_col[i] = (self.paddle_col[i] + 1).min(GRID - 1),
            _ => {}
        }
        self.ball_row[i] += 1;
        let step = if self.ball_row[i] == GRID - 1 {
            if self.ball_col[i] == self.paddle_col[i] {
                Step::terminal(1.0)
            } else {
                Step::terminal(-1.0)
            }
        } else {
            Step::cont(0.0)
        };
        self.render_slot(i, frame);
        step
    }
}

// ---------------------------------------------------------------------------
// GridPong (SoA planes of `super::grid_pong::GridPong`)
// ---------------------------------------------------------------------------

const PONG_LIVES: u32 = 3;
const PONG_PADDLE_W: usize = 2;

pub struct GridPongSoa {
    rng: Vec<Pcg32>,
    ball_r: Vec<i32>,
    ball_c: Vec<i32>,
    vel_r: Vec<i32>,
    vel_c: Vec<i32>,
    paddle: Vec<usize>,
    lives: Vec<u32>,
}

impl GridPongSoa {
    pub fn new(seeds: &[u64]) -> Self {
        Self {
            rng: seeds.iter().map(|&s| Pcg32::seeded(s)).collect(),
            ball_r: vec![0; seeds.len()],
            ball_c: vec![0; seeds.len()],
            vel_r: vec![1; seeds.len()],
            vel_c: vec![1; seeds.len()],
            paddle: vec![GRID / 2; seeds.len()],
            lives: vec![PONG_LIVES; seeds.len()],
        }
    }

    fn serve_slot(&mut self, i: usize) {
        self.ball_r[i] = 1;
        self.ball_c[i] = 1 + self.rng[i].index(GRID - 2) as i32;
        self.vel_r[i] = 1;
        self.vel_c[i] = if self.rng[i].chance(0.5) { 1 } else { -1 };
    }

    fn render_slot(&self, i: usize, frame: &mut [f32]) {
        frame.fill(0.0);
        if self.ball_r[i] >= 0 {
            put(frame, self.ball_r[i] as usize, self.ball_c[i] as usize, 1.0);
        }
        for p in 0..PONG_PADDLE_W {
            put(frame, GRID - 1, (self.paddle[i] + p).min(GRID - 1), 0.5);
        }
        // Lives indicator in the top-left corner (dimmer).
        for l in 0..self.lives[i] as usize {
            put(frame, 0, l, 0.25_f32.max(frame[l]));
        }
    }

    fn paddle_covers(&self, i: usize, col: i32) -> bool {
        col >= self.paddle[i] as i32 && col < (self.paddle[i] + PONG_PADDLE_W) as i32
    }
}

impl SoaGame for GridPongSoa {
    fn name(&self) -> &'static str {
        "grid_pong"
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_slot(&mut self, i: usize, frame: &mut [f32]) {
        self.lives[i] = PONG_LIVES;
        self.paddle[i] = GRID / 2;
        self.serve_slot(i);
        self.render_slot(i, frame);
    }

    fn step_slot(&mut self, i: usize, action: usize, frame: &mut [f32]) -> Step {
        match action {
            1 => self.paddle[i] = self.paddle[i].saturating_sub(1),
            2 => self.paddle[i] = (self.paddle[i] + 1).min(GRID - PONG_PADDLE_W),
            _ => {}
        }

        // Ball dynamics with wall bounces.
        let mut nr = self.ball_r[i] + self.vel_r[i];
        let mut nc = self.ball_c[i] + self.vel_c[i];
        if nc < 0 {
            nc = 1;
            self.vel_c[i] = 1;
        } else if nc >= GRID as i32 {
            nc = GRID as i32 - 2;
            self.vel_c[i] = -1;
        }
        if nr < 0 {
            nr = 1;
            self.vel_r[i] = 1;
        }

        let mut reward = 0.0;
        let mut done = false;
        if nr >= (GRID - 1) as i32 {
            // Reached the paddle row.
            if self.paddle_covers(i, nc) {
                reward = 1.0;
                self.vel_r[i] = -1;
                nr = (GRID - 2) as i32;
            } else {
                reward = -1.0;
                self.lives[i] -= 1;
                if self.lives[i] == 0 {
                    done = true;
                } else {
                    self.serve_slot(i);
                    self.render_slot(i, frame);
                    return Step::cont(reward);
                }
            }
        }
        self.ball_r[i] = nr;
        self.ball_c[i] = nc;
        self.render_slot(i, frame);
        Step {
            reward,
            done,
            truncated: false,
        }
    }
}

// ---------------------------------------------------------------------------
// Breakout (SoA planes of `super::breakout::Breakout`)
// ---------------------------------------------------------------------------

const BK_LIVES: u32 = 3;
const BK_PADDLE_W: usize = 3;
const BK_BRICK_ROWS: usize = 3;
const BK_BRICKS: usize = BK_BRICK_ROWS * GRID;

pub struct BreakoutSoa {
    rng: Vec<Pcg32>,
    /// `[E, BRICK_ROWS, GRID]` brick plane, flattened.
    bricks: Vec<bool>,
    ball_r: Vec<i32>,
    ball_c: Vec<i32>,
    vel_r: Vec<i32>,
    vel_c: Vec<i32>,
    ball_live: Vec<bool>,
    paddle: Vec<usize>,
    lives: Vec<u32>,
}

impl BreakoutSoa {
    pub fn new(seeds: &[u64]) -> Self {
        Self {
            rng: seeds.iter().map(|&s| Pcg32::seeded(s)).collect(),
            bricks: vec![true; seeds.len() * BK_BRICKS],
            ball_r: vec![0; seeds.len()],
            ball_c: vec![0; seeds.len()],
            vel_r: vec![0; seeds.len()],
            vel_c: vec![0; seeds.len()],
            ball_live: vec![false; seeds.len()],
            paddle: vec![GRID / 2 - 1; seeds.len()],
            lives: vec![BK_LIVES; seeds.len()],
        }
    }

    fn serve_slot(&mut self, i: usize) {
        self.ball_r[i] = (BK_BRICK_ROWS + 2) as i32;
        self.ball_c[i] = self.rng[i].index(GRID) as i32;
        self.vel_r[i] = 1;
        self.vel_c[i] = if self.rng[i].chance(0.5) { 1 } else { -1 };
        self.ball_live[i] = true;
    }

    fn bricks_left(&self, i: usize) -> usize {
        self.bricks[i * BK_BRICKS..(i + 1) * BK_BRICKS]
            .iter()
            .filter(|&&b| b)
            .count()
    }

    fn render_slot(&self, i: usize, frame: &mut [f32]) {
        frame.fill(0.0);
        let bricks = &self.bricks[i * BK_BRICKS..(i + 1) * BK_BRICKS];
        for r in 0..BK_BRICK_ROWS {
            for c in 0..GRID {
                if bricks[r * GRID + c] {
                    put(frame, r + 1, c, 0.75);
                }
            }
        }
        if self.ball_live[i] {
            put(frame, self.ball_r[i] as usize, self.ball_c[i] as usize, 1.0);
        }
        for p in 0..BK_PADDLE_W {
            put(frame, GRID - 1, (self.paddle[i] + p).min(GRID - 1), 0.5);
        }
    }

    fn paddle_covers(&self, i: usize, col: i32) -> bool {
        col >= self.paddle[i] as i32 && col < (self.paddle[i] + BK_PADDLE_W) as i32
    }
}

impl SoaGame for BreakoutSoa {
    fn name(&self) -> &'static str {
        "breakout"
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_slot(&mut self, i: usize, frame: &mut [f32]) {
        self.bricks[i * BK_BRICKS..(i + 1) * BK_BRICKS].fill(true);
        self.lives[i] = BK_LIVES;
        self.paddle[i] = GRID / 2 - 1;
        self.ball_live[i] = false;
        self.serve_slot(i);
        self.render_slot(i, frame);
    }

    fn step_slot(&mut self, i: usize, action: usize, frame: &mut [f32]) -> Step {
        if self.lives[i] == 0 || self.bricks_left(i) == 0 {
            // Stepping a finished episode (caller should reset): no-op.
            return Step::terminal(0.0);
        }
        match action {
            1 => self.paddle[i] = self.paddle[i].saturating_sub(1),
            2 => self.paddle[i] = (self.paddle[i] + 1).min(GRID - BK_PADDLE_W),
            3 if !self.ball_live[i] => self.serve_slot(i),
            _ => {}
        }
        if !self.ball_live[i] {
            self.render_slot(i, frame);
            return Step::cont(0.0);
        }

        let mut reward = 0.0;
        // Move with wall bounces.
        let mut nr = self.ball_r[i] + self.vel_r[i];
        let mut nc = self.ball_c[i] + self.vel_c[i];
        if nc < 0 {
            nc = 1;
            self.vel_c[i] = 1;
        } else if nc >= GRID as i32 {
            nc = GRID as i32 - 2;
            self.vel_c[i] = -1;
        }
        if nr <= 0 {
            nr = 1;
            self.vel_r[i] = 1;
        }

        // Brick collision.
        if (1..=BK_BRICK_ROWS as i32).contains(&nr) {
            let idx = i * BK_BRICKS + (nr - 1) as usize * GRID + nc as usize;
            if self.bricks[idx] {
                self.bricks[idx] = false;
                reward += 1.0;
                self.vel_r[i] = -self.vel_r[i];
                nr = self.ball_r[i]; // bounce back the way it came
            }
        }

        let mut done = false;
        if nr >= (GRID - 1) as i32 {
            if self.paddle_covers(i, nc) {
                self.vel_r[i] = -1;
                nr = (GRID - 2) as i32;
                // English: paddle edge redirects the ball.
                if nc == self.paddle[i] as i32 {
                    self.vel_c[i] = -1;
                } else if nc == (self.paddle[i] + BK_PADDLE_W - 1) as i32 {
                    self.vel_c[i] = 1;
                }
            } else {
                reward -= 1.0;
                self.lives[i] -= 1;
                self.ball_live[i] = false;
                if self.lives[i] == 0 {
                    done = true;
                }
            }
        }
        if self.ball_live[i] {
            self.ball_r[i] = nr;
            self.ball_c[i] = nc;
        }
        if self.bricks_left(i) == 0 {
            done = true;
        }
        self.render_slot(i, frame);
        Step {
            reward,
            done,
            truncated: false,
        }
    }
}

// ---------------------------------------------------------------------------
// NavMaze (SoA planes of `super::nav_maze::NavMaze`)
// ---------------------------------------------------------------------------

const NM_STEP_PENALTY: f32 = -0.01;
const NM_MAX_STEPS: usize = 400;
/// Half-resolution lattice side (odd cells 1, 3, .., GRID-1).
const NM_LATTICE: usize = GRID / 2;

pub struct NavMazeSoa {
    rng: Vec<Pcg32>,
    /// `[E, GRID, GRID]` wall plane, flattened.
    walls: Vec<bool>,
    agent: Vec<(usize, usize)>,
    goal: Vec<(usize, usize)>,
    steps: Vec<usize>,
}

impl NavMazeSoa {
    pub fn new(seeds: &[u64]) -> Self {
        let e = seeds.len();
        let mut m = Self {
            rng: seeds.iter().map(|&s| Pcg32::seeded(s)).collect(),
            walls: vec![false; e * CELLS],
            agent: vec![(0, 0); e],
            goal: vec![(GRID - 1, GRID - 1); e],
            steps: vec![0; e],
        };
        // The per-slot env generates a maze at construction (drawing
        // from its RNG) and again on every reset; replicate the
        // construction-time draw so the streams line up.
        for i in 0..e {
            m.generate_slot(i);
        }
        m
    }

    /// Recursive-backtracker over odd cells, identical draw order to
    /// the per-slot env but with fixed-size scratch (no allocation):
    /// the DFS stack and visited set live on the call stack.
    fn generate_slot(&mut self, i: usize) {
        let walls = &mut self.walls[i * CELLS..(i + 1) * CELLS];
        walls.fill(true);
        let cells = |j: usize| 2 * j + 1;
        let n = NM_LATTICE;
        let mut visited = [[false; NM_LATTICE]; NM_LATTICE];
        let mut stack = [(0usize, 0usize); NM_LATTICE * NM_LATTICE];
        let mut sp = 1usize;
        stack[0] = (0, 0);
        visited[0][0] = true;
        walls[cells(0) * GRID + cells(0)] = false;
        while sp > 0 {
            let (r, c) = stack[sp - 1];
            let mut neighbours = [(0usize, 0usize); 4];
            let mut count = 0;
            if r > 0 && !visited[r - 1][c] {
                neighbours[count] = (r - 1, c);
                count += 1;
            }
            if r + 1 < n && !visited[r + 1][c] {
                neighbours[count] = (r + 1, c);
                count += 1;
            }
            if c > 0 && !visited[r][c - 1] {
                neighbours[count] = (r, c - 1);
                count += 1;
            }
            if c + 1 < n && !visited[r][c + 1] {
                neighbours[count] = (r, c + 1);
                count += 1;
            }
            if count == 0 {
                sp -= 1;
                continue;
            }
            let (nr, nc) = neighbours[self.rng[i].index(count)];
            visited[nr][nc] = true;
            // Carve destination and the wall between.
            walls[cells(nr) * GRID + cells(nc)] = false;
            let wall_r = (cells(r) + cells(nr)) / 2;
            let wall_c = (cells(c) + cells(nc)) / 2;
            walls[wall_r * GRID + wall_c] = false;
            stack[sp] = (nr, nc);
            sp += 1;
        }
        // Agent at the first carved cell, goal at the last.
        self.agent[i] = (cells(0), cells(0));
        self.goal[i] = (cells(n - 1), cells(n - 1));
        self.steps[i] = 0;
    }

    fn render_slot(&self, i: usize, frame: &mut [f32]) {
        let walls = &self.walls[i * CELLS..(i + 1) * CELLS];
        for (out, &w) in frame.iter_mut().zip(walls) {
            *out = if w { 0.25 } else { 0.0 };
        }
        put(frame, self.goal[i].0, self.goal[i].1, 0.75);
        put(frame, self.agent[i].0, self.agent[i].1, 1.0);
    }
}

impl SoaGame for NavMazeSoa {
    fn name(&self) -> &'static str {
        "nav_maze"
    }

    fn num_envs(&self) -> usize {
        self.rng.len()
    }

    fn reset_slot(&mut self, i: usize, frame: &mut [f32]) {
        self.generate_slot(i);
        self.render_slot(i, frame);
    }

    fn step_slot(&mut self, i: usize, action: usize, frame: &mut [f32]) -> Step {
        let (r, c) = self.agent[i];
        let (nr, nc) = match action {
            0 => (r.saturating_sub(1), c),
            1 => ((r + 1).min(GRID - 1), c),
            2 => (r, c.saturating_sub(1)),
            3 => (r, (c + 1).min(GRID - 1)),
            _ => (r, c),
        };
        if !self.walls[i * CELLS + nr * GRID + nc] {
            self.agent[i] = (nr, nc);
        }
        self.steps[i] += 1;
        let step = if self.agent[i] == self.goal[i] {
            Step::terminal(1.0)
        } else if self.steps[i] >= NM_MAX_STEPS {
            Step {
                reward: NM_STEP_PENALTY,
                done: true,
                truncated: true,
            }
        } else {
            Step::cont(NM_STEP_PENALTY)
        };
        self.render_slot(i, frame);
        step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::wrappers::Wrapped;

    fn cfg(name: &str, k: usize, sticky: f64, max_len: usize, seed: u64) -> EnvConfig {
        EnvConfig {
            name: name.into(),
            frame_stack: k,
            sticky_action_prob: sticky,
            max_episode_len: max_len,
            step_cost_us: 0,
            seed,
            batch_native: true,
        }
    }

    /// Drive the SoA engine and E independent `Wrapped` replicas with
    /// the same seed layout; everything observable must be identical.
    fn assert_matches_wrapped(name: &str, e: usize, k: usize, sticky: f64, steps: usize) {
        let c = cfg(name, k, sticky, 37, 11);
        let base = 5u64;
        let mut soa = make_batch_env(&c, e, base).unwrap();
        let mut solos: Vec<Wrapped> = (0..e)
            .map(|i| Wrapped::from_config(&c, base + i as u64).unwrap())
            .collect();

        let obs_len = soa.obs_len();
        assert_eq!(obs_len, solos[0].obs_len());
        let mut obs_b = vec![0.0f32; e * obs_len];
        let mut obs_s = vec![vec![0.0f32; obs_len]; e];
        soa.reset_all(&mut obs_b);
        for (s, o) in solos.iter_mut().zip(&mut obs_s) {
            s.reset(o);
        }
        for (i, o) in obs_s.iter().enumerate() {
            assert_eq!(&obs_b[i * obs_len..(i + 1) * obs_len], &o[..], "reset obs {i}");
        }

        let mut step_buf = Vec::with_capacity(e);
        for t in 0..steps {
            let actions: Vec<usize> = (0..e).map(|i| (t * 7 + i * 3) % 4).collect();
            step_buf.clear();
            soa.step_all(&actions, &mut obs_b, &mut step_buf);
            for i in 0..e {
                let ss = solos[i].step(actions[i], &mut obs_s[i]);
                assert_eq!(step_buf[i], ss, "{name} slot {i} step {t}");
                assert_eq!(
                    &obs_b[i * obs_len..(i + 1) * obs_len],
                    &obs_s[i][..],
                    "{name} slot {i} obs at step {t}"
                );
            }
        }
        assert_eq!(
            soa.total_steps(),
            solos.iter().map(|s| s.total_steps).sum::<u64>()
        );
        assert_eq!(
            soa.episodes_completed(),
            solos.iter().map(|s| s.episodes_completed).sum::<u64>()
        );
        for (i, s) in solos.iter().enumerate() {
            assert_eq!(soa.last_return(i), s.last_return, "{name} last_return {i}");
        }
    }

    #[test]
    fn catch_soa_matches_wrapped() {
        assert_matches_wrapped("catch", 3, 4, 0.25, 200);
    }

    #[test]
    fn grid_pong_soa_matches_wrapped() {
        assert_matches_wrapped("grid_pong", 2, 3, 0.3, 250);
    }

    #[test]
    fn breakout_soa_matches_wrapped() {
        assert_matches_wrapped("breakout", 2, 4, 0.25, 300);
    }

    #[test]
    fn nav_maze_soa_matches_wrapped() {
        assert_matches_wrapped("nav_maze", 2, 2, 0.2, 150);
    }

    #[test]
    fn frame_stack_one_matches_wrapped() {
        // k = 1 skips the vectorized shift entirely (every position is
        // the newest channel); the equivalence must still hold.
        assert_matches_wrapped("catch", 2, 1, 0.25, 120);
    }

    #[test]
    fn step_range_matches_step_all_per_group() {
        let c = cfg("grid_pong", 4, 0.25, 100, 9);
        let e = 5;
        let mut whole = make_batch_env(&c, e, 2).unwrap();
        let mut split = make_batch_env(&c, e, 2).unwrap();
        let n = whole.obs_len();
        let mut obs_w = vec![0.0f32; e * n];
        let mut obs_s = vec![0.0f32; e * n];
        whole.reset_all(&mut obs_w);
        split.reset_all(&mut obs_s);
        let mut steps_w = Vec::with_capacity(e);
        let mut steps_s = Vec::with_capacity(e);
        for t in 0..100usize {
            let actions: Vec<usize> = (0..e).map(|i| (t + i) % 4).collect();
            steps_w.clear();
            whole.step_all(&actions, &mut obs_w, &mut steps_w);
            steps_s.clear();
            for (start, len) in [(0usize, 3usize), (3, 2)] {
                split.step_range(
                    start,
                    &actions[start..start + len],
                    &mut obs_s[start * n..(start + len) * n],
                    &mut steps_s,
                );
            }
            assert_eq!(steps_w, steps_s, "step {t}");
            assert_eq!(obs_w, obs_s, "obs at step {t}");
        }
        assert_eq!(whole.total_steps(), split.total_steps());
    }

    #[test]
    fn factory_rejects_unknown_env() {
        let c = cfg("pong_3d", 4, 0.0, 10, 0);
        let err = make_batch_env(&c, 1, 1).unwrap_err().to_string();
        assert!(err.contains("unknown env `pong_3d`"), "got: {err}");
    }
}
