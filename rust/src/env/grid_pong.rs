//! GridPong: single-player Pong against a wall (serve → rally → miss).
//!
//! The ball bounces off the top wall and both side walls; the player's
//! 2-cell paddle guards the bottom row. Each paddle contact scores +1 and
//! speeds nothing up (constant dynamics keep the timing model clean);
//! a miss costs one of three lives and -1 reward. Episode ends when all
//! lives are gone.
//!
//! Actions: 0 = noop, 1 = left, 2 = right, 3 = noop.

use super::{new_frame, put, Environment, Frame, Step, GRID};
use crate::util::prng::Pcg32;

const LIVES: u32 = 3;
const PADDLE_W: usize = 2;

pub struct GridPong {
    rng: Pcg32,
    ball_r: i32,
    ball_c: i32,
    vel_r: i32,
    vel_c: i32,
    paddle: usize, // left cell of the paddle
    lives: u32,
}

impl GridPong {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::seeded(seed),
            ball_r: 0,
            ball_c: 0,
            vel_r: 1,
            vel_c: 1,
            paddle: GRID / 2,
            lives: LIVES,
        }
    }

    fn serve(&mut self) {
        self.ball_r = 1;
        self.ball_c = 1 + self.rng.index(GRID - 2) as i32;
        self.vel_r = 1;
        self.vel_c = if self.rng.chance(0.5) { 1 } else { -1 };
    }

    fn render(&self, frame: &mut Frame) {
        frame.iter_mut().for_each(|v| *v = 0.0);
        if self.ball_r >= 0 {
            put(frame, self.ball_r as usize, self.ball_c as usize, 1.0);
        }
        for i in 0..PADDLE_W {
            put(frame, GRID - 1, (self.paddle + i).min(GRID - 1), 0.5);
        }
        // Lives indicator in the top-left corner (dimmer).
        for l in 0..self.lives as usize {
            put(frame, 0, l, 0.25_f32.max(frame[l]));
        }
    }

    fn paddle_covers(&self, col: i32) -> bool {
        col >= self.paddle as i32 && col < (self.paddle + PADDLE_W) as i32
    }
}

impl Environment for GridPong {
    fn reset(&mut self, frame: &mut Frame) {
        self.lives = LIVES;
        self.paddle = GRID / 2;
        self.serve();
        if frame.len() != GRID * GRID {
            *frame = new_frame();
        }
        self.render(frame);
    }

    fn step(&mut self, action: usize, frame: &mut Frame) -> Step {
        match action {
            1 => self.paddle = self.paddle.saturating_sub(1),
            2 => self.paddle = (self.paddle + 1).min(GRID - PADDLE_W),
            _ => {}
        }

        // Ball dynamics with wall bounces.
        let mut nr = self.ball_r + self.vel_r;
        let mut nc = self.ball_c + self.vel_c;
        if nc < 0 {
            nc = 1;
            self.vel_c = 1;
        } else if nc >= GRID as i32 {
            nc = GRID as i32 - 2;
            self.vel_c = -1;
        }
        if nr < 0 {
            nr = 1;
            self.vel_r = 1;
        }

        let mut reward = 0.0;
        let mut done = false;
        if nr >= (GRID - 1) as i32 {
            // Reached the paddle row.
            if self.paddle_covers(nc) {
                reward = 1.0;
                self.vel_r = -1;
                nr = (GRID - 2) as i32;
            } else {
                reward = -1.0;
                self.lives -= 1;
                if self.lives == 0 {
                    done = true;
                } else {
                    self.serve();
                    self.render(frame);
                    return Step::cont(reward);
                }
            }
        }
        self.ball_r = nr;
        self.ball_c = nc;
        self.render(frame);
        Step {
            reward,
            done,
            truncated: false,
        }
    }

    fn name(&self) -> &'static str {
        "grid_pong"
    }

    fn real_actions(&self) -> usize {
        3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::testutil::*;

    #[test]
    fn static_paddle_loses_all_lives() {
        let mut env = GridPong::new(3);
        let mut frame = new_frame();
        env.reset(&mut frame);
        let mut steps = 0;
        let mut misses = 0;
        loop {
            let s = env.step(0, &mut frame);
            steps += 1;
            if s.reward < 0.0 {
                misses += 1;
            }
            assert_frame_valid(&frame);
            if s.done {
                break;
            }
            assert!(steps < 10_000, "episode must terminate");
        }
        assert_eq!(misses, LIVES);
    }

    #[test]
    fn tracking_player_rallies() {
        // Follow the ball column with the paddle: should score many hits
        // before any plausible miss.
        let mut env = GridPong::new(9);
        let mut frame = new_frame();
        env.reset(&mut frame);
        let mut hits = 0;
        let mut prev_bc: Option<i32> = None;
        for _ in 0..600 {
            let ball = frame.iter().position(|&v| v == 1.0);
            let action = match ball {
                Some(i) => {
                    let bc = (i % GRID) as i32;
                    // Anticipate the diagonal motion: aim at bc + velocity.
                    let vel = prev_bc.map(|p| (bc - p).signum()).unwrap_or(0);
                    prev_bc = Some(bc);
                    let target = (bc + vel).clamp(0, GRID as i32 - 1);
                    let pc = frame
                        .iter()
                        .rposition(|&v| v == 0.5)
                        .map(|p| (p % GRID) as i32 - 1)
                        .unwrap_or(target);
                    if target < pc {
                        1
                    } else if target > pc + 1 {
                        2
                    } else {
                        0
                    }
                }
                None => 0,
            };
            let s = env.step(action, &mut frame);
            if s.reward > 0.0 {
                hits += 1;
            }
            if s.done {
                env.reset(&mut frame);
            }
        }
        assert!(hits > 20, "tracking play should rally (hits = {hits})");
    }

    #[test]
    fn ball_stays_in_bounds() {
        let mut env = GridPong::new(1);
        let mut frame = new_frame();
        env.reset(&mut frame);
        for i in 0..2_000 {
            let a = i % 3;
            let s = env.step(a, &mut frame);
            assert!((0..GRID as i32).contains(&env.ball_c), "col {}", env.ball_c);
            assert!(env.ball_r >= 0 && env.ball_r < GRID as i32);
            if s.done {
                env.reset(&mut frame);
            }
        }
    }

    #[test]
    fn reward_only_at_paddle_row_events() {
        let mut env = GridPong::new(5);
        let (total, episodes) = drive(&mut env, 2, 3_000);
        assert!(episodes > 0);
        assert!(total.abs() <= 3_000.0);
    }
}
