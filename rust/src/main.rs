//! `rlarch` — the launcher. Subcommands:
//!
//! ```text
//! rlarch train     [--config cfg.toml] [--actors N] [--steps K] ...
//!                  run the real SEED coordinator on the AOT artifacts
//! rlarch serve     [--listen uds:/run/fleet.sock] [--steps K] ...
//!                  fleet coordinator: learner + batcher here, actors remote
//! rlarch actor     --connect uds:/run/fleet.sock [--id B] [--local-actors N]
//!                  fleet worker: actor threads over a remote coordinator
//! rlarch ctl       --connect uds:/run/ctl.sock --cmd "reload /ckpt"
//!                  drive a serving coordinator's control socket
//! rlarch sweep     [--actors 4,8,...,256]      Fig. 3 on the simulator
//! rlarch smsweep   [--sms 80,60,...,2]         Fig. 4 on the simulator
//! rlarch breakdown                              Fig. 2 on the simulator
//! rlarch info                                   artifact + config summary
//! ```
//!
//! Python never runs here: `train` loads `artifacts/*.hlo.txt` through
//! PJRT; the simulator subcommands consume `artifacts/kernel_trace.json`.

use rlarch::cli::Cli;
use rlarch::config::{FaultsConfig, InferenceMode, SystemConfig};
use rlarch::coordinator;
use rlarch::metrics::Registry;
use rlarch::report::figure::{ascii_bar, Table};
use rlarch::runtime::{Backend, MockModel, ModelDims, XlaServer};
use rlarch::simarch::{
    default_system, synthetic_paper_train_trace, synthetic_paper_trace, GpuModel,
    TraceSet,
};
use rlarch::telemetry;
use rlarch::vecenv::VecEnv;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: &[String] = if args.is_empty() { &[] } else { &args[1..] };
    let code = match sub {
        "train" => cmd_train(rest),
        "serve" => cmd_serve(rest),
        "actor" => cmd_actor(rest),
        "ctl" => cmd_ctl(rest),
        "sweep" => cmd_sweep(rest),
        "smsweep" => cmd_smsweep(rest),
        "breakdown" => cmd_breakdown(rest),
        "info" => cmd_info(rest),
        _ => {
            eprintln!(
                "usage: rlarch <train|serve|actor|ctl|sweep|smsweep|breakdown|info> [flags]\n\
                 run `rlarch <subcommand> --help` for flags"
            );
            2
        }
    };
    std::process::exit(code);
}

fn load_config(parsed: &rlarch::cli::Parsed) -> anyhow::Result<SystemConfig> {
    let mut cfg = match parsed.get("config") {
        "" => SystemConfig::default(),
        path => rlarch::config::load(Path::new(path))
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    };
    if let Ok(n) = parsed.get_usize("actors") {
        if n > 0 {
            cfg.actors.num_actors = n;
        }
    }
    if let Ok(e) = parsed.get_usize("envs-per-actor") {
        if e > 0 {
            cfg.actors.envs_per_actor = e;
        }
    }
    if let Ok(d) = parsed.get_usize("pipeline-depth") {
        if d > 0 {
            cfg.actors.pipeline_depth = d;
        }
    }
    if let Ok(s) = parsed.get_usize("replay-shards") {
        if s > 0 {
            cfg.replay.shards = s;
        }
    }
    if let Ok(d) = parsed.get_usize("prefetch-depth") {
        if d > 0 {
            cfg.learner.prefetch_depth = d;
        }
    }
    if let Ok(b) = parsed.get_usize("insert-batch") {
        if b > 0 {
            cfg.replay.insert_batch = b;
        }
    }
    if let Ok(k) = parsed.get_usize("steps") {
        if k > 0 {
            cfg.learner.max_steps = k;
        }
    }
    if let Ok(mb) = parsed.get_usize("max-batch") {
        if mb > 0 {
            // Keep the bucket ladder valid: drop buckets above the new
            // cap and make the cap the largest compiled shape.
            cfg.batcher.max_batch = mb;
            cfg.batcher.batch_sizes.retain(|&b| b < mb);
            cfg.batcher.batch_sizes.push(mb);
        }
    }
    if !parsed.get("batch-sizes").is_empty() {
        // An explicit ladder wins over --max-batch: the largest bucket
        // is the cap (validation requires it).
        let sizes = parsed.get_usize_list("batch-sizes")?;
        if let Some(&last) = sizes.last() {
            cfg.batcher.max_batch = last;
        }
        cfg.batcher.batch_sizes = sizes;
    }
    if !parsed.get("timeout-us").is_empty() {
        // Empty = unset: 0 is a meaningful value here (flush every
        // submission immediately), so it cannot double as the sentinel.
        cfg.batcher.timeout_us = parsed.get_u64("timeout-us")?;
    }
    match parsed.get("env") {
        "" => {}
        e => cfg.env.name = e.to_string(),
    }
    if parsed.get("mode") == "local" {
        cfg.mode = InferenceMode::Local;
    }
    if parsed.get_switch("batch-native") {
        cfg.env.batch_native = true;
    }
    // Telemetry knobs (train-only flags; absent on other subcommands the
    // getters fall through to the config/defaults).
    match parsed.get("trace-out") {
        "" => {}
        p => cfg.telemetry.trace_out = p.to_string(),
    }
    match parsed.get("metrics-out") {
        "" => {}
        p => cfg.telemetry.metrics_out = p.to_string(),
    }
    if let Ok(ms) = parsed.get_usize("snapshot-interval-ms") {
        if ms > 0 {
            cfg.telemetry.snapshot_interval_ms = ms;
        }
    }
    // CLI overrides can invalidate a config that parsed cleanly (e.g.
    // --replay-shards that does not divide the capacity): re-validate
    // here so that fails before the runtime spawns.
    cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(cfg)
}

fn cmd_train(args: &[String]) -> i32 {
    let cli = Cli::new("rlarch train", "run the SEED coordinator (real PJRT)")
        .flag("config", "", "TOML config path (default: built-in)")
        .flag("actors", "0", "override actor count")
        .flag("envs-per-actor", "0", "override envs per actor thread (vecenv)")
        .flag(
            "pipeline-depth",
            "0",
            "override actor pipeline depth (1 = serialized)",
        )
        .flag(
            "replay-shards",
            "0",
            "override replay shard count (1 = single-mutex buffer)",
        )
        .flag(
            "prefetch-depth",
            "0",
            "override learner prefetch depth (1 = serialized)",
        )
        .flag(
            "insert-batch",
            "0",
            "override replay ingest batch (sequences per flush; 1 = unbatched)",
        )
        .flag("steps", "0", "override learner steps")
        .flag(
            "max-batch",
            "0",
            "override batcher row cap (rescales the bucket ladder to fit)",
        )
        .flag(
            "batch-sizes",
            "",
            "override AOT launch-bucket ladder, ascending (largest = max batch); \
             a single bucket equal to the cap pads every partial flush to it",
        )
        .flag(
            "timeout-us",
            "",
            "override batcher flush timeout in microseconds (0 = flush \
             every submission immediately)",
        )
        .flag("env", "", "override env (grid_pong|breakout|catch|nav_maze)")
        .switch(
            "batch-native",
            "step env slots through the batch-native SoA engine (bit-for-bit \
             equivalent to the per-slot path; cost only)",
        )
        .flag("mode", "central", "central (SEED) or local (IMPALA-style)")
        .flag(
            "backend",
            "xla",
            "xla (AOT artifacts via PJRT) or mock (deterministic in-process \
             model; no artifacts needed — CI smoke)",
        )
        .flag(
            "trace-out",
            "",
            "write hot-path spans as Chrome trace-event JSON here (enables \
             span tracing; open in chrome://tracing or Perfetto)",
        )
        .flag(
            "metrics-out",
            "",
            "write the sampled metrics time-series as JSONL here (enables \
             the background registry sampler)",
        )
        .flag(
            "snapshot-interval-ms",
            "0",
            "override telemetry sampler period (default from config: 200)",
        )
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<()> {
        let cfg = load_config(&parsed)?;
        let dir = Path::new(parsed.get("artifacts"));
        // The server handle must outlive the run (dropping it tears the
        // PJRT process down), hence the keepalive outside the match.
        let mut _server = None;
        let backend = match parsed.get("backend") {
            "xla" => {
                let (srv, handle) = XlaServer::spawn(dir, None, true)?;
                _server = Some(srv);
                Backend::Xla(handle)
            }
            "mock" => Backend::Mock(Arc::new(MockModel::new(mock_dims(&cfg)?, cfg.seed))),
            other => anyhow::bail!("unknown --backend `{other}` (xla|mock)"),
        };
        let metrics = Registry::new();
        println!(
            "rlarch train: env={} batch_native={} actors={} envs/actor={} depth={} steps={} \
             shards={} prefetch={} ingest={} pool={} buckets={:?} mode={:?}",
            cfg.env.name,
            cfg.env.batch_native,
            cfg.actors.num_actors,
            cfg.actors.envs_per_actor,
            cfg.actors.pipeline_depth,
            cfg.learner.max_steps,
            cfg.replay.shards,
            cfg.learner.prefetch_depth,
            cfg.replay.insert_batch,
            cfg.replay.pool,
            cfg.batcher.batch_sizes,
            cfg.mode
        );
        let report = coordinator::run(&cfg, backend, metrics.clone())?;
        if let Some(e) = &report.first_error {
            anyhow::bail!(
                "run failed ({} batcher error(s)): {e}",
                report.batcher_errors
            );
        }
        println!(
            "done in {:.1}s: {} env steps ({:.0}/s), {} episodes, mean return {:.2}",
            report.elapsed_seconds,
            report.env_steps,
            report.env_steps_per_sec,
            report.episodes,
            report.mean_return
        );
        println!(
            "learner: {} steps, loss {:.4} -> {:.4}, {} target syncs; \
             batcher occupancy {:.1}",
            report.learner.steps,
            report.learner.first_loss,
            report.learner.final_loss,
            report.learner.target_syncs,
            report.mean_batch_occupancy
        );
        // Self-validate the telemetry outputs: a run that claims to have
        // written a trace/time-series must have written parseable ones
        // (the CI smoke relies on this failing loudly).
        if cfg.telemetry.trace_enabled() {
            let events = telemetry::validate_trace_file(&cfg.telemetry.trace_out)?;
            println!(
                "trace: {events} span events -> {}",
                cfg.telemetry.trace_out
            );
        }
        if cfg.telemetry.sampler_enabled() {
            let samples =
                telemetry::validate_metrics_file(&cfg.telemetry.metrics_out)?;
            println!(
                "metrics: {samples} samples -> {}",
                cfg.telemetry.metrics_out
            );
        }
        // Fig. 2-style phase attribution: measured busy-share per phase
        // vs the architectural model's steady-state prediction (kernel
        // traces when present, the synthetic paper-scale traces
        // otherwise), with the drift exported as `telemetry.model_drift`.
        let model = load_traces(parsed.get("artifacts")).unwrap_or_else(|_| {
            default_system(
                synthetic_paper_trace(1, 1, 64),
                synthetic_paper_train_trace(2, 80, 16),
            )
        });
        if let Some(table) = telemetry::attribution_report(
            &metrics,
            Some(&model),
            cfg.actors.num_actors,
        ) {
            println!("\nphase attribution (measured vs model):\n{table}");
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Mock-backend model dims: probe one env instance for the observation
/// shape; the rest follow the learner config. `serve` and `actor`
/// processes sharing a config derive identical dims from this — the
/// transport handshake rejects any disagreement.
fn mock_dims(cfg: &SystemConfig) -> anyhow::Result<ModelDims> {
    let probe =
        VecEnv::from_config(&cfg.env, 1, cfg.seed).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(ModelDims {
        obs_len: probe.obs_len(),
        hidden: 16,
        num_actions: rlarch::env::NUM_ACTIONS,
        seq_len: cfg.learner.seq_len(),
        train_batch: cfg.learner.train_batch,
    })
}

fn cmd_serve(args: &[String]) -> i32 {
    let cli = Cli::new(
        "rlarch serve",
        "fleet coordinator: learner + batcher + replay here, actors connect remotely",
    )
    .flag("config", "", "TOML config path (default: built-in)")
    .flag(
        "listen",
        "",
        "override fleet.listen (tcp:host:port or uds:/path)",
    )
    .flag(
        "actors",
        "0",
        "override the FLEET-WIDE actor total (workers carve id slices from it)",
    )
    .flag("steps", "0", "override learner steps")
    .flag("replay-shards", "0", "override replay shard count")
    .flag("prefetch-depth", "0", "override learner prefetch depth")
    .flag(
        "insert-batch",
        "0",
        "override replay ingest batch (also the wire-ingest commit batch)",
    )
    .flag(
        "max-inflight-rows",
        "0",
        "override fleet.max_inflight_rows (per-connection shed budget)",
    )
    .flag(
        "liveness-ms",
        "",
        "override fleet.liveness_timeout_ms (reap silent connections; 0 = off)",
    )
    .flag(
        "checkpoint-dir",
        "",
        "override fleet.checkpoint_dir (snapshot learner state here; resumes if present)",
    )
    .flag(
        "checkpoint-every",
        "0",
        "override fleet.checkpoint_every (trained batches between snapshots)",
    )
    .flag(
        "control",
        "",
        "override serve.control: bind the line-delimited control socket here \
         (health/ready/stats/reload/shutdown via `rlarch ctl`)",
    )
    .flag(
        "drain-timeout-ms",
        "0",
        "override fleet.drain_timeout_ms (bound on reload/shutdown drains)",
    )
    .flag(
        "faults",
        "",
        "fault plan spec, e.g. seed=7,corrupt_rate=0.02,stall_rate=0.01 ([faults] keys)",
    )
    .flag("env", "", "override env (grid_pong|breakout|catch|nav_maze)")
    .flag(
        "backend",
        "xla",
        "xla (AOT artifacts via PJRT) or mock (deterministic in-process model)",
    )
    .flag("artifacts", "artifacts", "artifact directory");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<()> {
        let mut cfg = load_config(&parsed)?;
        match parsed.get("listen") {
            "" => {}
            a => cfg.fleet.listen = a.to_string(),
        }
        if let Ok(n) = parsed.get_usize("max-inflight-rows") {
            if n > 0 {
                cfg.fleet.max_inflight_rows = n;
            }
        }
        if !parsed.get("liveness-ms").is_empty() {
            cfg.fleet.liveness_timeout_ms = parsed.get_u64("liveness-ms")?;
        }
        if !parsed.get("checkpoint-dir").is_empty() {
            cfg.fleet.checkpoint_dir = parsed.get("checkpoint-dir").to_string();
        }
        if let Ok(n) = parsed.get_u64("checkpoint-every") {
            if n > 0 {
                cfg.fleet.checkpoint_every = n;
            }
        }
        match parsed.get("control") {
            "" => {}
            a => cfg.serve.control = a.to_string(),
        }
        if let Ok(n) = parsed.get_u64("drain-timeout-ms") {
            if n > 0 {
                cfg.fleet.drain_timeout_ms = n;
            }
        }
        if !parsed.get("faults").is_empty() {
            cfg.faults = FaultsConfig::from_spec(parsed.get("faults"))
                .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
        }
        let mut _server = None;
        let backend = match parsed.get("backend") {
            "xla" => {
                let (srv, handle) =
                    XlaServer::spawn(Path::new(parsed.get("artifacts")), None, true)?;
                _server = Some(srv);
                Backend::Xla(handle)
            }
            "mock" => Backend::Mock(Arc::new(MockModel::new(mock_dims(&cfg)?, cfg.seed))),
            other => anyhow::bail!("unknown --backend `{other}` (xla|mock)"),
        };
        let metrics = Registry::new();
        println!(
            "rlarch serve: listen={} fleet_actors={} envs/actor={} steps={} \
             shards={} ingest={} max_inflight_rows={}",
            cfg.fleet.listen,
            cfg.actors.num_actors,
            cfg.actors.envs_per_actor,
            cfg.learner.max_steps,
            cfg.replay.shards,
            cfg.replay.insert_batch,
            cfg.fleet.max_inflight_rows
        );
        if !cfg.serve.control.is_empty() {
            println!(
                "control socket: {} (drain timeout {} ms)",
                cfg.serve.control, cfg.fleet.drain_timeout_ms
            );
        }
        let report = coordinator::run_serve(&cfg, backend, metrics)?;
        println!(
            "drained in {:.1}s: learner {} steps (loss {:.4} -> {:.4}), \
             {} sequences by wire; accepts {}, disconnects {}, reconnects {}, \
             shed rows {}; batcher occupancy {:.1}",
            report.elapsed_seconds,
            report.learner.steps,
            report.learner.first_loss,
            report.learner.final_loss,
            report.sequences,
            report.accepts,
            report.disconnects,
            report.reconnects,
            report.shed_rows,
            report.mean_batch_occupancy
        );
        if report.generation > 0 {
            println!(
                "checkpointing: generation {} ({} snapshot(s), resumed from step {})",
                report.generation, report.checkpoints, report.resumed_steps
            );
        }
        if report.reloads > 0 {
            println!(
                "serving: {} hot-reload(s), final generation {}",
                report.reloads, report.generation
            );
        }
        if let Some(inj) = &report.injected {
            println!(
                "fault injection: killed {} dropped {} delayed {} truncated {} \
                 corrupted {} stalled {} panics {}",
                inj.killed,
                inj.dropped,
                inj.delayed,
                inj.truncated,
                inj.corrupted,
                inj.stalled,
                inj.panics
            );
        }
        if let Some(e) = &report.first_error {
            println!("first fleet error: {e}");
        }
        anyhow::ensure!(
            report.batcher_errors == 0,
            "{} batcher error(s) during the run",
            report.batcher_errors
        );
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_actor(args: &[String]) -> i32 {
    let cli = Cli::new(
        "rlarch actor",
        "fleet worker: actor threads driving envs against a remote coordinator",
    )
    .flag("config", "", "TOML config path (must match the server's)")
    .flag(
        "connect",
        "",
        "override fleet.connect (tcp:host:port or uds:/path)",
    )
    .flag("id", "0", "fleet-global id of this worker's first actor")
    .flag("local-actors", "1", "actor threads in this process")
    .flag(
        "actors",
        "0",
        "override the FLEET-WIDE actor total (must match the server's)",
    )
    .flag("envs-per-actor", "0", "override envs per actor thread (vecenv)")
    .flag("pipeline-depth", "0", "override actor pipeline depth")
    .flag(
        "max-rounds",
        "",
        "stop after this many env rounds (default: run until server drain)",
    )
    .flag(
        "heartbeat-ms",
        "",
        "override fleet.heartbeat_interval_ms (ping the server when idle; 0 = off)",
    )
    .flag(
        "liveness-ms",
        "",
        "override fleet.liveness_timeout_ms (per-ticket deadline floor; 0 = off)",
    )
    .flag(
        "actor-restarts",
        "",
        "override fleet.actor_restart_budget (supervisor restarts per actor)",
    )
    .flag(
        "faults",
        "",
        "fault plan spec, e.g. seed=7,panic_actor=0,panic_at_step=3 ([faults] keys)",
    )
    .flag("env", "", "override env (must match the server's)");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<()> {
        let mut cfg = load_config(&parsed)?;
        match parsed.get("connect") {
            "" => {}
            a => cfg.fleet.connect = a.to_string(),
        }
        let id_base = parsed.get_usize("id")?;
        let local_actors = parsed.get_usize("local-actors")?.max(1);
        let max_rounds = match parsed.get("max-rounds") {
            "" => None,
            _ => Some(parsed.get_u64("max-rounds")?),
        };
        if !parsed.get("heartbeat-ms").is_empty() {
            cfg.fleet.heartbeat_interval_ms = parsed.get_u64("heartbeat-ms")?;
        }
        if !parsed.get("liveness-ms").is_empty() {
            cfg.fleet.liveness_timeout_ms = parsed.get_u64("liveness-ms")?;
        }
        if !parsed.get("actor-restarts").is_empty() {
            cfg.fleet.actor_restart_budget = parsed.get_usize("actor-restarts")?;
        }
        if !parsed.get("faults").is_empty() {
            cfg.faults = FaultsConfig::from_spec(parsed.get("faults"))
                .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
        }
        // Workers carry no backend: dims derive from the shared config
        // (mock convention) and the handshake validates them against
        // the server's actual model.
        let dims = mock_dims(&cfg)?;
        println!(
            "rlarch actor: connect={} ids {}..{} of fleet {} envs/actor={} depth={}",
            cfg.fleet.connect,
            id_base,
            id_base + local_actors,
            cfg.actors.num_actors,
            cfg.actors.envs_per_actor,
            cfg.actors.pipeline_depth
        );
        let report = coordinator::run_worker(
            &cfg,
            dims,
            id_base,
            local_actors,
            max_rounds,
            Registry::new(),
        )?;
        println!(
            "worker done in {:.1}s: {} env steps, {} episodes, mean return {:.2}, \
             {} supervisor restart(s)",
            report.elapsed_seconds,
            report.env_steps,
            report.episodes,
            report.mean_return,
            report.actor_restarts
        );
        match &report.first_error {
            Some(e) if report.env_steps == 0 => {
                anyhow::bail!("no env steps completed: {e}")
            }
            Some(e) => println!("note: {e} (server drain reached this worker mid-wait)"),
            None => {}
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// `rlarch ctl` — one-shot control client: send one command line to a
/// serving coordinator's control socket and print the reply. Exit 0 on
/// an `ok` reply, 1 on `err` (so shell scripts and CI can branch).
fn cmd_ctl(args: &[String]) -> i32 {
    let cli = Cli::new(
        "rlarch ctl",
        "drive a serving coordinator's control socket (health/ready/stats/reload/shutdown)",
    )
    .flag(
        "connect",
        "",
        "control socket address (tcp:host:port or uds:/path; the server's --control)",
    )
    .flag(
        "cmd",
        "health",
        "command line to send: health | ready | stats | reload <dir> | shutdown",
    );
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<String> {
        let addr = parsed.get("connect");
        anyhow::ensure!(!addr.is_empty(), "--connect is required (the server's --control)");
        let addr = rlarch::transport::Addr::parse(addr)?;
        rlarch::serve::control::send_command(&addr, parsed.get("cmd"))
    };
    match run() {
        Ok(reply) => {
            println!("{reply}");
            if reply.starts_with("ok") {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn load_traces(dir: &str) -> anyhow::Result<rlarch::simarch::SystemModel> {
    let ts = TraceSet::load(Path::new(dir))?;
    Ok(default_system(
        ts.find("infer_paper_scale")
            .ok_or_else(|| anyhow::anyhow!("no infer_paper_scale trace"))?
            .clone(),
        ts.find("train_paper_scale")
            .ok_or_else(|| anyhow::anyhow!("no train_paper_scale trace"))?
            .clone(),
    ))
}

fn cmd_sweep(args: &[String]) -> i32 {
    let cli = Cli::new("rlarch sweep", "Fig. 3: actor sweep on the simulator")
        .flag("actors", "1,2,4,8,16,32,40,64,128,256", "actor counts")
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<()> {
        let m = load_traces(parsed.get("artifacts"))?;
        let actors = parsed.get_usize_list("actors")?;
        let base = m.steady_state(actors[0]).env_rate;
        let mut t = Table::new(&[
            "actors", "env steps/s", "speedup", "batch", "GPU util", "power W",
            "perf/W",
        ]);
        for &n in &actors {
            let p = m.steady_state(n);
            t.row(&[
                n.to_string(),
                format!("{:.0}", p.env_rate),
                format!("{:.2}x", p.env_rate / base),
                format!("{:.1}", p.batch_size),
                format!("{:.2}", p.gpu_util),
                format!("{:.0}", p.power_w),
                format!("{:.1}", p.perf_per_watt),
            ]);
        }
        println!("{}", t.to_markdown());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_smsweep(args: &[String]) -> i32 {
    let cli = Cli::new("rlarch smsweep", "Fig. 4: SM sweep on the simulator")
        .flag("sms", "80,60,40,20,10,4,2", "SM counts")
        .flag("actors", "40", "actor count at the operating point")
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<()> {
        let m = load_traces(parsed.get("artifacts"))?;
        let n = parsed.get_usize("actors")?;
        let sms = parsed.get_usize_list("sms")?;
        let base = m.steady_state(n).env_rate;
        let mut t = Table::new(&["SMs", "CPU/GPU ratio", "slowdown", ""]);
        for &s in &sms {
            let p = m.with_sms(s).steady_state(n);
            let slow = base / p.env_rate;
            t.row(&[
                s.to_string(),
                format!("{:.3}", 40.0 / s as f64),
                format!("{slow:.3}x"),
                ascii_bar((slow - 1.0) / 10.0, 24),
            ]);
        }
        println!("{}", t.to_markdown());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_breakdown(args: &[String]) -> i32 {
    let cli = Cli::new("rlarch breakdown", "Fig. 2: GPU component breakdown")
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> anyhow::Result<()> {
        let ts = TraceSet::load(Path::new(parsed.get("artifacts")))?;
        let gpu = GpuModel::new(rlarch::config::GpuModelConfig::default());
        let trace = ts
            .find("train_paper_scale")
            .ok_or_else(|| anyhow::anyhow!("no train_paper_scale trace"))?;
        let b = gpu.breakdown(trace);
        let mut t = Table::new(&["component", "share", "", "paper"]);
        for (name, share, paper) in [
            ("Math", b.math, "57%"),
            ("SM utilization", b.sm_util, "15%"),
            ("DRAM bandwidth", b.dram_bw, "12%"),
            ("DRAM latency", b.dram_latency, "~8%"),
            ("L2", b.l2, "~8%"),
        ] {
            t.row(&[
                name.to_string(),
                format!("{:.1}%", share * 100.0),
                ascii_bar(share, 30),
                paper.to_string(),
            ]);
        }
        println!("{}", t.to_markdown());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_info(args: &[String]) -> i32 {
    let cli = Cli::new("rlarch info", "artifact + config summary")
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let dir = Path::new(parsed.get("artifacts"));
    match rlarch::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!(
                "agent: obs {s}x{s}x{c}, {a} actions, LSTM {h}, {p} params",
                s = m.obs_size,
                c = m.obs_channels,
                a = m.num_actions,
                h = m.lstm_hidden,
                p = m.param_count
            );
            println!(
                "r2d2: seq {} (burn-in {}), n-step {}, gamma {}, batch {}",
                m.seq_len, m.burn_in, m.n_step, m.gamma, m.train_batch
            );
            println!("artifacts: {:?}", m.artifacts.keys().collect::<Vec<_>>());
            println!("infer batches: {:?}", m.infer_batch_sizes());
            0
        }
        Err(e) => {
            eprintln!("error: {e} (run `make artifacts`)");
            1
        }
    }
}
