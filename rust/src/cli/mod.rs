//! Declarative CLI flag parser (clap is not in the offline crate set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; generates `--help` text from the declarations.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue(String, String, &'static str),
    MissingPositional(&'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown flag `--{name}` (see --help)"),
            CliError::MissingValue(name) => write!(f, "flag `--{name}` expects a value"),
            CliError::BadValue(name, value, ty) => {
                write!(f, "flag `--{name}`: cannot parse `{value}` as {ty}")
            }
            CliError::MissingPositional(name) => {
                write!(f, "missing required positional `{name}`")
            }
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Clone, Debug)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Builder + parser.
pub struct Cli {
    program: &'static str,
    about: &'static str,
    flags: Vec<FlagSpec>,
    positionals: Vec<(&'static str, &'static str)>,
}

#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self {
            program,
            about,
            flags: Vec::new(),
            positionals: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn help_text(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        out.push_str(" [flags]\n\nFLAGS:\n");
        for f in &self.flags {
            let default = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{:<24} {}{}\n", f.name, f.help, default));
        }
        if !self.positionals.is_empty() {
            out.push_str("\nPOSITIONALS:\n");
            for (p, h) in &self.positionals {
                out.push_str(&format!("  {p:<26} {h}\n"));
            }
        }
        out
    }

    /// Parse; on `--help` prints help and exits the process.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut parsed = Parsed::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                parsed.values.insert(f.name.to_string(), d.clone());
            }
            if !f.takes_value {
                parsed.bools.insert(f.name.to_string(), false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    parsed.values.insert(name, v);
                } else {
                    parsed.bools.insert(name, true);
                }
            } else {
                parsed.positionals.push(a.clone());
            }
            i += 1;
        }
        if parsed.positionals.len() < self.positionals.len() {
            return Err(CliError::MissingPositional(
                self.positionals[parsed.positionals.len()].0,
            ));
        }
        Ok(parsed)
    }

    /// Parse from the process environment (skipping argv[0]).
    pub fn parse_env(&self) -> Result<Parsed, CliError> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&args)
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_default()
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::BadValue(name.into(), self.get(name).into(), "usize"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::BadValue(name.into(), self.get(name).into(), "u64"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::BadValue(name.into(), self.get(name).into(), "f64"))
    }

    pub fn get_switch(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// Parse a comma-separated list of usizes (sweep specs like "4,8,40").
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse().map_err(|_| {
                    CliError::BadValue(name.into(), s.into(), "usize list")
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "test tool")
            .flag("actors", "8", "number of actors")
            .flag("mode", "central", "inference mode")
            .switch("verbose", "chatty output")
            .positional("config", "config path")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(&sv(&["conf.toml"])).unwrap();
        assert_eq!(p.get("actors"), "8");
        assert_eq!(p.get_usize("actors").unwrap(), 8);
        assert!(!p.get_switch("verbose"));
        assert_eq!(p.positional(0), Some("conf.toml"));
    }

    #[test]
    fn equals_and_space_forms() {
        let p = cli()
            .parse(&sv(&["c", "--actors=32", "--mode", "local", "--verbose"]))
            .unwrap();
        assert_eq!(p.get_usize("actors").unwrap(), 32);
        assert_eq!(p.get("mode"), "local");
        assert!(p.get_switch("verbose"));
    }

    #[test]
    fn errors() {
        assert!(matches!(
            cli().parse(&sv(&["c", "--nope"])),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            cli().parse(&sv(&["c", "--actors"])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(cli().parse(&sv(&[])), Err(CliError::MissingPositional(_))));
        let p = cli().parse(&sv(&["c", "--actors=abc"])).unwrap();
        assert!(p.get_usize("actors").is_err());
    }

    #[test]
    fn usize_list() {
        let c = Cli::new("t", "t").flag("sweep", "4,8,40", "sweep");
        let p = c.parse(&[]).unwrap();
        assert_eq!(p.get_usize_list("sweep").unwrap(), vec![4, 8, 40]);
    }

    #[test]
    fn help_mentions_flags() {
        let h = cli().help_text();
        assert!(h.contains("--actors"));
        assert!(h.contains("config"));
    }
}
