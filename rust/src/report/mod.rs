//! Reporting: the mini-bench harness (criterion is not in the offline
//! crate set) and figure/table renderers shared by `rust/benches/*` and
//! the examples. Benches print markdown to stdout and drop CSVs under
//! `target/bench_reports/`.

pub mod bench;
pub mod figure;

pub use bench::{bench, BenchResult};
pub use figure::{ascii_bar, Series, Table};

use std::path::PathBuf;

/// Directory for CSV outputs (created on demand).
pub fn report_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("bench_reports");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write a CSV report; returns the path written.
pub fn write_csv(name: &str, contents: &str) -> PathBuf {
    let path = report_dir().join(format!("{name}.csv"));
    let _ = std::fs::write(&path, contents);
    path
}
