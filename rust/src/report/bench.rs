//! Minimal benchmark harness: warmup + timed iterations with summary
//! statistics. Used by every `[[bench]]` target (criterion is not in the
//! offline crate set).

use crate::util::stats::{mean, percentile};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Iterations per second (1/mean).
    pub rate: f64,
}

impl BenchResult {
    pub fn markdown_header() -> &'static str {
        "| bench | iters | mean | p50 | p95 | min | rate |\n|---|---|---|---|---|---|---|"
    }

    pub fn to_markdown_row(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} | {:.1}/s |",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            fmt_time(self.min_s),
            self.rate
        )
    }
}

/// Human-friendly duration formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Run `f` for `warmup` + `iters` iterations, timing each.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let m = mean(&samples);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: m,
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        rate: if m > 0.0 { 1.0 / m } else { f64::INFINITY },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0u64;
        let r = bench("noop", 2, 10, || {
            count += 1;
        });
        assert_eq!(count, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.p95_s >= r.p50_s * 0.5);
        assert!(r.to_markdown_row().contains("noop"));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.5).ends_with('s'));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5e-6).ends_with("us"));
        assert!(fmt_time(2.5e-9).ends_with("ns"));
    }
}
