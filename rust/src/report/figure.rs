//! Text figure/table renderers: aligned markdown tables and ASCII bars
//! for the paper-figure benches.

/// A named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn to_csv(&self) -> String {
        let mut out = format!("x,{}\n", self.name);
        for (x, y) in &self.points {
            out.push_str(&format!("{x},{y}\n"));
        }
        out
    }
}

/// Horizontal ASCII bar of `frac` in [0,1], `width` cells.
pub fn ascii_bar(frac: f64, width: usize) -> String {
    let filled = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    let mut s = String::with_capacity(width);
    for i in 0..width {
        s.push(if i < filled { '█' } else { '·' });
    }
    s
}

/// Simple aligned table builder (markdown output).
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                s.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment_and_csv() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| name        | value |"));
        assert!(md.lines().count() == 4);
        assert_eq!(t.to_csv().lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_rows() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn bars_clamp() {
        assert_eq!(ascii_bar(0.5, 10).chars().filter(|&c| c == '█').count(), 5);
        assert_eq!(ascii_bar(2.0, 4).chars().filter(|&c| c == '█').count(), 4);
        assert_eq!(ascii_bar(-1.0, 4).chars().filter(|&c| c == '█').count(), 0);
    }

    #[test]
    fn series_csv() {
        let mut s = Series::new("rate");
        s.push(1.0, 2.0);
        s.push(2.0, 4.0);
        assert_eq!(s.to_csv(), "x,rate\n1,2\n2,4\n");
    }
}
