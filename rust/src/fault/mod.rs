//! Deterministic fault injection for the fleet (DESIGN.md §15).
//!
//! A [`FaultPlan`] is a seeded schedule of the failures a production
//! fleet actually sees — lost and delayed frames, corrupted bytes,
//! killed connections, stalled inference, panicking actor threads —
//! threaded into the transport and mock-backend seams behind the
//! `[faults]` config section. Every decision is drawn from a PCG
//! stream derived from `faults.seed` and a per-site id, so a given
//! plan replays exactly and every injected fault is counted in the
//! plan's own ledger (the chaos tests assert the `fleet.*` metrics
//! reconcile against it). With the section at its all-zero default the
//! plan is never constructed: the seams hold an `Option` that is
//! `None`, and the fault-free paths are bit-for-bit identical to a
//! build without this module (pinned by the PR 9 equivalence test).

use crate::config::FaultsConfig;
use crate::util::prng::Pcg32;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What to do with one received frame. Sampled per frame in a fixed
/// order (kill, drop, delay, truncate, corrupt) so a schedule replays
/// bit-for-bit for a given (seed, site, connection epoch) triple.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameFault {
    /// No fault: process the frame normally.
    Deliver,
    /// Kill the connection outright (the peer sees EOF and recovers).
    Kill,
    /// Silently discard the frame (a lost packet; the client's ticket
    /// deadline is the mechanism that notices).
    Drop,
    /// Hold the frame for the configured delay, then deliver it.
    Delay(Duration),
    /// Truncate the frame bytes before parsing (always rejected).
    Truncate,
    /// Flip the header magic before parsing (always rejected).
    Corrupt,
}

/// Ledger of everything a plan injected, by kind. The chaos soak
/// asserts the `fleet.*` metrics account for every entry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InjectedFaults {
    pub killed: u64,
    pub dropped: u64,
    pub delayed: u64,
    pub truncated: u64,
    pub corrupted: u64,
    pub stalled: u64,
    pub panics: u64,
}

/// The seeded fault schedule, shared by every seam (`Arc`). Holds the
/// configured rates plus the atomic injection ledger; per-connection
/// randomness lives in the [`ConnFaults`] handles it hands out.
pub struct FaultPlan {
    cfg: FaultsConfig,
    killed: AtomicU64,
    dropped: AtomicU64,
    delayed: AtomicU64,
    truncated: AtomicU64,
    corrupted: AtomicU64,
    stalled: AtomicU64,
    panics: AtomicU64,
    /// The actor panic fires exactly once per plan: a restarted actor
    /// must make progress, not re-panic forever, so the supervisor's
    /// restart count under this plan is deterministic.
    panic_fired: AtomicBool,
    /// Per-site connection epochs: each reconnection of a site draws
    /// the next stream in that site's seeded chain. Without this, a
    /// schedule that breaks a connection on its first frame would
    /// replay identically on every retry and livelock the site.
    epochs: Mutex<HashMap<u64, u64>>,
}

impl FaultPlan {
    /// Build the shared plan, or `None` when the config is all-off —
    /// the seams then cost one `Option` check and the wire paths stay
    /// bit-for-bit the fault-free ones.
    pub fn from_config(cfg: &FaultsConfig) -> Option<Arc<FaultPlan>> {
        cfg.enabled().then(|| {
            Arc::new(FaultPlan {
                cfg: cfg.clone(),
                killed: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                delayed: AtomicU64::new(0),
                truncated: AtomicU64::new(0),
                corrupted: AtomicU64::new(0),
                stalled: AtomicU64::new(0),
                panics: AtomicU64::new(0),
                panic_fired: AtomicBool::new(false),
                epochs: Mutex::new(HashMap::new()),
            })
        })
    }

    /// The per-site frame-fault stream for connection site `site`
    /// (infer connections use `actor_id + 1`, ingest uses 0). The
    /// stream depends only on (seed, site, per-site epoch) — the
    /// epoch is how many connections the site has opened before, so
    /// accept order *across* sites never matters, while a reconnected
    /// site advances to the next stream in its chain instead of
    /// replaying the fate that just killed it.
    pub fn conn(self: &Arc<Self>, site: u64) -> ConnFaults {
        let epoch = {
            let mut g = self.epochs.lock().unwrap();
            let e = g.entry(site).or_insert(0);
            let cur = *e;
            *e += 1;
            cur
        };
        let mut sm = crate::util::prng::SplitMix64::new(self.cfg.seed ^ site);
        let mut state = sm.next_u64();
        for _ in 0..epoch {
            state = sm.next_u64();
        }
        ConnFaults {
            rng: Pcg32::new(state, site.wrapping_mul(2).wrapping_add(1)),
            plan: self.clone(),
        }
    }

    /// The mock-inference stall schedule, if configured:
    /// `(rate, stall, seed)` for [`crate::runtime::MockModel`]'s seam.
    pub fn infer_stall(&self) -> Option<(f64, Duration, u64)> {
        (self.cfg.stall_rate > 0.0).then(|| {
            (
                self.cfg.stall_rate,
                Duration::from_millis(self.cfg.stall_ms),
                self.cfg.seed,
            )
        })
    }

    /// The submit round at which fleet-global actor `id` should panic,
    /// if this plan targets it.
    pub fn actor_panic_at(&self, id: usize) -> Option<u64> {
        (self.cfg.panic_actor >= 0 && self.cfg.panic_actor as usize == id)
            .then_some(self.cfg.panic_at_step)
    }

    /// Claim the one-shot actor panic. True exactly once per plan.
    pub fn take_panic(&self) -> bool {
        let first = !self.panic_fired.swap(true, Ordering::AcqRel);
        if first {
            self.panics.fetch_add(1, Ordering::Relaxed);
        }
        first
    }

    /// Record an injected mock-inference stall (the model's seam calls
    /// this so the ledger covers every kind).
    pub fn note_stall(&self) {
        self.stalled.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot the injection ledger.
    pub fn injected(&self) -> InjectedFaults {
        InjectedFaults {
            killed: self.killed.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            delayed: self.delayed.load(Ordering::Relaxed),
            truncated: self.truncated.load(Ordering::Relaxed),
            corrupted: self.corrupted.load(Ordering::Relaxed),
            stalled: self.stalled.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
        }
    }
}

/// One connection's handle on the plan: a private PCG stream plus the
/// shared ledger. Lives in the server's per-connection reader.
pub struct ConnFaults {
    rng: Pcg32,
    plan: Arc<FaultPlan>,
}

impl ConnFaults {
    /// Decide the fate of the next received frame and record it in the
    /// ledger. Exactly one fault (the first that fires in kill → drop
    /// → delay → truncate → corrupt order) applies per frame.
    pub fn sample(&mut self) -> FrameFault {
        let cfg = &self.plan.cfg;
        if cfg.kill_rate > 0.0 && self.rng.chance(cfg.kill_rate) {
            self.plan.killed.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Kill;
        }
        if cfg.drop_rate > 0.0 && self.rng.chance(cfg.drop_rate) {
            self.plan.dropped.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Drop;
        }
        if cfg.delay_rate > 0.0 && self.rng.chance(cfg.delay_rate) {
            self.plan.delayed.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Delay(Duration::from_millis(cfg.delay_ms));
        }
        if cfg.truncate_rate > 0.0 && self.rng.chance(cfg.truncate_rate) {
            self.plan.truncated.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Truncate;
        }
        if cfg.corrupt_rate > 0.0 && self.rng.chance(cfg.corrupt_rate) {
            self.plan.corrupted.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Corrupt;
        }
        FrameFault::Deliver
    }

    /// Apply a byte-mutating fault to a copy of the frame. `Truncate`
    /// cuts at a random point strictly inside the frame (possibly
    /// inside the header); `Corrupt` flips the magic, which
    /// `parse_header` always rejects — both are *guaranteed* to be
    /// refused by the defensive decoder, which is what makes
    /// `fleet.bad_frames` reconcile exactly against the ledger.
    pub fn mutate(&mut self, bytes: &mut Vec<u8>, fault: FrameFault) {
        match fault {
            FrameFault::Truncate => {
                let keep = self.rng.index(bytes.len().max(1));
                bytes.truncate(keep);
            }
            FrameFault::Corrupt => {
                if !bytes.is_empty() {
                    bytes[0] ^= 0x5A;
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(cfg: FaultsConfig) -> Arc<FaultPlan> {
        FaultPlan::from_config(&cfg).expect("enabled plan")
    }

    #[test]
    fn disabled_config_builds_no_plan() {
        assert!(FaultPlan::from_config(&FaultsConfig::default()).is_none());
        let on = FaultsConfig {
            corrupt_rate: 0.5,
            ..Default::default()
        };
        assert!(FaultPlan::from_config(&on).is_some());
    }

    #[test]
    fn schedules_replay_for_the_same_seed_and_site() {
        let cfg = FaultsConfig {
            seed: 7,
            drop_rate: 0.2,
            delay_rate: 0.2,
            kill_rate: 0.05,
            truncate_rate: 0.1,
            corrupt_rate: 0.1,
            ..Default::default()
        };
        let (pa, pb) = (plan(cfg.clone()), plan(cfg));
        let mut a = pa.conn(3);
        let mut b = pb.conn(3);
        let sa: Vec<FrameFault> = (0..256).map(|_| a.sample()).collect();
        let sb: Vec<FrameFault> = (0..256).map(|_| b.sample()).collect();
        assert_eq!(sa, sb);
        assert_eq!(pa.injected(), pb.injected());
        // A different site draws a different (still seeded) schedule.
        let mut c = pa.conn(4);
        let sc: Vec<FrameFault> = (0..256).map(|_| c.sample()).collect();
        assert_ne!(sa, sc);
        // A reconnection of the same site advances to the next epoch:
        // a fresh stream (no first-frame livelock), but still the same
        // stream on both plans (replayable).
        let mut a2 = pa.conn(3);
        let mut b2 = pb.conn(3);
        let sa2: Vec<FrameFault> = (0..256).map(|_| a2.sample()).collect();
        let sb2: Vec<FrameFault> = (0..256).map(|_| b2.sample()).collect();
        assert_eq!(sa2, sb2);
        assert_ne!(sa, sa2, "epoch 1 must not replay epoch 0");
    }

    #[test]
    fn ledger_counts_every_sampled_fault() {
        let p = plan(FaultsConfig {
            seed: 11,
            drop_rate: 0.5,
            ..Default::default()
        });
        let mut c = p.conn(1);
        let dropped = (0..1000)
            .filter(|_| c.sample() == FrameFault::Drop)
            .count() as u64;
        assert!(dropped > 0);
        assert_eq!(p.injected().dropped, dropped);
        assert_eq!(p.injected().killed, 0);
    }

    #[test]
    fn mutations_are_always_rejected_by_the_decoder() {
        let p = plan(FaultsConfig {
            seed: 5,
            truncate_rate: 1.0,
            ..Default::default()
        });
        let mut c = p.conn(0);
        let mut buf = Vec::new();
        for i in 0..64u64 {
            crate::transport::frame::encode_submit(
                &mut buf,
                i,
                1,
                &[1.0, 2.0],
                &[3.0],
                &[4.0],
            );
            let mut frame = buf[4..].to_vec();
            let fault = if i % 2 == 0 {
                FrameFault::Truncate
            } else {
                FrameFault::Corrupt
            };
            c.mutate(&mut frame, fault);
            let rejected = match crate::transport::frame::parse_header(&frame) {
                Err(_) => true,
                Ok(hd) => {
                    let (mut o, mut h, mut cc) =
                        (Vec::new(), Vec::new(), Vec::new());
                    crate::transport::frame::decode_submit(
                        crate::transport::frame::payload(&frame),
                        hd.rows as usize,
                        2,
                        1,
                        &mut o,
                        &mut h,
                        &mut cc,
                    )
                    .is_err()
                }
            };
            assert!(rejected, "mutated frame {i} must not decode");
        }
    }

    #[test]
    fn actor_panic_is_one_shot_and_targeted() {
        let p = plan(FaultsConfig {
            panic_actor: 2,
            panic_at_step: 5,
            ..Default::default()
        });
        assert_eq!(p.actor_panic_at(2), Some(5));
        assert_eq!(p.actor_panic_at(1), None);
        assert!(p.take_panic());
        assert!(!p.take_panic(), "panic fires exactly once per plan");
        assert_eq!(p.injected().panics, 1);
    }
}
