//! Telemetry: the observability layer over the SEED dataflow.
//!
//! Three pillars, all config-gated and off by default (the disabled
//! path is bit-for-bit and allocation-identical to an uninstrumented
//! run):
//!
//! 1. **Striped hot-path timers** live in `metrics/` (per-thread stripe
//!    accumulators merged at snapshot; see `metrics::Timer`).
//! 2. **Span tracing** ([`span`]): lock-free per-thread rings of
//!    structured phase spans rendered as Chrome trace-event JSON
//!    (`rlarch train --trace-out`).
//! 3. **Phase attribution** ([`sampler`], [`phase`]): a background
//!    thread samples the registry into a JSONL time-series with derived
//!    gauges (steps/s, batch occupancy, padding efficiency, live
//!    CPU/GPU-ratio proxy), and the end of run renders a Fig. 2-style
//!    breakdown compared against `SystemModel::steady_state`
//!    (`telemetry.model_drift`).
//!
//! [`Telemetry`] is the lifecycle handle the coordinator drives:
//! `install` the tracer into the metrics registry, `start_sampler`
//! before the workers spawn, `write_trace` after they join.

pub mod phase;
pub mod sampler;
pub mod span;

pub use phase::{attribution_report, MeasuredPhases, MODEL_DRIFT};
pub use sampler::{SamplerHandle, CPU_GPU_RATIO};
pub use span::{SpanKind, SpanRecorder, Tracer};

use crate::config::TelemetryConfig;
use crate::metrics::Registry;
use crate::util::json::Value;
use std::sync::Arc;

/// Per-run telemetry lifecycle, built from the `[telemetry]` config
/// section. With default config this is a no-op shell: no tracer, no
/// sampler, no files.
pub struct Telemetry {
    cfg: TelemetryConfig,
    tracer: Option<Arc<Tracer>>,
}

impl Telemetry {
    pub fn disabled() -> Telemetry {
        Telemetry {
            cfg: TelemetryConfig::default(),
            tracer: None,
        }
    }

    pub fn from_config(cfg: &TelemetryConfig) -> Telemetry {
        Telemetry {
            cfg: cfg.clone(),
            tracer: cfg
                .trace_enabled()
                .then(|| Tracer::new(cfg.trace_capacity)),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Make span recorders fetched from `metrics` live (tracing runs
    /// only).
    pub fn install(&self, metrics: &Registry) {
        if let Some(t) = &self.tracer {
            metrics.install_tracer(t.clone());
        }
    }

    /// Spawn the background registry sampler if `metrics_out` is set.
    pub fn start_sampler(
        &self,
        metrics: &Registry,
    ) -> anyhow::Result<Option<SamplerHandle>> {
        if !self.cfg.sampler_enabled() {
            return Ok(None);
        }
        Ok(Some(sampler::start(
            metrics.clone(),
            &self.cfg.metrics_out,
            self.cfg.snapshot_interval_ms,
        )?))
    }

    /// Write the Chrome trace to `trace_out` (tracing runs only; call
    /// after the instrumented threads have joined). Returns the path
    /// and span count when a trace was written.
    pub fn write_trace(&self) -> anyhow::Result<Option<(String, usize)>> {
        let Some(tracer) = &self.tracer else {
            return Ok(None);
        };
        let path = &self.cfg.trace_out;
        let doc = tracer.chrome_trace();
        std::fs::write(path, doc.to_string())
            .map_err(|e| anyhow::anyhow!("telemetry.trace_out `{path}`: {e}"))?;
        Ok(Some((path.clone(), tracer.span_count())))
    }
}

/// Validate an emitted Chrome trace: parses as JSON and carries a
/// non-empty `traceEvents` array. Returns the event count. Used by the
/// CLI after a `--trace-out` run and by the CI smoke gate.
pub fn validate_trace_file(path: &str) -> anyhow::Result<usize> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read trace `{path}`: {e}"))?;
    let v = Value::parse(&text)
        .map_err(|e| anyhow::anyhow!("trace `{path}` is not valid JSON: {e}"))?;
    let events = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trace `{path}` lacks traceEvents[]"))?;
    if events.is_empty() {
        anyhow::bail!("trace `{path}` has no events");
    }
    Ok(events.len())
}

/// Validate an emitted JSONL metrics series: every non-empty line
/// parses as a JSON object with a numeric `t`. Returns the line count.
pub fn validate_metrics_file(path: &str) -> anyhow::Result<usize> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read metrics `{path}`: {e}"))?;
    let mut n = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Value::parse(line).map_err(|e| {
            anyhow::anyhow!("metrics `{path}` line {}: invalid JSON: {e}", i + 1)
        })?;
        if v.get("t").and_then(|t| t.as_f64()).is_none() {
            anyhow::bail!("metrics `{path}` line {} lacks numeric `t`", i + 1);
        }
        n += 1;
    }
    if n == 0 {
        anyhow::bail!("metrics `{path}` is empty");
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_telemetry_is_inert() {
        let t = Telemetry::disabled();
        assert!(!t.enabled());
        let metrics = Registry::new();
        t.install(&metrics);
        assert!(metrics.tracer().is_none());
        assert!(t.start_sampler(&metrics).unwrap().is_none());
        assert!(t.write_trace().unwrap().is_none());
        // Recorders fetched through the registry come back inert.
        assert!(!metrics.span_recorder(format_args!("actor-0")).enabled());
    }

    #[test]
    fn trace_write_and_validate_roundtrip() {
        let dir = std::env::temp_dir().join("rlarch_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let cfg = TelemetryConfig {
            trace_out: path.to_str().unwrap().to_string(),
            ..Default::default()
        };
        let t = Telemetry::from_config(&cfg);
        assert!(t.enabled());
        let metrics = Registry::new();
        t.install(&metrics);
        let rec = metrics.span_recorder(format_args!("worker-{}", 0));
        assert!(rec.enabled());
        {
            let _g = rec.span(SpanKind::EnvStep);
        }
        let (written, spans) = t.write_trace().unwrap().unwrap();
        assert_eq!(spans, 1);
        // Metadata event + 1 span event.
        assert_eq!(validate_trace_file(&written).unwrap(), 2);
        assert!(validate_metrics_file(&written).is_err());
    }
}
