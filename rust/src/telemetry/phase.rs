//! End-of-run Fig. 2-style phase attribution: measured busy time per
//! pipeline phase (from timer `.sum`s), rendered as a breakdown table
//! and compared against `SystemModel::steady_state`'s prediction. The
//! mean absolute share gap is exported as the `telemetry.model_drift`
//! gauge so calibration regressions are a single number.

use crate::metrics::Registry;
use crate::simarch::{PhaseShares, SystemModel};
use std::collections::BTreeMap;

/// Gauge exporting the model-vs-measured drift (mean absolute share
/// difference across the four phases, in [0, 1]).
pub const MODEL_DRIFT: &str = "telemetry.model_drift";

/// Measured busy seconds per phase, summed across threads.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeasuredPhases {
    pub env_s: f64,
    pub infer_s: f64,
    pub train_s: f64,
    pub replay_s: f64,
}

impl MeasuredPhases {
    /// Pull the phase sums out of a registry snapshot. Phases map to
    /// the metric inventory as: env = `actor.env_seconds` (env stepping
    /// + transition building + replay hand-off), infer =
    /// `batcher.infer_seconds`, train = `learner.train_seconds`,
    /// replay = `learner.sample_seconds` + `learner.assemble_seconds`.
    pub fn from_snapshot(snap: &BTreeMap<String, f64>) -> MeasuredPhases {
        let get = |k: &str| snap.get(k).copied().unwrap_or(0.0);
        MeasuredPhases {
            env_s: get("actor.env_seconds.sum"),
            infer_s: get("batcher.infer_seconds.sum"),
            train_s: get("learner.train_seconds.sum"),
            replay_s: get("learner.sample_seconds.sum")
                + get("learner.assemble_seconds.sum"),
        }
    }

    pub fn total(&self) -> f64 {
        self.env_s + self.infer_s + self.train_s + self.replay_s
    }

    pub fn shares(&self) -> PhaseShares {
        let total = self.total();
        if total <= 0.0 {
            return PhaseShares::default();
        }
        PhaseShares {
            env: self.env_s / total,
            infer: self.infer_s / total,
            train: self.train_s / total,
            replay: self.replay_s / total,
        }
    }
}

fn share_drift(a: &PhaseShares, b: &PhaseShares) -> f64 {
    ((a.env - b.env).abs()
        + (a.infer - b.infer).abs()
        + (a.train - b.train).abs()
        + (a.replay - b.replay).abs())
        / 4.0
}

/// Render the Fig. 2-style breakdown table and, when a model is
/// supplied, set `telemetry.model_drift` in the registry. Returns
/// `None` when nothing was measured (e.g. a run that never trained).
pub fn attribution_report(
    metrics: &Registry,
    model: Option<&SystemModel>,
    actors: usize,
) -> Option<String> {
    let snap = metrics.snapshot();
    let measured = MeasuredPhases::from_snapshot(&snap);
    if measured.total() <= 0.0 {
        return None;
    }
    let shares = measured.shares();
    let predicted = model.map(|m| m.phase_shares(actors.max(1)));
    let drift = predicted.map(|p| share_drift(&shares, &p));
    if let Some(d) = drift {
        metrics.gauge(MODEL_DRIFT).set(d);
    }

    let rows: [(&str, f64, f64, Option<f64>); 4] = [
        ("env", measured.env_s, shares.env, predicted.map(|p| p.env)),
        (
            "infer",
            measured.infer_s,
            shares.infer,
            predicted.map(|p| p.infer),
        ),
        (
            "train",
            measured.train_s,
            shares.train,
            predicted.map(|p| p.train),
        ),
        (
            "replay",
            measured.replay_s,
            shares.replay,
            predicted.map(|p| p.replay),
        ),
    ];
    let mut out = String::from(
        "| phase | busy s | measured share | model share | gap (pp) |\n\
         |---|---|---|---|---|\n",
    );
    for (name, busy, share, pred) in rows {
        let (model_col, gap_col) = match pred {
            Some(p) => (
                format!("{:.1}%", p * 100.0),
                format!("{:+.1}", (share - p) * 100.0),
            ),
            None => ("-".into(), "-".into()),
        };
        out.push_str(&format!(
            "| {name} | {busy:.3} | {:.1}% | {model_col} | {gap_col} |\n",
            share * 100.0
        ));
    }
    if let Some(d) = drift {
        out.push_str(&format!(
            "\ntelemetry.model_drift = {d:.4} (mean |measured - model| share)\n"
        ));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simarch::{
        default_system, synthetic_paper_trace, synthetic_paper_train_trace,
    };

    fn fake_measured(metrics: &Registry) {
        metrics.timer("actor.env_seconds").record(0.6);
        metrics.timer("batcher.infer_seconds").record(0.2);
        metrics.timer("learner.train_seconds").record(0.1);
        metrics.timer("learner.sample_seconds").record(0.05);
        metrics.timer("learner.assemble_seconds").record(0.05);
    }

    #[test]
    fn measured_shares_from_snapshot() {
        let metrics = Registry::new();
        fake_measured(&metrics);
        let m = MeasuredPhases::from_snapshot(&metrics.snapshot());
        assert!((m.total() - 1.0).abs() < 1e-9);
        let s = m.shares();
        assert!((s.env - 0.6).abs() < 1e-9);
        assert!((s.replay - 0.1).abs() < 1e-9);
    }

    #[test]
    fn attribution_sets_drift_gauge_and_renders_table() {
        let metrics = Registry::new();
        fake_measured(&metrics);
        let model = default_system(
            synthetic_paper_trace(1, 1, 64),
            synthetic_paper_train_trace(2, 80, 16),
        );
        let table = attribution_report(&metrics, Some(&model), 4).unwrap();
        for phase in ["env", "infer", "train", "replay"] {
            assert!(table.contains(&format!("| {phase} |")), "{table}");
        }
        assert!(table.contains("telemetry.model_drift"), "{table}");
        let drift = metrics.gauge(MODEL_DRIFT).get();
        assert!(
            (0.0..=1.0).contains(&drift) && metrics.gauge(MODEL_DRIFT).written(),
            "drift {drift}"
        );
    }

    #[test]
    fn attribution_without_model_or_measurement() {
        let metrics = Registry::new();
        assert!(attribution_report(&metrics, None, 4).is_none());
        fake_measured(&metrics);
        let table = attribution_report(&metrics, None, 4).unwrap();
        assert!(table.contains("| env |"));
        assert!(!metrics.gauge(MODEL_DRIFT).written());
    }
}
