//! Structured span tracing: a lock-free per-thread ring of phase spans.
//!
//! Each instrumented thread registers one `SpanRing` with the shared
//! [`Tracer`] and records spans through a [`SpanRecorder`] — a
//! single-writer handle whose hot path is two relaxed atomic stores into
//! a preallocated slot (no locks, no allocation; `micro_metrics` pins
//! this at 0 steady-state allocations). The coordinator drains all rings
//! after its worker threads join and renders them as Chrome trace-event
//! JSON (`chrome://tracing` / Perfetto), making pipeline overlap
//! (`pipeline_depth`, learner prefetch) visually inspectable.
//!
//! Slots are pairs of `AtomicU64` (start_us, dur_us<<8 | kind), so a
//! drain that races a still-live writer can at worst observe one torn
//! span — never undefined behavior. In practice `Tracer::drain` runs
//! post-join when every writer has quiesced.

use crate::util::json::{obj, Value};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Pipeline phases a span can describe. Encoded as a `u8` in the ring
/// so a slot stays two machine words.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Actor: action selection + env stepping + transition building +
    /// replay hand-off for one slot group.
    EnvStep = 0,
    /// Actor → batcher submission (enqueue side).
    PolicySubmit = 1,
    /// Actor blocked waiting for an inference reply.
    PolicyWait = 2,
    /// Batcher: collecting rows until the flush condition (size/timeout).
    BatcherCollect = 3,
    /// Batcher: padded-bucket launch on the backend (flush → launch).
    BatcherLaunch = 4,
    /// Actor-side replay insert (ingest push, including deferred flush).
    ReplayInsert = 5,
    /// Learner-side prioritized sampling.
    ReplaySample = 6,
    /// Learner: batch assembly from sampled sequences.
    LearnerAssemble = 7,
    /// Learner: backend train step.
    LearnerTrain = 8,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::EnvStep => "env_step",
            SpanKind::PolicySubmit => "policy_submit",
            SpanKind::PolicyWait => "policy_wait",
            SpanKind::BatcherCollect => "batcher_collect",
            SpanKind::BatcherLaunch => "batcher_launch",
            SpanKind::ReplayInsert => "replay_insert",
            SpanKind::ReplaySample => "replay_sample",
            SpanKind::LearnerAssemble => "learner_assemble",
            SpanKind::LearnerTrain => "learner_train",
        }
    }

    fn from_u8(v: u8) -> Option<SpanKind> {
        Some(match v {
            0 => SpanKind::EnvStep,
            1 => SpanKind::PolicySubmit,
            2 => SpanKind::PolicyWait,
            3 => SpanKind::BatcherCollect,
            4 => SpanKind::BatcherLaunch,
            5 => SpanKind::ReplayInsert,
            6 => SpanKind::ReplaySample,
            7 => SpanKind::LearnerAssemble,
            8 => SpanKind::LearnerTrain,
            _ => return None,
        })
    }
}

/// A completed span, decoded from a ring slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub kind: SpanKind,
    /// Start, microseconds since the tracer epoch.
    pub start_us: u64,
    pub dur_us: u64,
}

/// One ring slot: `a` = start_us, `b` = dur_us << 8 | kind. Durations
/// cap at 2^56 µs (~2k years), far beyond any run.
struct Slot {
    a: AtomicU64,
    b: AtomicU64,
}

const SLOT_EMPTY: u64 = u64::MAX;

/// Fixed-capacity single-writer span ring. The owning thread writes via
/// its `SpanRecorder`; older spans are overwritten on wrap (the trace
/// keeps the newest `capacity` spans per thread).
pub struct SpanRing {
    label: String,
    tid: u32,
    slots: Box<[Slot]>,
    /// Total spans ever pushed (not wrapped).
    head: AtomicUsize,
}

impl SpanRing {
    fn new(label: String, tid: u32, capacity: usize) -> Self {
        let slots = (0..capacity.max(1))
            .map(|_| Slot {
                a: AtomicU64::new(SLOT_EMPTY),
                b: AtomicU64::new(SLOT_EMPTY),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Self {
            label,
            tid,
            slots,
            head: AtomicUsize::new(0),
        }
    }

    /// Hot path: two relaxed stores + a release bump. No locks, no
    /// allocation.
    fn push(&self, kind: SpanKind, start_us: u64, dur_us: u64) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[h % self.slots.len()];
        slot.a.store(start_us, Ordering::Relaxed);
        slot.b
            .store((dur_us << 8) | kind as u64, Ordering::Relaxed);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Spans dropped to wrap-around (total pushed minus retained).
    pub fn dropped(&self) -> u64 {
        let h = self.head.load(Ordering::Acquire);
        h.saturating_sub(self.slots.len()) as u64
    }

    /// Decode retained spans in push order (oldest retained first).
    pub fn collect(&self) -> Vec<Span> {
        let h = self.head.load(Ordering::Acquire);
        let cap = self.slots.len();
        let n = h.min(cap);
        let mut out = Vec::with_capacity(n);
        for i in (h - n)..h {
            let slot = &self.slots[i % cap];
            let a = slot.a.load(Ordering::Relaxed);
            let b = slot.b.load(Ordering::Relaxed);
            if a == SLOT_EMPTY || b == SLOT_EMPTY {
                continue;
            }
            if let Some(kind) = SpanKind::from_u8((b & 0xFF) as u8) {
                out.push(Span {
                    kind,
                    start_us: a,
                    dur_us: b >> 8,
                });
            }
        }
        out
    }
}

/// Shared tracer: the registration point for per-thread rings and the
/// post-run drain/render side. Created once per run when `--trace-out`
/// is set; absent (and therefore zero-cost) otherwise.
pub struct Tracer {
    epoch: Instant,
    capacity: usize,
    rings: Mutex<Vec<Arc<SpanRing>>>,
}

impl Tracer {
    pub fn new(span_capacity: usize) -> Arc<Tracer> {
        Arc::new(Tracer {
            epoch: Instant::now(),
            capacity: span_capacity.max(1),
            rings: Mutex::new(Vec::new()),
        })
    }

    /// Register a ring for the calling thread and hand back its
    /// single-writer recorder. Allocation happens here (startup), never
    /// on the record path.
    pub fn recorder(self: &Arc<Tracer>, label: &str) -> SpanRecorder {
        let mut rings = self.rings.lock().unwrap();
        let tid = rings.len() as u32 + 1;
        let ring = Arc::new(SpanRing::new(label.to_string(), tid, self.capacity));
        rings.push(ring.clone());
        SpanRecorder {
            inner: Some(RecorderInner {
                ring,
                epoch: self.epoch,
            }),
        }
    }

    /// All registered rings (drain after the writers have joined).
    pub fn rings(&self) -> Vec<Arc<SpanRing>> {
        self.rings.lock().unwrap().clone()
    }

    /// Total spans recorded across every ring (retained, post-wrap).
    pub fn span_count(&self) -> usize {
        self.rings().iter().map(|r| r.collect().len()).sum()
    }

    /// Render every ring as a Chrome trace-event JSON document
    /// (`{"traceEvents": [...]}`): one complete-event (`"ph":"X"`) per
    /// span plus a thread-name metadata event per ring.
    pub fn chrome_trace(&self) -> Value {
        let mut events: Vec<Value> = Vec::new();
        for ring in self.rings() {
            events.push(obj(&[
                ("name", Value::from("thread_name")),
                ("ph", Value::from("M")),
                ("pid", Value::from(1u64)),
                ("tid", Value::from(ring.tid as u64)),
                (
                    "args",
                    obj(&[("name", Value::from(ring.label.as_str()))]),
                ),
            ]));
            for s in ring.collect() {
                events.push(obj(&[
                    ("name", Value::from(s.kind.name())),
                    ("cat", Value::from("rlarch")),
                    ("ph", Value::from("X")),
                    ("ts", Value::from(s.start_us)),
                    ("dur", Value::from(s.dur_us)),
                    ("pid", Value::from(1u64)),
                    ("tid", Value::from(ring.tid as u64)),
                ]));
            }
        }
        obj(&[("traceEvents", Value::Arr(events))])
    }
}

struct RecorderInner {
    ring: Arc<SpanRing>,
    epoch: Instant,
}

/// Per-thread span writer. `inner == None` is the disabled recorder:
/// `span()` returns an inert guard without reading the clock, so the
/// disabled path stays bit-for-bit and allocation-identical to an
/// uninstrumented build.
///
/// Deliberately not `Clone`: one recorder (and so one ring writer) per
/// thread is the single-writer contract the lock-free ring relies on.
pub struct SpanRecorder {
    inner: Option<RecorderInner>,
}

impl SpanRecorder {
    pub fn disabled() -> SpanRecorder {
        SpanRecorder { inner: None }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a span; it records itself into the ring when dropped.
    #[inline]
    pub fn span(&self, kind: SpanKind) -> SpanGuard<'_> {
        SpanGuard {
            open: self
                .inner
                .as_ref()
                .map(|inner| (inner, kind, Instant::now())),
        }
    }
}

/// RAII span: measures from `SpanRecorder::span` to drop.
pub struct SpanGuard<'a> {
    open: Option<(&'a RecorderInner, SpanKind, Instant)>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some((inner, kind, t0)) = self.open.take() {
            let start_us = t0.duration_since(inner.epoch).as_micros() as u64;
            let dur_us = t0.elapsed().as_micros() as u64;
            inner.ring.push(kind, start_us, dur_us);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = SpanRecorder::disabled();
        assert!(!rec.enabled());
        for _ in 0..10 {
            let _g = rec.span(SpanKind::EnvStep);
        }
    }

    #[test]
    fn spans_record_in_order() {
        let tracer = Tracer::new(64);
        let rec = tracer.recorder("worker");
        for kind in [SpanKind::EnvStep, SpanKind::PolicyWait, SpanKind::EnvStep] {
            let _g = rec.span(kind);
        }
        let rings = tracer.rings();
        assert_eq!(rings.len(), 1);
        let spans = rings[0].collect();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].kind, SpanKind::EnvStep);
        assert_eq!(spans[1].kind, SpanKind::PolicyWait);
        assert!(spans.windows(2).all(|w| w[0].start_us <= w[1].start_us));
        assert_eq!(rings[0].dropped(), 0);
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let tracer = Tracer::new(4);
        let rec = tracer.recorder("w");
        for _ in 0..10 {
            let _g = rec.span(SpanKind::LearnerTrain);
        }
        let ring = &tracer.rings()[0];
        assert_eq!(ring.collect().len(), 4);
        assert_eq!(ring.dropped(), 6);
    }

    #[test]
    fn chrome_trace_shape() {
        let tracer = Tracer::new(16);
        let a = tracer.recorder("actor-0");
        let b = tracer.recorder("learner");
        {
            let _g = a.span(SpanKind::EnvStep);
        }
        {
            let _g = b.span(SpanKind::LearnerTrain);
        }
        let doc = tracer.chrome_trace();
        // Round-trips through the in-tree JSON parser.
        let parsed = Value::parse(&doc.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 span events.
        assert_eq!(events.len(), 4);
        let names: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(names.contains(&"env_step"));
        assert!(names.contains(&"learner_train"));
        let span = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("env_step"))
            .unwrap();
        assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(span.get("ts").unwrap().as_f64().is_some());
    }
}
