//! Background registry sampler: a thread that snapshots the metrics
//! registry every `telemetry.snapshot_interval_ms` into a JSONL
//! time-series and publishes derived gauges — steps/s, inference batch
//! occupancy, padding efficiency, and the paper's live CPU/GPU-ratio
//! proxy `(t_env + t_replay) / (t_infer + t_train)` — back into the
//! registry under `telemetry.*`.
//!
//! Each output line is one flat JSON object: `t` (seconds since sampler
//! start), every metric from `Registry::snapshot`, and the derived
//! gauges. Rates are computed from deltas between consecutive
//! snapshots; the CPU/GPU ratio from cumulative timer `.sum`s.

use crate::metrics::Registry;
use crate::util::json::Value;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Derived-gauge names (also the JSONL keys).
pub const STEPS_PER_SEC: &str = "telemetry.steps_per_sec";
pub const BATCH_OCCUPANCY: &str = "telemetry.batch_occupancy";
pub const PADDING_EFFICIENCY: &str = "telemetry.padding_efficiency";
pub const CPU_GPU_RATIO: &str = "telemetry.cpu_gpu_ratio";

/// Counters a rate/ratio is derived from, carried between ticks.
#[derive(Default)]
struct Window {
    t: f64,
    env_steps: f64,
    items: f64,
    batches: f64,
    padded_rows: f64,
}

/// Handle to the running sampler thread. `stop` signals it, joins it,
/// and returns how many samples were written (always ≥ 1: a final
/// snapshot is taken on shutdown so even sub-interval runs produce a
/// time-series).
pub struct SamplerHandle {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<std::io::Result<u64>>>,
}

impl SamplerHandle {
    pub fn stop(mut self) -> anyhow::Result<u64> {
        self.stop.store(true, Ordering::Relaxed);
        let n = self
            .join
            .take()
            .expect("sampler joined twice")
            .join()
            .map_err(|_| anyhow::anyhow!("telemetry sampler thread panicked"))??;
        Ok(n)
    }
}

impl Drop for SamplerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn the sampler. The output file is created eagerly so a bad path
/// fails the run up front, not silently from a background thread.
pub fn start(
    metrics: Registry,
    path: &str,
    interval_ms: usize,
) -> anyhow::Result<SamplerHandle> {
    let file = File::create(path)
        .map_err(|e| anyhow::anyhow!("telemetry.metrics_out `{path}`: {e}"))?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let interval = Duration::from_millis(interval_ms.max(1) as u64);
    let join = std::thread::Builder::new()
        .name("rlarch-telemetry".into())
        .spawn(move || run_sampler(metrics, file, interval, stop2))
        .expect("spawn telemetry sampler");
    Ok(SamplerHandle {
        stop,
        join: Some(join),
    })
}

fn run_sampler(
    metrics: Registry,
    file: File,
    interval: Duration,
    stop: Arc<AtomicBool>,
) -> std::io::Result<u64> {
    let mut out = BufWriter::new(file);
    let t0 = Instant::now();
    let mut prev = Window::default();
    let mut samples = 0u64;
    let slice = Duration::from_millis(10).min(interval);
    'run: loop {
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline {
            if stop.load(Ordering::Relaxed) {
                break 'run;
            }
            std::thread::sleep(slice);
        }
        tick(&metrics, &mut out, t0, &mut prev)?;
        samples += 1;
    }
    // Final snapshot so the series always covers the end of the run.
    tick(&metrics, &mut out, t0, &mut prev)?;
    samples += 1;
    out.flush()?;
    Ok(samples)
}

fn tick(
    metrics: &Registry,
    out: &mut BufWriter<File>,
    t0: Instant,
    prev: &mut Window,
) -> std::io::Result<()> {
    let snap = metrics.snapshot();
    let t = t0.elapsed().as_secs_f64();
    let derived = derive(metrics, &snap, t, prev);

    let mut kvs: Vec<(String, Value)> = Vec::with_capacity(snap.len() + 5);
    kvs.push(("t".into(), Value::Num(t)));
    for (k, v) in &snap {
        kvs.push((k.clone(), Value::Num(*v)));
    }
    for (k, v) in derived {
        kvs.push((k.into(), Value::Num(v)));
    }
    let line = Value::Obj(kvs).to_string();
    out.write_all(line.as_bytes())?;
    out.write_all(b"\n")?;
    out.flush()
}

/// Compute the derived gauges from the snapshot, publish them into the
/// registry, and return them for the JSONL line. Rate-style gauges use
/// the delta since the previous tick; the CPU/GPU ratio uses cumulative
/// busy seconds (stable from the first sample onward).
fn derive(
    metrics: &Registry,
    snap: &BTreeMap<String, f64>,
    t: f64,
    prev: &mut Window,
) -> Vec<(&'static str, f64)> {
    let get = |k: &str| snap.get(k).copied().unwrap_or(0.0);
    let cur = Window {
        t,
        env_steps: get("actor.env_steps"),
        items: get("batcher.items"),
        batches: get("batcher.batches"),
        padded_rows: get("batcher.padded_rows"),
    };
    let dt = (cur.t - prev.t).max(1e-9);
    let d_items = cur.items - prev.items;
    let d_batches = cur.batches - prev.batches;
    let d_padded = cur.padded_rows - prev.padded_rows;

    let mut out = Vec::with_capacity(4);
    out.push((STEPS_PER_SEC, (cur.env_steps - prev.env_steps) / dt));
    if d_batches > 0.0 {
        out.push((BATCH_OCCUPANCY, d_items / d_batches));
        out.push((PADDING_EFFICIENCY, d_items / (d_items + d_padded).max(1.0)));
    }
    // The paper's live bottleneck proxy: CPU-side busy time (env
    // stepping + replay service) vs GPU-side busy time (inference +
    // training), from cumulative timer sums.
    // Fleet frame codec time is CPU-side work the transport adds on the
    // coordinator (0 in-process): it belongs on the CPU side of the
    // ratio the same way replay service does.
    let cpu_s = get("actor.env_seconds.sum")
        + get("learner.sample_seconds.sum")
        + get("learner.assemble_seconds.sum")
        + get("fleet.encode_seconds.sum")
        + get("fleet.decode_seconds.sum");
    let gpu_s = get("batcher.infer_seconds.sum") + get("learner.train_seconds.sum");
    if gpu_s > 0.0 {
        out.push((CPU_GPU_RATIO, cpu_s / gpu_s));
    }
    for (k, v) in &out {
        metrics.gauge(k).set(*v);
    }
    *prev = cur;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_writes_parseable_jsonl_with_derived_gauges() {
        let dir = std::env::temp_dir().join("rlarch_sampler_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.jsonl");
        let metrics = Registry::new();
        // Simulate a running system.
        metrics.counter("actor.env_steps").add(100);
        metrics.counter("batcher.items").add(100);
        metrics.counter("batcher.batches").add(20);
        metrics.counter("batcher.padded_rows").add(28);
        metrics.timer("actor.env_seconds").record(0.4);
        metrics.timer("batcher.infer_seconds").record(0.1);
        metrics.timer("learner.train_seconds").record(0.1);

        let handle =
            start(metrics.clone(), path.to_str().unwrap(), 5).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        metrics.counter("actor.env_steps").add(50);
        let samples = handle.stop().unwrap();
        assert!(samples >= 2, "expected multiple ticks, got {samples}");

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len() as u64, samples);
        for line in &lines {
            let v = Value::parse(line).expect("JSONL line must parse");
            assert!(v.get("t").unwrap().as_f64().unwrap() >= 0.0);
            assert!(v.get("actor.env_steps").is_some());
        }
        // First tick sees all cumulative state as a delta: occupancy and
        // the cpu/gpu ratio are present and sane.
        let first = Value::parse(lines[0]).unwrap();
        let occ = first.get(BATCH_OCCUPANCY).unwrap().as_f64().unwrap();
        assert!((occ - 5.0).abs() < 1e-9, "occupancy {occ}");
        let pad = first.get(PADDING_EFFICIENCY).unwrap().as_f64().unwrap();
        assert!((pad - 100.0 / 128.0).abs() < 1e-9, "padding {pad}");
        let ratio = first.get(CPU_GPU_RATIO).unwrap().as_f64().unwrap();
        assert!((ratio - 2.0).abs() < 1e-9, "cpu/gpu ratio {ratio}");
        // Derived gauges are published back into the registry.
        assert!(metrics.gauge(STEPS_PER_SEC).written());
        assert!((metrics.gauge(CPU_GPU_RATIO).get() - 2.0).abs() < 1e-9);

        let bad = start(Registry::new(), "/nonexistent-dir/x.jsonl", 5);
        assert!(bad.is_err(), "bad path must fail eagerly");
    }
}
