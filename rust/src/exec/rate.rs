//! Token-bucket rate limiter (used to throttle actor env-step rates when
//! emulating slower environment simulators, and for backpressure tests).

use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct RateLimiter {
    rate_per_sec: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl RateLimiter {
    pub fn new(rate_per_sec: f64, burst: f64) -> Self {
        assert!(rate_per_sec > 0.0 && burst >= 1.0);
        Self {
            rate_per_sec,
            burst,
            tokens: burst,
            last: Instant::now(),
        }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate_per_sec).min(self.burst);
    }

    /// Non-blocking: take a token if available.
    pub fn try_acquire(&mut self) -> bool {
        self.refill();
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Blocking: sleep until a token is available, then take it.
    pub fn acquire(&mut self) {
        loop {
            self.refill();
            if self.tokens >= 1.0 {
                self.tokens -= 1.0;
                return;
            }
            let deficit = 1.0 - self.tokens;
            let wait = deficit / self.rate_per_sec;
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.01)));
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_throttle() {
        let mut rl = RateLimiter::new(100.0, 5.0);
        let mut immediate = 0;
        for _ in 0..5 {
            if rl.try_acquire() {
                immediate += 1;
            }
        }
        assert_eq!(immediate, 5);
        // Bucket drained; next acquire should mostly fail instantly.
        assert!(!rl.try_acquire() || !rl.try_acquire());
    }

    #[test]
    fn acquire_approximates_rate() {
        let mut rl = RateLimiter::new(2000.0, 1.0);
        let start = Instant::now();
        for _ in 0..100 {
            rl.acquire();
        }
        let elapsed = start.elapsed().as_secs_f64();
        // 100 tokens at 2000/s ≈ 50 ms (allow broad CI jitter).
        assert!(elapsed > 0.03, "too fast: {elapsed}");
        assert!(elapsed < 1.0, "too slow: {elapsed}");
    }
}
