//! Cooperative shutdown signalling for actor/learner/batcher threads.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cloneable token; `signal()` flips all clones.
#[derive(Clone, Debug, Default)]
pub struct ShutdownToken {
    flag: Arc<AtomicBool>,
}

impl ShutdownToken {
    pub fn new() -> Self {
        Self {
            flag: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn signal(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_signalled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }

    /// Sleep in small slices so shutdown latency stays bounded.
    /// Returns true if shutdown was signalled during the wait.
    pub fn sleep_interruptible(&self, total: Duration) -> bool {
        let deadline = Instant::now() + total;
        let slice = Duration::from_millis(5).min(total);
        while Instant::now() < deadline {
            if self.is_signalled() {
                return true;
            }
            std::thread::sleep(slice);
        }
        self.is_signalled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_state() {
        let t = ShutdownToken::new();
        let t2 = t.clone();
        assert!(!t2.is_signalled());
        t.signal();
        assert!(t2.is_signalled());
    }

    #[test]
    fn interruptible_sleep_returns_early() {
        let t = ShutdownToken::new();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            t2.signal();
        });
        let start = Instant::now();
        let interrupted = t.sleep_interruptible(Duration::from_secs(5));
        assert!(interrupted);
        assert!(start.elapsed() < Duration::from_secs(1));
        h.join().unwrap();
    }
}
