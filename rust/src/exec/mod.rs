//! Execution substrate: thread pool, channels, shutdown tokens, rate
//! limiting.
//!
//! Tokio is not in the offline crate set; the coordinator's event loop is
//! built on std threads + channels, which is also the honest model
//! of SEED-RL's actor/learner processes (blocking env steps, a central
//! batched inference service, and a learner thread). The hot inference
//! path uses [`channel`] instead of `std::sync::mpsc` because std mpsc
//! allocates a queue node per send — see the module docs.

pub mod channel;
pub mod pool;
pub mod rate;
pub mod shutdown;

pub use channel::{channel_counted, Receiver, RecvTimeoutError, Sender};
pub use pool::ThreadPool;
pub use rate::RateLimiter;
pub use shutdown::ShutdownToken;
