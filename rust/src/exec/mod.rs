//! Execution substrate: thread pool, shutdown tokens, rate limiting.
//!
//! Tokio is not in the offline crate set; the coordinator's event loop is
//! built on std threads + mpsc channels, which is also the honest model
//! of SEED-RL's actor/learner processes (blocking env steps, a central
//! batched inference service, and a learner thread).

pub mod pool;
pub mod rate;
pub mod shutdown;

pub use pool::ThreadPool;
pub use rate::RateLimiter;
pub use shutdown::ShutdownToken;
