//! Fixed-size thread pool with join-on-drop semantics.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed-size worker pool. `execute` never blocks; jobs queue in
/// an unbounded channel. Dropping the pool joins all workers after the
/// queue drains.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("rlarch-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Jobs submitted but not yet completed.
    pub fn pending(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Busy-wait (with yield) until all submitted jobs complete.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel-for over a slice of inputs, returning outputs in order.
/// Spawns up to `threads` OS threads; used by sweep drivers in benches.
pub fn parallel_map<T, R, F>(inputs: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1);
    let n = inputs.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = inputs.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let f = &f;
    let slots = Mutex::new(&mut results);
    thread::scope(|s| {
        for _ in 0..threads.min(n.max(1)) {
            s.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, x)) => {
                        let r = f(x);
                        slots.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_after_drain() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop here
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..64).collect::<Vec<u64>>(), 8, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<u64> = parallel_map(Vec::<u64>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
